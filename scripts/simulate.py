"""Trace-driven fleet simulation CLI: run the EdgeRL controller (or a
static baseline) against request-level traffic and report per-request
latency percentiles, SLO attainment, goodput and energy.

    PYTHONPATH=src python scripts/simulate.py \
        --trace diurnal --devices 8 --requests 100000

    # compare the trained controller against the static baselines under
    # bursty (MMPP) traffic — same seeds => identical request streams
    PYTHONPATH=src python scripts/simulate.py --trace mmpp \
        --compare a2c,device_only,full_offload --seeds 0,1,2

    # cross-check the analytical backend against real SplitServingEngine
    # execution on a reduced transformer (TPU env)
    PYTHONPATH=src python scripts/simulate.py --env tpu --execute \
        --sample 16 --requests 20000

The default paper-env fleet is the "UAV testbed scaled up": per-device
server provisioning held at the 3-UAV paper ratio, WiFi-6-class uplink
(1 Gb/s max), 10 s decision slots, and the beyond-paper stability-aware
reward (RewardWeights.w_stab) so the trained controller knows about
request-level capacity (see DESIGN.md §5).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (A2CConfig, RewardWeights, agent_policy,
                        make_paper_env, make_tpu_env, train_agent,
                        transformer_profile)
from repro.core.baselines import POLICIES
from repro.core.latency import LatencyParams
from repro.sim import (AnalyticalBackend, ExecuteBackend, FleetConfig,
                       get_trace, simulate)
from repro.sim.traces import RandomRateTrace

POLICY_CHOICES = ("a2c", "oracle", "device_only", "full_offload", "random")
_BASELINES = {"oracle": "greedy_oracle", "device_only": "device_only",
              "full_offload": "full_offload", "random": "random"}


def build_trace(args):
    if args.trace == "poisson":
        return get_trace("poisson", rate_rps=args.rate)
    if args.trace == "mmpp":
        return get_trace("mmpp", rate_low_rps=args.rate_low,
                         rate_high_rps=args.rate_high)
    if args.trace == "diurnal":
        return get_trace("diurnal", base_rps=args.rate_low,
                         peak_rps=args.rate_high)
    if args.trace == "uniform":
        return get_trace("uniform", max_rps=args.rate_high)
    if args.trace == "replay":
        if not args.replay_file:
            raise SystemExit("--trace replay needs --replay-file (.npy)")
        return get_trace("replay", counts=np.load(args.replay_file),
                         slot_seconds_recorded=args.slot_seconds)
    raise SystemExit(f"unknown trace {args.trace}")


def build_env(args):
    """Returns (env_cfg, tables, model_ids, backend_factory)."""
    weights = RewardWeights(w_acc=args.w_acc, w_lat=args.w_lat,
                            w_energy=args.w_energy, w_stab=args.w_stab)
    if args.env == "tpu":
        import jax

        from repro.configs import get_config
        from repro.models import init

        archs = [args.arch] * args.devices
        env_cfg, tables = make_tpu_env(
            archs, weights=weights, reduced=True, seq_len=args.exec_seq,
            slot_seconds=args.slot_seconds, peak_rps=args.peak_rps)
        model_ids = np.zeros(args.devices, np.int32)

        def backend_factory():
            if not args.execute:
                return AnalyticalBackend(env_cfg, tables)
            cfg = get_config(args.arch).reduced()
            prof = transformer_profile(cfg, seq_len=args.exec_seq)
            params = init(cfg, jax.random.key(0))
            return ExecuteBackend(env_cfg, tables, [cfg], [prof], [params],
                                  seq_len=args.exec_seq, sample=args.sample)
        return env_cfg, tables, model_ids, backend_factory

    if args.execute:
        raise SystemExit("--execute needs --env tpu (the executable "
                         "engine serves the transformer stack)")
    # paper env, fleet-scaled: hold per-device server provisioning at the
    # paper's 3-UAV ratio and give the uplink a WiFi-6-class ceiling
    lat = LatencyParams(server_flops=0.55e12 * args.devices,
                        bw_max_bps=1e9)
    env_cfg, tables = make_paper_env(
        weights=weights, n_uavs=args.devices, latency=lat,
        slot_seconds=args.slot_seconds, peak_rps=args.peak_rps,
        # one frame per request at saturation: keeps the env's battery
        # drain per slot equal to the fleet's per-request metering
        frames_per_slot=args.slot_seconds * max(args.peak_rps, 1.0))
    if args.models == "cycle":
        model_ids = np.arange(args.devices, dtype=np.int32) % tables.n_models
    else:
        model_ids = np.full(args.devices, tables.names.index(args.models),
                            np.int32)
    return env_cfg, tables, model_ids, \
        lambda: AnalyticalBackend(env_cfg, tables)


def build_policy(name, env_cfg, tables, args):
    if name != "a2c":
        return POLICIES[_BASELINES[name]]
    peak = args.peak_rps if args.peak_rps > 0 else 2.0 * args.rate
    print(f"training A2C controller ({args.episodes} episodes, "
          f"domain-randomized load up to {peak:.0f} rps) ...", flush=True)
    params, hist = train_agent(
        env_cfg, tables,
        A2CConfig(episodes=args.episodes, entropy_coef=0.03),
        seed=args.train_seed,
        trace=RandomRateTrace(max_rps=peak) if env_cfg.peak_rps > 0
        else None)
    last = np.mean([h["mean_reward"] for h in hist[-15:]])
    print(f"  trained: mean reward (last 15 episodes) = {last:+.3f}")
    return agent_policy(params)


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--trace", default="diurnal",
                    choices=("poisson", "mmpp", "diurnal", "uniform",
                             "replay"))
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--requests", type=int, default=100_000)
    ap.add_argument("--policy", default="a2c", choices=POLICY_CHOICES)
    ap.add_argument("--compare", default=None,
                    help="comma-separated policies; overrides --policy")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated sim seeds; metrics average "
                    "over them (same seed = same request stream)")
    ap.add_argument("--episodes", type=int, default=300)
    ap.add_argument("--train-seed", type=int, default=0)
    ap.add_argument("--slo-ms", type=float, default=2000.0)
    ap.add_argument("--slot-seconds", type=float, default=10.0)
    ap.add_argument("--rate", type=float, default=6.0,
                    help="poisson rate (requests/s/device)")
    ap.add_argument("--rate-low", type=float, default=2.0,
                    help="mmpp calm rate / diurnal base rate")
    ap.add_argument("--rate-high", type=float, default=30.0,
                    help="mmpp burst rate / diurnal peak / uniform max")
    ap.add_argument("--peak-rps", type=float, default=30.0,
                    help="load-feature saturation rate; 0 disables the "
                    "stability reward term (paper-faithful)")
    ap.add_argument("--replay-file", default=None)
    ap.add_argument("--models", default="cycle",
                    choices=("cycle", "vgg", "resnet", "densenet"),
                    help="paper-env fleet composition")
    ap.add_argument("--w-acc", type=float, default=0.05)
    ap.add_argument("--w-lat", type=float, default=0.10)
    ap.add_argument("--w-energy", type=float, default=0.15)
    ap.add_argument("--w-stab", type=float, default=0.70)
    ap.add_argument("--env", default="paper", choices=("paper", "tpu"))
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--execute", action="store_true",
                    help="cross-check a sampled subset through the real "
                    "SplitServingEngine (tpu env)")
    ap.add_argument("--sample", type=int, default=16)
    ap.add_argument("--exec-seq", type=int, default=32)
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args()

    trace = build_trace(args)
    env_cfg, tables, model_ids, backend_factory = build_env(args)
    fleet = FleetConfig(slo_s=args.slo_ms / 1e3)
    seeds = [int(s) for s in args.seeds.split(",")]
    names = (args.compare.split(",") if args.compare else [args.policy])
    for nm in names:
        if nm not in POLICY_CHOICES:
            ap.error(f"unknown policy {nm!r}; choices {POLICY_CHOICES}")

    print(f"fleet: {args.devices} devices, trace={trace.name} "
          f"(mean {trace.mean_rps:.1f} rps/device), slo={fleet.slo_s}s, "
          f"requests={args.requests} x seeds {seeds}")
    hdr = (f"{'policy':14s} {'requests':>9s} {'p50_s':>8s} {'p95_s':>8s} "
           f"{'p99_s':>8s} {'slo_att':>8s} {'goodput':>8s} {'E/req_J':>8s} "
           f"{'drop':>6s}")
    out = {"config": {k: v for k, v in vars(args).items()}, "policies": {}}
    rows_printed = False
    for name in names:
        policy = build_policy(name, env_cfg, tables, args)
        per_seed = []
        cross = None
        for seed in seeds:
            res = simulate(env_cfg, tables, policy, trace,
                           n_requests=args.requests, seed=seed, fleet=fleet,
                           backend=backend_factory(), model_ids=model_ids)
            per_seed.append(res.summary)
            cross = res.cross_check or cross
        mean = {k: float(np.mean([s[k] for s in per_seed]))
                for k in per_seed[0] if k != "unit"}
        if not rows_printed:
            print("\n" + hdr)
            rows_printed = True
        print(f"{name:14s} {mean['count']:9.0f} {mean['p50']:8.3f} "
              f"{mean['p95']:8.2f} {mean['p99']:8.2f} "
              f"{mean['slo_attainment']:8.3f} {mean['goodput']:8.1f} "
              f"{mean['energy_per_request_j']:8.3f} {mean['dropped']:6.0f}")
        out["policies"][name] = {"mean": mean, "per_seed": per_seed}
        if cross:
            out["policies"][name]["cross_check"] = {
                k: v for k, v in cross.items() if k != "records"}
    if cross := next((out["policies"][n].get("cross_check")
                      for n in names if out["policies"][n].get("cross_check")),
                     None):
        print(f"\nexecute cross-check: {cross['samples']} requests through "
              f"SplitServingEngine; act-bytes exact={cross['bytes_exact']} "
              f"({cross['bytes_mismatches']} mismatches); wall/analytical "
              f"latency ratio median={cross['latency_ratio_median']:.2f} "
              f"max={cross['latency_ratio_max']:.2f} "
              f"(tolerance {cross['latency_tolerance']}x, within="
              f"{cross['latency_within_tolerance']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=float)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()

"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088 (Mixtral of Experts)",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,             # per-expert hidden size
    vocab_size=32_768,
    head_dim=128,
    moe=True,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=16_384,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    mlp_act="swiglu",
))

"""Request-pricing backends behind one interface.

``AnalyticalBackend`` prices every request through the single cost core
(``repro.core.pricing``) with ``xp=numpy`` over numpy table snapshots —
the identical formulas the env rewards with under jnp, at fleet scale
(millions of simulated requests on CPU).

``ExecuteBackend`` extends it: a sampled subset of requests is routed
through the real ``SplitServingEngine`` on a reduced config, so the
simulated activation bytes can be cross-checked *exactly* against the
measured ones, and the analytical latency model can be checked for
consistency against wall-clock execution (calibrated on the first
sample; ratios thereafter must stay within a stated tolerance). The
expected cost it checks against comes from the same PricingBreakdown the
fleet prices with.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core import pricing
from repro.core.env import EnvConfig, ProfileTables
from repro.core.pricing import PricingBreakdown, StateView

# Per-device per-request cost constants for one decision epoch. Within an
# epoch every request of a device shares these constants (same state,
# same action); per-request variability comes from the fleet loop's
# queueing recursion. Alias kept for API compatibility.
RequestPricing = PricingBreakdown

# Fault injection for validating the perf gate (DESIGN.md §10): a
# nonzero REPRO_CHAOS_PRICING_SLEEP_S sleeps that long inside every
# analytical pricing call, so `scripts/benchgate.py` can be shown to
# fail the regressed case AND attribute it to the pricing.analytical
# phase. Never set outside gate acceptance runs.
_CHAOS_SLEEP = float(os.environ.get("REPRO_CHAOS_PRICING_SLEEP_S", 0) or 0)


class AnalyticalBackend:
    """Prices (version, cut) actions from the dense env tables."""

    def __init__(self, env_cfg: EnvConfig, tables: ProfileTables):
        self.env_cfg = env_cfg
        self.tables = tables
        # numpy snapshots: indexing dense tables per epoch must not pay
        # jnp dispatch on the hot path
        self._np_tables = pricing.numpy_tables(tables)

    def price(self, model_id: np.ndarray, actions: np.ndarray,
              bandwidth: np.ndarray, p_tx: np.ndarray, *,
              srv_flops=None, srv_service_s=None, link_scale=None,
              link_rtt_s=None) -> PricingBreakdown:
        """One pricing core, numpy namespace. The view carries queue=0 —
        the fleet loop adds its own *measured* server wait per epoch —
        and load=0 (the stability score is a training-time signal).
        Cluster runs pass the pool's live per-server service arrays and
        the topology's link matrices; actions then carry a server column
        and the core reprices Eq. 2-4 against each chosen target."""
        with obs.span("pricing.analytical", n=len(np.asarray(model_id))):
            if _CHAOS_SLEEP:
                time.sleep(_CHAOS_SLEEP)
            view = StateView(
                model_id=np.asarray(model_id),
                bandwidth=np.asarray(bandwidth, dtype=np.float64),
                p_tx=np.asarray(p_tx, dtype=np.float64),
                queue=0.0, load=0.0,
                srv_flops=srv_flops, srv_service_s=srv_service_s,
                link_scale=link_scale, link_rtt_s=link_rtt_s)
            return pricing.price_actions(self.env_cfg, self._np_tables,
                                         view, np.asarray(actions), xp=np)

    # the analytical backend executes nothing; the fleet loop calls this
    # hook unconditionally so both backends share one interface
    def maybe_execute(self, model_idx: int, j: int, k: int) -> None:
        return None

    def cross_check(self) -> Optional[Dict]:
        return None


class ExecuteBackend(AnalyticalBackend):
    """Analytical pricing + sampled execution through SplitServingEngine.

    ``model_cfgs``/``profiles`` must be the (reduced) configs and the
    ModelProfiles the env tables were built from, and ``seq_len`` the
    profile sequence length — the executed batch is (1, seq_len) so the
    measured cut activation is byte-identical to the table entry.
    """

    def __init__(self, env_cfg: EnvConfig, tables: ProfileTables,
                 model_cfgs: Sequence, profiles: Sequence,
                 params: Sequence, *, seq_len: int, sample: int = 16,
                 latency_tolerance: float = 5.0):
        from repro.serving import SplitServingEngine

        super().__init__(env_cfg, tables)
        self.model_cfgs = list(model_cfgs)
        self.profiles = list(profiles)
        self.seq_len = int(seq_len)
        self.sample = int(sample)
        self.latency_tolerance = float(latency_tolerance)
        self.records: List[Dict] = []
        self._calib_speedup: Optional[float] = None
        self._engines = [
            SplitServingEngine(c, p, versions=tuple(v.version
                                                    for v in prof.versions))
            for c, p, prof in zip(self.model_cfgs, params, self.profiles)]
        self._batches = [self._make_batch(c) for c in self.model_cfgs]

    def _make_batch(self, cfg):
        import jax.numpy as jnp

        toks = (jnp.arange(self.seq_len, dtype=jnp.int32)[None] * 7) \
            % cfg.vocab_size
        batch = {"tokens": toks}
        if cfg.cross_attn_every:
            batch["media"] = jnp.zeros((1, cfg.n_media_tokens, cfg.d_model),
                                       cfg.cdtype)
        if cfg.enc_dec:
            batch["enc_frames"] = jnp.zeros((1, cfg.encoder_seq, cfg.d_model),
                                            cfg.cdtype)
        return batch

    def expected_act_bytes(self, model_idx: int, j: int, k: int,
                           batch: int = 1) -> int:
        """Wire bytes the engine must measure for this action: the table
        entry scaled by batch, plus the f32 per-row scales the w8a8 link
        format carries (engine.infer ships int8 codes + scales; the env
        tables price codes only — the scale vector is the one term the
        slot-level tables fold away)."""
        from repro.quant import get_version

        prof = self.profiles[model_idx]
        v = prof.versions[min(j, len(prof.versions) - 1)]
        base = int(self._np_tables.cut_bytes[model_idx, j, k]) * batch
        if get_version(v.version).act_bits == 8:
            base += batch * self.seq_len * 4
        return base

    def maybe_execute(self, model_idx: int, j: int, k: int) -> None:
        """Route one request through the real split engine (up to
        ``sample`` total) and record measured vs analytical cost.

        Terminal cuts (profile layer == n_layers) are skipped: the env
        prices them as device-complete inference shipping a class id,
        while the executable engine always finishes logits server-side —
        nothing crosses the link for the tables to agree with."""
        if len(self.records) >= self.sample:
            return
        import jax

        from repro.core.controller import resolve_selection

        cfg = self.model_cfgs[model_idx]
        prof = self.profiles[model_idx]
        v = prof.versions[min(j, len(prof.versions) - 1)]
        if v.cut_points[min(k, len(v.cut_points) - 1)] >= v.n_layers:
            return
        version, cut = resolve_selection(cfg, prof, int(j), int(k))
        eng = self._engines[model_idx]
        batch = self._batches[model_idx]
        with obs.span("pricing.execute", model=cfg.name, version=version,
                      cut=str(cut)):
            logits, _ = eng.infer(batch, cut, version)   # warm (compile)
            jax.block_until_ready(logits)
            t0 = time.perf_counter()
            logits, measured_bytes = eng.infer(batch, cut, version)
            jax.block_until_ready(logits)
            wall_s = time.perf_counter() - t0
        # expected compute time from the same PricingBreakdown the fleet
        # prices with: head + tail model-seconds for this (j, k); the
        # engine runs both halves on this host, so no link/queue terms
        br = self.price(np.asarray([model_idx]),
                        np.asarray([[j, k]]),
                        np.asarray([1.0]), np.asarray([0.0]))
        model_s = float(br.head_s[0] + br.tail_s[0])
        if self._calib_speedup is None:
            # first sample calibrates this host's speed relative to the
            # modeled device/server regime; later samples then test the
            # analytical model's *relative* cost structure against real
            # execution
            self._calib_speedup = model_s / max(wall_s, 1e-9)
        est_s = model_s / self._calib_speedup
        self.records.append({
            "model": cfg.name, "version": version, "cut": cut,
            "j": int(j), "k": int(k),
            "expected_bytes": self.expected_act_bytes(model_idx, j, k),
            "measured_bytes": int(measured_bytes),
            "wall_s": wall_s, "est_s": est_s,
        })

    def cross_check(self) -> Optional[Dict]:
        if not self.records:
            return None
        mismatches = [r for r in self.records
                      if r["expected_bytes"] != r["measured_bytes"]]
        ratios = np.array([r["wall_s"] / max(r["est_s"], 1e-12)
                           for r in self.records])
        tol = self.latency_tolerance
        return {
            "samples": len(self.records),
            "bytes_exact": not mismatches,
            "bytes_mismatches": len(mismatches),
            "latency_ratio_median": float(np.median(ratios)),
            "latency_ratio_max": float(np.max(ratios)),
            "latency_tolerance": tol,
            "latency_within_tolerance": bool(
                np.all((ratios >= 1.0 / tol) & (ratios <= tol))),
            "records": self.records,
        }

"""Reward function (paper Eqs. 8-11).

R = mean_k( w1*A + w2*L + w3*E ), sum(w) = 1.
A: sigmoid-normalized accuracy; L/E: 1 - cost / all-local cost.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    w_acc: float = 1 / 3
    w_lat: float = 1 / 3
    w_energy: float = 1 / 3
    # Eq. 9 sigmoid shape
    p: float = 20.0
    q: float = 0.72

    def normalized(self) -> "RewardWeights":
        s = self.w_acc + self.w_lat + self.w_energy
        return dataclasses.replace(self, w_acc=self.w_acc / s,
                                   w_lat=self.w_lat / s,
                                   w_energy=self.w_energy / s)


def accuracy_score(w: RewardWeights, acc):
    """Eq. 9."""
    return 1.0 / (1.0 + jnp.exp(-w.p * (acc - w.q)))


def latency_score(t_total, t_all_local):
    """Eq. 10."""
    return 1.0 - t_total / jnp.maximum(t_all_local, 1e-9)


def energy_score(e_total, e_all_local):
    """Eq. 11."""
    return 1.0 - e_total / jnp.maximum(e_all_local, 1e-9)


def reward(w: RewardWeights, acc_s, lat_s, energy_s, mask=None):
    """Eq. 8: per-UAV weighted sum averaged over (active) UAVs."""
    r = w.w_acc * acc_s + w.w_lat * lat_s + w.w_energy * energy_s
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(r * mask) / denom
    return jnp.mean(r)

"""Per-request fleet metrics: latency percentiles, SLO attainment,
goodput and energy — not just slot-averaged scores.

``summarize_latencies`` is the shared schema: the fleet simulator and
the continuous-batching scheduler (``serving.ServerStats``) both report
through it, so a latency table means the same thing whether the numbers
came from the analytical pricer or from wall-clock decode steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# Keys every latency report carries (values are floats; "unit" is the
# only string: "s" for the simulator, "steps" for the scheduler).
LATENCY_SCHEMA = ("count", "mean", "p50", "p95", "p99", "max",
                  "slo", "slo_attainment", "goodput")


def summarize_latencies(latencies, *, slo: Optional[float] = None,
                        duration: Optional[float] = None,
                        unit: str = "s") -> Dict:
    """Percentiles + SLO attainment + goodput for a latency array.

    ``slo``: deadline in the same unit; attainment is the fraction of
    requests at or under it. ``duration``: wall span of the measurement
    window; goodput is SLO-met requests per unit duration (falls back
    to all completed requests when no SLO is given).
    """
    lat = np.asarray(latencies, dtype=np.float64).ravel()
    out = {k: 0.0 for k in LATENCY_SCHEMA}
    out["unit"] = unit
    out["count"] = float(lat.size)
    out["slo"] = float(slo) if slo is not None else float("nan")
    if lat.size == 0:
        out["slo_attainment"] = float("nan")
        return out
    out["mean"] = float(np.mean(lat))
    p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
    out["p50"], out["p95"], out["p99"] = float(p50), float(p95), float(p99)
    out["max"] = float(np.max(lat))
    good = float(np.sum(lat <= slo)) if slo is not None else float(lat.size)
    out["slo_attainment"] = good / lat.size if slo is not None \
        else float("nan")
    out["goodput"] = good / duration if duration else 0.0
    return out


@dataclasses.dataclass
class FleetMetrics:
    """Streaming accumulator for per-request outcomes.

    Latency/energy arrays are appended per (device, epoch) batch and
    concatenated once at summary time, so recording is O(1) per batch
    and a multi-million-request run stays a handful of numpy arrays.
    """
    slo_s: float = 1.0
    _lat: List[np.ndarray] = dataclasses.field(default_factory=list)
    _energy: List[np.ndarray] = dataclasses.field(default_factory=list)
    _device: List[np.ndarray] = dataclasses.field(default_factory=list)
    dropped: int = 0

    def record(self, latencies_s, energies_j=None, device=None):
        lat = np.asarray(latencies_s, dtype=np.float64).ravel()
        if lat.size == 0:
            return
        self._lat.append(lat)
        if energies_j is not None:
            e = np.asarray(energies_j, dtype=np.float64).ravel()
            self._energy.append(np.broadcast_to(e, lat.shape).copy()
                                if e.size != lat.size else e)
        if device is not None:
            self._device.append(np.full(lat.shape, device, dtype=np.int32))

    def drop(self, n: int):
        """Requests lost outright (dead device): SLO misses, no latency."""
        self.dropped += int(n)

    @property
    def latencies_s(self) -> np.ndarray:
        return np.concatenate(self._lat) if self._lat else np.zeros(0)

    @property
    def energies_j(self) -> np.ndarray:
        return np.concatenate(self._energy) if self._energy else np.zeros(0)

    @property
    def devices(self) -> np.ndarray:
        return np.concatenate(self._device) if self._device \
            else np.zeros(0, np.int32)

    def summary(self, duration_s: Optional[float] = None) -> Dict:
        lat = self.latencies_s
        out = summarize_latencies(lat, slo=self.slo_s, duration=duration_s,
                                  unit="s")
        # dropped requests count against attainment and goodput
        total = lat.size + self.dropped
        if total:
            met = out["slo_attainment"] * lat.size if lat.size else 0.0
            out["slo_attainment"] = met / total
        out["dropped"] = float(self.dropped)
        e = self.energies_j
        out["energy_j"] = float(np.sum(e))
        out["energy_per_request_j"] = float(np.mean(e)) if e.size else 0.0
        out["duration_s"] = float(duration_s) if duration_s else 0.0
        return out

"""Checkpointing: flattened-pytree .npz files (no orbax in this env).

Path-keyed so restores are structure-checked; works for model params,
optimizer state, and the A2C agent alike. Sharded arrays are gathered to
host before save (fine at the sizes we train here; a production TPU run
would write per-shard files — noted in DESIGN.md).
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    name: str = "state") -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"{name}_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str, name: str = "state") -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1))
             for f in os.listdir(ckpt_dir)
             if (m := re.match(rf"{name}_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       name: str = "state") -> Any:
    path = os.path.join(ckpt_dir, f"{name}_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    treedef = leaves_with_path[1]
    out = []
    for path_k, leaf in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)

"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh):
  compute term    = FLOPs / (chips * 197 TFLOP/s bf16)
  memory term     = bytes  / (chips * 819 GB/s HBM)
  collective term = collective bytes / (chips * 50 GB/s link)

FLOPs/bytes come from the scan-aware jaxpr walker (analysis/jaxpr_cost.py);
raw compiled.cost_analysis() numbers are stored alongside for reference but
undercount while-loop bodies (verified; see EXPERIMENTS.md §Methodology).
Collective bytes come from the compiled HLO with loop-trip-count
multiplication (analysis/hlo_collectives.py).

MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference); the ratio
MODEL_FLOPS / FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.configs import SHAPES, get_config
from repro.core.transformer_cost import model_flops
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW


def _advice(dom: str, rec: Dict) -> str:
    arch, shape = rec["arch"], rec["shape"]
    if dom == "compute":
        if "deepseek" in arch and "decode" in shape:
            return ("absorb w_uk/w_uv into q/out projections so the MLA "
                    "cache is attended in latent space (no per-step "
                    "re-expansion)")
        if rec.get("ratio", 1) < 0.5:
            return ("cut non-model FLOPs: masked-causal block skipping in "
                    "chunked attention / leaner MoE dispatch")
        return "fuse elementwise chains; raise arithmetic intensity per block"
    if dom == "memory":
        return ("shrink live activations: smaller loss/attention chunks, "
                "offload-friendly remat policy, bf16 master-weight split")
    return ("reduce gradient/param all-reduce volume: FSDP-style "
            "reduce-scatter + all-gather schedule, or overlap collectives "
            "with the backward scan")


def load(path: str) -> List[Dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"],
                  r.get("variant", "baseline"))] = r  # last wins
    return list(recs.values())


def enrich(rec: Dict) -> Dict:
    chips = rec["devices"]
    flops = rec.get("jaxpr_flops", 0.0)
    mbytes = rec.get("jaxpr_bytes_fused",
                     rec.get("jaxpr_bytes_min", rec.get("jaxpr_bytes", 0.0)))
    cbytes = rec.get("collectives", {}).get("total_bytes", 0.0)
    t_c = flops / (chips * PEAK_FLOPS_BF16)
    t_m = mbytes / (chips * HBM_BW)
    t_l = cbytes / (chips * ICI_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    info = SHAPES[rec["shape"]]
    cfg = get_config(rec["arch"])
    mf = model_flops(cfg, info["kind"], info["global_batch"], info["seq_len"])
    out = dict(rec)
    out.update(compute_s=t_c, memory_s=t_m, collective_s=t_l, dominant=dom,
               model_flops=mf, ratio=(mf / flops if flops else 0.0),
               bound_s=max(t_c, t_m, t_l))
    out["advice"] = _advice(dom, out)
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def table(recs: List[Dict], mesh: str = "single",
          variant: str = "baseline") -> str:
    rows = [enrich(r) for r in recs
            if r["mesh"] == mesh and r["status"] == "ok"
            and r.get("variant", "baseline") == variant]
    rows.sort(key=lambda r: (r["shape"], -r["bound_s"]))
    lines = ["| arch | shape | compute | memory | collective | dominant |"
             " MODEL/HLO | note |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['ratio']:.2f} | {r['advice']} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    recs = load(args.results)
    print(table(recs, args.mesh))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([enrich(r) for r in recs], f, indent=1)


if __name__ == "__main__":
    main()

"""Summarize an obs event trace (events.jsonl) into per-phase timing,
drift/online timeline, metrics and JAX compile/retrace accounting.

    # record a trace, then view it
    PYTHONPATH=src python scripts/simulate.py --scenario link-brownout \
        --trace-out events.jsonl
    PYTHONPATH=src python scripts/obsview.py events.jsonl

    # machine-readable folded report alongside the text view
    PYTHONPATH=src python scripts/obsview.py events.jsonl --json obs.json

    # or JSON only, to stdout (what the bench gate / CI consumes
    # instead of scraping the printed table)
    PYTHONPATH=src python scripts/obsview.py events.jsonl --json -
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import report as obs_report


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("events", help="obs JSONL trace (simulate.py "
                    "--trace-out / benchmarks/run.py --trace)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the folded report as JSON "
                    "('-' = JSON only, to stdout — machine-readable "
                    "for the bench gate / CI)")
    args = ap.parse_args()

    try:
        rep = obs_report.load(args.events)
    except (OSError, ValueError) as e:
        raise SystemExit(f"obsview: {e}")
    if args.json == "-":
        json.dump(rep, sys.stdout, indent=2, default=str)
        print()
        return
    print(obs_report.render(rep))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=2, default=str)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()

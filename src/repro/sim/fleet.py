"""Discrete-event trace-driven fleet simulator.

Each decision epoch (one env slot):

1. the trace delivers per-device request arrivals,
2. the controller policy picks (version, cut) per device from the
   *measured* state — observed arrival rate (EWMA), server queue depth,
   battery, link bandwidth — via ``controller.measured_state``,
3. the pricing backend turns each action into per-request cost
   constants (head/link/tail times, energy, wire bytes),
4. requests flow through a per-device FIFO: the device serializes
   head-compute + transmit per request, so completion times follow the
   Lindley recursion C_k = max(A_k, C_{k-1}) + s — vectorized with a
   running max, so a million-request epoch is a few numpy ops,
5. offloaded tails add the measured server wait (queue * job service
   time, exactly the env's Eq. 4 term) and feed the server backlog that
   the *next* epoch's controller observes.

Per-request end-to-end latency, SLO attainment, goodput and energy
accumulate in ``FleetMetrics``; device backlogs carry across epochs, so
bursts (MMPP) really queue instead of averaging away.

Nonstationary worlds (``repro.online``): a ``WorldSchedule`` switches
the *physics* — pricing config, world-dynamics bounds, trace scale,
battery/churn side effects — at its regime boundaries, while the
controller's observation normalization keeps the base-regime constants
(sensors don't learn the world's config file changed). An
``OnlineConfig`` additionally closes the loop: the fleet captures each
epoch's measured transition, prices its reward under the *current*
regime, and lets an ``OnlineLearner`` incrementally update and hot-swap
the policy's parameters mid-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro import obs
from repro.core import energy as en
from repro.core.env import EnvConfig, ProfileTables
from repro.sim.backends import AnalyticalBackend
from repro.sim.metrics import EpochLog, FleetMetrics
from repro.sim.traces import Trace

ENGINES = ("loop", "vectorized", "scan")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    slo_s: float = 1.0            # per-request deadline
    ewma: float = 0.5             # observed arrival-rate smoothing
    max_epochs: int = 100_000
    load_norm_rps: Optional[float] = None   # None -> 2 x trace mean
    # Cap on the queue depth the *controller observes* (jobs). Fleet
    # congestion can push the true queue orders of magnitude past
    # anything the slot-env training distribution contains; an
    # unclipped value drives the policy nets far out of their trained
    # input range. Pricing and metrics always use the true queue.
    queue_obs_clip: float = 25.0
    record_epochs: bool = True
    # epoch-flow engine (repro.sim.megafleet): "loop" walks per-device
    # FIFOs in Python (the parity oracle); "vectorized" runs the same
    # recursion as fused (devices,)-array numpy ops, bit-identical
    # under the same seed; "scan" is a jitted jax.lax.scan over epochs
    # (float32, histogram percentiles, stationary worlds only)
    engine: str = "loop"
    # epoch_log bounds for mega-fleet horizons: keep every stride-th
    # epoch row, stop after cap rows (None = unbounded)
    log_stride: int = 1
    log_cap: Optional[int] = None
    # flight recorder (repro.obs.timeline): capture per-epoch fleet
    # aggregates, per-server series and annotation events into
    # SimResult.timeline. Off by default; capture only *reads* state, so
    # results stay bit-identical on vs off (tested on every engine).
    # Rows follow log_stride.
    timeline: bool = False
    # SLO attainment objective the error-budget report (repro.obs.slo)
    # burns against; scenarios override it per preset
    slo_target: float = 0.95
    # scan engine only: shard the device axis over every visible jax
    # device via shard_map (per-epoch psum reductions)
    shard: bool = False


@dataclasses.dataclass
class SimResult:
    summary: Dict
    metrics: FleetMetrics
    selection_hist: np.ndarray            # (M, V, K) requests per action
    epochs: int
    served: int
    duration_s: float
    cross_check: Optional[Dict] = None
    # EpochLog (columnar, dict-row view) — annotated loosely because a
    # plain list of dicts is also accepted by every consumer
    epoch_log: object = dataclasses.field(default_factory=list)
    # drift/adaptation metrics (runs with a schedule or an OnlineConfig):
    # per-regime reward/oracle/regret/recovery + online-learner counters
    adaptation: Optional[Dict] = None
    # cluster runs only: (S,) requests routed to each server
    server_hist: Optional[np.ndarray] = None
    # flight recorder (FleetConfig.timeline=True): repro.obs.timeline
    # Timeline with per-epoch series, annotations and the SLO report
    timeline: object = None

    @property
    def modal_selection(self):
        h = self.selection_hist
        out = {}
        for mi in range(h.shape[0]):
            if h[mi].sum() > 0:
                j, k = np.unravel_index(np.argmax(h[mi]), h[mi].shape)
                out[mi] = (int(j), int(k))
        return out


def _queues_loop(counts, alive, free_at, pr, srv_wait, t_now,
                 slot_seconds, w_rng, metrics, slo_s):
    """One epoch of request flow, per-device loop (engine="loop").

    The parity oracle for ``megafleet.numpy_queues``: same rng stream
    (offsets drawn unconditionally for every device with arrivals — the
    world-rng draw order must not depend on policy-driven state like
    battery death, or two policies under the same seed would unpair
    mid-run), same recursion, same device-order metric recording.
    Mutates ``free_at`` in place; returns slo_hits.
    """
    slo_hits = 0
    for d in range(counts.shape[0]):
        c = int(counts[d])
        if c == 0:
            continue
        offs = t_now + np.sort(w_rng.uniform(0.0, slot_seconds, c))
        if not alive[d]:
            continue                   # dropped — counted by the caller
        s = pr.head_s[d] + pr.tx_s[d]
        idx = np.arange(c)
        start = np.maximum.accumulate(np.maximum(offs, free_at[d])
                                      - s * idx)
        done = start + s * (idx + 1)       # head+tx completion times
        free_at[d] = done[-1]
        lat = done - offs + pr.tail_s[d]
        if pr.offloaded[d]:
            # scalar (classic single server) or (n,) per-device wait at
            # each device's routed server (cluster mode)
            lat = lat + (srv_wait[d] if np.ndim(srv_wait) else srv_wait)
        metrics.record(lat, np.full(c, pr.energy_j[d]), device=d)
        slo_hits += int(np.sum(lat <= slo_s))
    return slo_hits


def simulate(env_cfg: EnvConfig, tables: ProfileTables, policy,
             trace: Trace, *, n_requests: int = 100_000, seed: int = 0,
             fleet: FleetConfig = FleetConfig(),
             backend: Optional[AnalyticalBackend] = None,
             model_ids: Optional[Sequence[int]] = None,
             schedule=None, online=None, autoscaler=None) -> SimResult:
    """Run the fleet until ``n_requests`` have arrived (or max_epochs).

    Cluster mode (``env_cfg.cluster`` set): actions carry a server
    column, the queue/backlog state is per-server, pricing runs against
    each device's *chosen* target, and an optional ``autoscaler``
    (``repro.cluster.AutoscalerConfig``) moves replicas/DVFS per epoch
    on the measured per-server queue depth (replica energy and scale
    events land in the summary). A 1-server pool at uniform topology is
    bit-identical to the classic path (tests/test_cluster.py).

    ``policy`` is a ``repro.policies.Policy`` built against this same
    (env_cfg, tables) world — ``act(state, rng) -> (n, 2) int32``
    ((n, 3) in cluster mode); its
    jitted decide step is cached on the instance, so repeated simulate()
    calls with one policy object (seed sweeps, warm + timed benchmark
    runs) compile once — and re-traced only when online adaptation
    hot-swaps its params.

    ``schedule`` (``repro.online.WorldSchedule``) switches the physics
    regime at its patch epochs; ``online`` (``repro.online.OnlineConfig``)
    enables closed-loop adaptation of a trainable policy. Either one
    turns on per-regime adaptation metrics (``SimResult.adaptation``).

    The trace and the world dynamics draw from independent generators
    spawned off one seed, and the draw order is policy-independent
    (drift patches and trace scaling fire on the epoch clock, never on
    policy-driven state), so two policies simulated with the same seed
    face the *identical* request stream — and the whole run, online
    updates included, is bit-reproducible.
    """
    import jax

    from repro.core import pricing
    from repro.core.controller import measured_state

    if policy.env_cfg is not env_cfg or policy.tables is not tables:
        raise ValueError(
            f"policy {policy.name!r} was built against a different "
            "(env_cfg, tables) world than this simulation — its decisions "
            "would silently score under the wrong physics; build it from "
            "the same objects (run_scenario does this for you)")
    cfg = env_cfg
    n = cfg.n_uavs
    if fleet.engine not in ENGINES:
        raise ValueError(f"unknown fleet engine {fleet.engine!r}; "
                         f"valid engines: {', '.join(ENGINES)}")
    if fleet.shard and fleet.engine != "scan":
        raise ValueError("FleetConfig.shard requires engine='scan' — the "
                         "host engines have no device axis to shard")
    if fleet.engine == "scan":
        from repro.sim import megafleet
        if cfg.cluster is not None:
            raise ValueError(
                "engine='scan' compiles the single-server world into one "
                "jitted lax.scan; cluster pools keep per-server state on "
                "the host — use engine='loop' or 'vectorized'")
        if autoscaler is not None:
            raise ValueError("autoscaler needs a cluster-mode env "
                             "(EnvConfig.cluster)")
        if schedule is not None or online is not None:
            raise ValueError(
                "engine='scan' compiles a stationary world into one "
                "jitted lax.scan; drift schedules and online adaptation "
                "need host round-trips — use engine='vectorized'")
        if backend is not None and type(backend) is not AnalyticalBackend:
            raise ValueError(
                "engine='scan' prices on-device through the jnp pricing "
                "core; execute cross-check backends need the host loop")
        return megafleet.simulate_scan(
            env_cfg, tables, policy, trace, n_requests=n_requests,
            seed=seed, fleet=fleet, model_ids=model_ids)
    from repro.sim import megafleet
    backend = backend if backend is not None else AnalyticalBackend(cfg,
                                                                    tables)

    # -- nonstationarity + online adaptation --------------------------------
    regimes, learner, tracker, np_t = None, None, None, None
    if schedule is not None:
        from repro.sim.backends import ExecuteBackend
        if isinstance(backend, ExecuteBackend):
            raise ValueError("drift schedules price through the analytical "
                             "backend; the execute cross-check assumes one "
                             "stationary table world")
        # compile() caches one AnalyticalBackend per patched regime, so
        # switches inside the epoch loop never rebuild table snapshots
        regimes = schedule.compile(cfg, tables)
    if online is not None or schedule is not None:
        from repro.online.monitor import AdaptationTracker, oracle_reward
        tracker = AdaptationTracker()
        np_t = pricing.numpy_tables(tables)
    if online is not None:
        from repro.online.adapt import OnlineLearner
        learner = OnlineLearner(policy, online, model_ids if model_ids
                                is not None else
                                np.arange(n, dtype=np.int32)
                                % tables.n_models)
    regime_idx = 0
    reg = regimes[0] if regimes else None
    phys = cfg                    # current regime's physics config
    phys_backend = backend
    lp, pw = phys.latency, phys.power

    ss = np.random.SeedSequence(seed)
    s_trace, s_world = ss.spawn(2)
    t_rng = np.random.default_rng(s_trace)
    w_rng = np.random.default_rng(s_world)
    jkey = jax.random.key(seed)

    if model_ids is None:
        model_ids = np.arange(n, dtype=np.int32) % tables.n_models
    model_ids = np.asarray(model_ids, dtype=np.int32)

    # world state (mirrors env_reset means, drawn from the world rng)
    battery = np.full(n, pw.battery_j)
    bw = w_rng.uniform(lp.bw_min_bps, lp.bw_max_bps, n)
    p_tx = w_rng.uniform(pw.p_tx_min, pw.p_tx_max, n)
    activity = np.tile(np.asarray(cfg.activity, dtype=np.float64), (n, 1))
    cluster = cfg.cluster
    pool = None
    srv_hist = None
    if cluster is not None:
        from repro.cluster.pool import ServerPool
        link_scale = np.asarray(cluster.link_scale, dtype=np.float64)
        link_rtt_s = np.asarray(cluster.link_rtt_s, dtype=np.float64)
        if link_scale.shape != (n, cluster.n_servers):
            raise ValueError(
                f"cluster topology is {link_scale.shape} (devices x "
                f"servers) but this fleet is ({n}, {cluster.n_servers})")
        pool = ServerPool(cluster, autoscaler)
        srv_hist = np.zeros(cluster.n_servers, dtype=np.int64)
        side_queue = np.zeros(cluster.n_servers)   # per-server bg jobs
        backlog_s = np.zeros(cluster.n_servers)    # per-server tail work
    else:
        if autoscaler is not None:
            raise ValueError("autoscaler needs a cluster-mode env "
                             "(EnvConfig.cluster)")
        side_queue = 0.0      # env-style background jobs on the server
        backlog_s = 0.0       # fleet-induced tail work awaiting service
    free_at = np.zeros(n)     # absolute time each device drains its FIFO
    obs_rate = np.full(n, trace.mean_rps)
    # load normalization must match what the controller trained on:
    # cfg.peak_rps when the stability-aware env is in play, else a
    # 2x-mean heuristic for paper-faithful (Bernoulli-task) policies.
    # Fixed at the base regime — the controller's sensor calibration
    # does not track drift.
    norm_rps = fleet.load_norm_rps or (
        cfg.peak_rps if cfg.peak_rps > 0 else max(2.0 * trace.mean_rps,
                                                  1e-9))

    stream = trace.stream(t_rng, n, cfg.slot_seconds)
    metrics = FleetMetrics(slo_s=fleet.slo_s)
    tl = None
    if fleet.timeline:
        from repro.obs.timeline import Timeline
        tl = Timeline(slo_s=fleet.slo_s, slot_seconds=cfg.slot_seconds,
                      stride=fleet.log_stride,
                      n_servers=0 if cluster is None else cluster.n_servers,
                      server_names=None if cluster is None
                      else list(cluster.names),
                      engine=fleet.engine)
    hist = np.zeros((tables.n_models, tables.n_versions, tables.n_cuts),
                    dtype=np.int64)
    epoch_log = EpochLog(stride=fleet.log_stride, cap=fleet.log_cap)
    served = 0
    epoch = 0
    t_now = 0.0

    while served < n_requests and epoch < fleet.max_epochs:
      with obs.span("fleet.epoch", epoch=epoch, regime=regime_idx):
        counts = np.asarray(next(stream), dtype=np.int64)

        # -- regime switch (epoch-clock driven, policy-independent) --------
        if regimes is not None:
            r = schedule.regime_at(epoch)
            if r != regime_idx:
                regime_idx, reg = r, regimes[r]
                obs.event("drift.regime_switch", epoch=epoch,
                          regime=regime_idx, name=reg.name)
                if tl is not None:
                    tl.annotate(epoch, "regime_switch",
                                regime=regime_idx, name=reg.name)
                phys = reg.env_cfg
                lp, pw = phys.latency, phys.power
                phys_backend = backend if phys is cfg \
                    else (reg.backend or AnalyticalBackend(phys, tables))
                if reg.battery_scale is not None:
                    battery = battery * reg.battery_scale
                for d in reg.kill_devices:
                    battery[d] = 0.0
                for d in reg.revive_devices:
                    battery[d] = pw.battery_j
                    free_at[d] = t_now
                # world variables snap into the new regime's bounds
                bw = np.clip(bw, lp.bw_min_bps, lp.bw_max_bps)
                p_tx = np.clip(p_tx, pw.p_tx_min, pw.p_tx_max)
            if reg.trace_scale != 1.0:
                from repro.online.drift import scale_counts
                counts = np.asarray(
                    scale_counts(t_rng, counts, reg.trace_scale),
                    dtype=np.int64)

        alive = battery > 0.0
        if not alive.any():
            break
        if pool is None:
            eff = None
            queue_jobs = side_queue + backlog_s / lp.job_service_s
            srv_wait = queue_jobs * lp.job_service_s
            obs_queue = min(queue_jobs, fleet.queue_obs_clip)
        else:
            # live per-server service arrays at the pool's current
            # replica/DVFS state under the current regime's physics
            eff = pool.effective(lp, phys)
            queue_jobs = side_queue + backlog_s / eff.service_s
            srv_wait_s = queue_jobs * eff.service_s       # (S,)
            obs_queue = np.minimum(queue_jobs, fleet.queue_obs_clip)
        load = np.clip(obs_rate / norm_rps, 0.0, 1.0)

        # 1) decide from measured state (obs normalization: base regime)
        with obs.span("fleet.decide", policy=policy.name):
            state = measured_state(
                cfg, tables, battery_j=battery, bandwidth=bw, p_tx=p_tx,
                queue_jobs=obs_queue, load=load,
                model_id=model_ids, activity=activity, t=epoch)
            jkey, k_pol = jax.random.split(jkey)
            actions = np.asarray(policy.jitted()(state, k_pol))

        # 2) price this epoch's actions under the current regime
        if pool is None:
            pr = phys_backend.price(model_ids, actions, bw, p_tx)
        else:
            pr = phys_backend.price(
                model_ids, actions, bw, p_tx, srv_flops=eff.flops,
                srv_service_s=eff.service_s, link_scale=link_scale,
                link_rtt_s=link_rtt_s)
            # each device waits behind its *routed* server's queue
            srv_wait = srv_wait_s[actions[:, 2]]

        # 3) flow requests through device FIFOs (Lindley recursion).
        # Everything outside the queueing recursion itself is shared by
        # both host engines as vectorized expressions — same float
        # summation order, so the engines stay bit-identical.
        sel = alive & (counts > 0)
        dropped = int(counts[~alive].sum())
        if dropped:
            metrics.drop(dropped)
        contrib = np.where(sel & pr.offloaded, counts * pr.tail_s, 0.0)
        if pool is None:
            tail_in_s = float(contrib.sum())
        else:
            # per-server sums via mask-compress (same pairwise summation
            # order as the classic .sum(), so S == 1 stays bit-equal)
            routed = actions[:, 2]
            tail_in_s = np.array([contrib[routed == s].sum()
                                  for s in range(cluster.n_servers)])
        mark = metrics.mark() if tl is not None else None
        with obs.span("fleet.queues", engine=fleet.engine):
            if fleet.engine == "vectorized":
                slo_hits = megafleet.numpy_queues(
                    counts, alive, free_at, pr, srv_wait, t_now,
                    cfg.slot_seconds, w_rng, metrics, fleet.slo_s)
            else:
                slo_hits = _queues_loop(
                    counts, alive, free_at, pr, srv_wait, t_now,
                    cfg.slot_seconds, w_rng, metrics, fleet.slo_s)
        # one scatter-add per epoch instead of a per-device increment
        np.add.at(hist, (model_ids[sel], actions[sel, 0],
                         actions[sel, 1]), counts[sel])
        if pool is not None:
            np.add.at(srv_hist, actions[sel, 2], counts[sel])
        if sel.any():
            d0 = int(np.argmax(sel))
            phys_backend.maybe_execute(int(model_ids[d0]),
                                       int(actions[d0, 0]),
                                       int(actions[d0, 1]))

        # 3b) adaptation metrics + online update: the epoch's slot-level
        # reward (Eq. 8 over the measured view) priced under the CURRENT
        # regime, and the greedy oracle re-solved under the same regime
        if tracker is not None:
          with obs.span("fleet.adapt"):
            vkw = {} if pool is None else dict(
                srv_flops=eff.flops, srv_service_s=eff.service_s,
                link_scale=link_scale, link_rtt_s=link_rtt_s)
            view = pricing.StateView(
                model_id=model_ids, bandwidth=bw, p_tx=p_tx,
                queue=obs_queue, load=load, **vkw)
            br = pricing.price_actions(phys, np_t, view, actions, xp=np)
            wts = phys.weights
            per = (wts.w_acc * br.acc_score + wts.w_lat * br.lat_score
                   + wts.w_energy * br.energy_score
                   + wts.w_stab * br.stab_score)
            amask = alive.astype(np.float64)
            r_epoch = float((per * amask).sum()
                            / max(amask.sum(), 1.0))
            oracle_r = oracle_reward(phys, np_t, view, amask)
            tracker.record(epoch, regime_idx,
                           reg.name if reg is not None else "base",
                           r_epoch, oracle_r)
            if learner is not None:
                on0 = (learner.updates, learner.bursts,
                       learner.monitor.triggers)
                learner.observe_transition(state, actions, per, amask,
                                           regime_idx)
                swapped = learner.step(epoch, r_epoch,
                                       oracle_reward=oracle_r)
                if tl is not None:
                    # counter deltas -> annotation events (the learner
                    # already emitted the matching online.* obs events)
                    if learner.monitor.triggers > on0[2]:
                        tl.annotate(epoch, "drift_trigger")
                    if learner.bursts > on0[1]:
                        tl.annotate(epoch, "burst_start")
                    if swapped:
                        tl.annotate(epoch, "hotswap",
                                    updates=learner.updates)

        # 4) world dynamics (mirrors env_step, on the world rng, under
        #    the current regime's latency/power bounds)
        with obs.span("fleet.dynamics"):
            kin_p = np.asarray(en.kinetic_power(pw, activity[:, 0],
                                                activity[:, 1],
                                                activity[:, 2]))
            drain = np.where(alive, kin_p * cfg.slot_seconds
                             + counts * pr.energy_j, 0.0)
            battery = np.maximum(battery - drain, 0.0)
            bw = np.clip(bw * np.exp(w_rng.normal(size=n) * 0.15),
                         lp.bw_min_bps, lp.bw_max_bps)
            p_tx = np.clip(p_tx + w_rng.normal(size=n) * 0.05,
                           pw.p_tx_min, pw.p_tx_max)
            activity = np.clip(activity + w_rng.normal(size=(n, 3))
                               * cfg.activity_jitter, 0.0, 1.0)
            activity /= np.maximum(activity.sum(-1, keepdims=True), 1.0)
            if pool is None:
                side_queue = max(
                    side_queue
                    + float(w_rng.poisson(phys.queue_arrival_rate))
                    - phys.queue_service_per_slot, 0.0)
                backlog_s = max(backlog_s + tail_in_s - cfg.slot_seconds,
                                0.0)
            else:
                # one scalar Poisson per server, in server order: at
                # S == 1 with unit scale both the lam and the PCG64
                # stream position match the classic draw bitwise
                arr = np.array([float(w_rng.poisson(
                    phys.queue_arrival_rate
                    * cluster.bg_arrival_scale[s]))
                    for s in range(cluster.n_servers)])
                side_queue = np.maximum(side_queue + arr - eff.bg_drain,
                                        0.0)
                backlog_s = np.maximum(
                    backlog_s + tail_in_s
                    - cfg.slot_seconds * eff.cap_scale, 0.0)
                pool.tick(queue_jobs, cfg.slot_seconds)
                for dec in pool.last_decisions:
                    obs.event("autoscale.decision", epoch=epoch, **dec)
                    if tl is not None:
                        tl.annotate(epoch, "autoscale", **dec)
            obs_rate = (1.0 - fleet.ewma) * obs_rate \
                + fleet.ewma * counts / cfg.slot_seconds

        served += int(counts.sum())
        t_now += cfg.slot_seconds
        obs.inc("fleet.arrivals", int(counts.sum()), policy=policy.name)
        if dropped:
            obs.inc("fleet.dropped", dropped, policy=policy.name)
        obs.inc("fleet.slo_hits", slo_hits, policy=policy.name)
        obs.observe("fleet.queue_jobs",
                    queue_jobs if pool is None else float(queue_jobs.sum()),
                    policy=policy.name)
        if tl is not None:
            with obs.span("fleet.timeline"):
                lat_e, en_e = metrics.since(mark)
                tl.append_epoch(
                    epoch=epoch, arrivals=int(counts.sum()),
                    dropped=dropped, slo_hits=slo_hits,
                    alive=int(alive.sum()), regime=regime_idx,
                    queue_jobs=float(np.sum(queue_jobs)),
                    backlog_s=float(np.sum(backlog_s)),
                    lat=lat_e, energy_j=float(en_e.sum()),
                    # per-server series: measured depth at decision time
                    # + the DVFS/replica/power state this epoch ran at
                    # (pool.tick snapshots before the autoscaler moves)
                    srv_queue=None if pool is None else queue_jobs,
                    srv_dvfs=None if pool is None else pool.last_dvfs,
                    srv_replicas=None if pool is None
                    else pool.last_replicas,
                    srv_power_w=None if pool is None
                    else pool.last_power_w)
        if fleet.record_epochs:
            epoch_log.append({
                "epoch": epoch, "arrivals": int(counts.sum()),
                # cluster rows log totals (scalar schema shared with the
                # classic path; per-server depth is in the summary)
                "queue_jobs": float(np.sum(queue_jobs)),
                "backlog_s": float(np.sum(backlog_s)), "dropped": dropped,
                "slo_hits": slo_hits,
                "alive": int(alive.sum()), "regime": regime_idx,
            })
        epoch += 1

    adaptation = None
    if tracker is not None:
        adaptation = tracker.summary(include_series=fleet.record_epochs)
        adaptation["schedule"] = schedule.name if schedule is not None \
            else None
        if learner is not None:
            adaptation["online"] = learner.summary()
            # leave the policy in its serving (greedy) mode
            if hasattr(policy, "set_explore"):
                policy.set_explore(0.0)

    if tl is not None:
        from repro.obs.slo import SLOConfig
        tl.finalize(SLOConfig(target=fleet.slo_target))
    summary = metrics.summary(duration_s=t_now)
    summary["epochs"] = epoch
    summary["requests"] = served
    if pool is not None:
        summary.update(pool.summary())
    return SimResult(summary=summary, metrics=metrics, selection_hist=hist,
                     epochs=epoch, served=served, duration_s=t_now,
                     cross_check=backend.cross_check(), epoch_log=epoch_log,
                     adaptation=adaptation, server_hist=srv_hist,
                     timeline=tl)

"""repro.bench.runner — execute a case matrix under observation.

The runner owns the row surface benchmark functions emit through
(``emit(name, us, derived)`` — the CSV line plus a structured record)
and the per-case obs story: every run installs one live
``obs.Recorder`` (written to ``--trace`` when asked), each case runs
inside a ``bench`` span, and the slice of events the case produced is
folded via ``repro.obs.report`` into a compact per-phase breakdown
(``{phase: {count, total_s}}``) stored on the case's records. That
breakdown is what lets the gate name the regressed *phase*
(``fleet.queues`` vs ``pricing.analytical``), not just the case.

Failure encoding: a case that raises produces a record
``{"name": ..., "error": "Type: msg"}`` with **no timing fields** —
``-1.0`` sentinels would poison baseline statistics, so history and
gate skip error records explicitly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.bench.matrix import Case
from repro.bench.stats import format_sig, summarize

# phases kept per record (largest total_s first)
MAX_PHASES = 16


@dataclass
class Sink:
    """Collects CSV rows + structured records for one run."""
    echo: bool = True
    rows: List[str] = field(default_factory=list)
    records: List[Dict] = field(default_factory=list)

    def row(self, name: str, us_per_call: float, derived: str,
            **extra) -> None:
        """One benchmark result. ``us_per_call`` may be a
        ``stats.Timing`` carrying repeated samples; plain floats are
        single-sample (reported, not gateable). 4 significant digits
        everywhere — fixed one-decimal rounding collapsed
        sub-microsecond cases to 0.0/0.1."""
        line = f"{name},{float(us_per_call):.4g},{derived}"
        self.rows.append(line)
        samples = [float(s) for s in
                   getattr(us_per_call, "samples", (float(us_per_call),))]
        s = summarize(samples)
        rec = {"name": name,
               "us_per_call": format_sig(float(us_per_call)),
               "derived": derived,
               "samples": [format_sig(x) for x in samples],
               "n": s.n,
               "min": format_sig(s.min),
               "median": format_sig(s.median),
               "mean": format_sig(s.mean),
               "std": format_sig(s.std),
               "ci_lo": format_sig(s.ci_lo),
               "ci_hi": format_sig(s.ci_hi)}
        if extra:
            rec["extra"] = {k: format_sig(v) if isinstance(v, float)
                            else v for k, v in extra.items()}
        self.records.append(rec)
        if self.echo:
            print(line, flush=True)

    def error(self, name: str, exc: BaseException) -> None:
        msg = f"{type(exc).__name__}: {exc}".replace(",", ";") \
            .replace("\n", " ")[:500]
        line = f"{name},ERROR,{msg}"
        self.rows.append(line)
        self.records.append({"name": name, "error": msg})
        if self.echo:
            print(line, flush=True)


_SINK: Optional[Sink] = None


def emit(name: str, us_per_call: float, derived: str, **extra) -> None:
    """Module-level row hook for benchmark functions (the runner binds
    the active sink around each run)."""
    if _SINK is None:
        raise RuntimeError("repro.bench.runner.emit called outside a run "
                           "(use runner.run or bind a Sink)")
    _SINK.row(name, us_per_call, derived, **extra)


def fold_phases(events: Sequence[Dict]) -> Dict[str, Dict]:
    """Fold one case's event slice into {phase: {count, total_s}} via
    the canonical obs fold; the wrapping ``bench`` span is dropped and
    phases are capped at MAX_PHASES by total time."""
    from repro.obs.report import fold
    phases = fold(list(events)).get("phases", {})
    phases.pop("bench", None)
    items = sorted(phases.items(), key=lambda kv: -kv[1]["total_s"])
    return {name: {"count": int(p["count"]),
                   "total_s": format_sig(p["total_s"], 6)}
            for name, p in items[:MAX_PHASES]}


@dataclass
class RunResult:
    records: List[Dict]
    rows: List[str]
    errors: int


def run(cases: Sequence[Case], trace: Optional[str] = None,
        meta: Optional[Dict] = None, echo: bool = True,
        header: bool = True,
        overrides: Optional[Dict[str, Dict]] = None) -> RunResult:
    """Execute ``cases`` in order under one live recorder.

    ``overrides`` maps group name -> extra kwargs merged into the
    case's params at call time (the CLI's --agent/--episodes surface).
    Cases always run recorded — even without ``trace`` — so the phase
    breakdown exists and the timing environment is identical between
    gated runs.
    """
    global _SINK
    sink = Sink(echo=echo)
    errors = 0
    if echo and header:
        print("name,us_per_call,derived")
    prev = _SINK
    _SINK = sink
    try:
        with obs.recording(trace, meta=dict(meta or {})) as rec:
            for case in cases:
                kw = dict((overrides or {}).get(case.group, {}))
                s0, i0 = len(rec.events), len(sink.records)
                try:
                    with obs.span("bench", name=case.name):
                        case.run(**kw)
                except Exception as e:   # noqa: BLE001 — report, keep benching
                    sink.error(case.name, e)
                    errors += 1
                phases = fold_phases(rec.events[s0:])
                for r in sink.records[i0:]:
                    r["case"] = case.name
                    if phases and "error" not in r:
                        r["phases"] = phases
    finally:
        _SINK = prev
    return RunResult(records=sink.records, rows=sink.rows, errors=errors)

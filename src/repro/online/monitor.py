"""Drift detection + adaptation metrics for the closed-loop fleet.

``DriftMonitor`` watches the per-epoch reward stream (EWMA residual +
a Page-Hinkley decrease test) and raises a trigger when the world's
physics have drifted away from what the controller was tuned for —
the gate that starts an adaptation burst in ``repro.online.adapt``.

``AdaptationTracker`` scores the whole run against the per-regime
greedy oracle: each epoch it re-solves the (V, K) grid under the
*current* regime's EnvConfig with the numpy pricing core (the identical
``pricing.price_actions`` the jnp ``baselines.greedy_oracle`` scores
with — parity is tested), accumulates per-regime regret, and reports
the recovery time: epochs from each regime boundary until the policy's
smoothed reward is back within 10% of the per-regime oracle's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro import obs
from repro.core import pricing


class PageHinkley:
    """Page-Hinkley test for a downward shift in a signal's mean.

    Maintains m_t = sum(x_i - mean_i + delta); a drop makes m_t fall
    away from its running max M_t, and M_t - m_t > lambda_ triggers.
    ``delta`` absorbs magnitude-delta noise; ``lambda_`` sets the
    detection threshold. Reset after each trigger.
    """

    def __init__(self, delta: float = 0.005, lambda_: float = 0.05,
                 min_samples: int = 8):
        self.delta = float(delta)
        self.lambda_ = float(lambda_)
        self.min_samples = int(min_samples)
        self.reset()

    def reset(self):
        self._n = 0
        self._mean = 0.0
        self._m = 0.0
        self._max = 0.0

    def update(self, x: float) -> bool:
        self._n += 1
        self._mean += (x - self._mean) / self._n
        self._m += x - self._mean + self.delta
        self._max = max(self._max, self._m)
        if self._n >= self.min_samples and \
                (self._max - self._m) > self.lambda_:
            self.reset()
            return True
        return False


class DriftMonitor:
    """EWMA reward residual + Page-Hinkley trigger.

    ``update(reward)`` returns True on the epoch a drift is declared.
    The EWMA tracks the recent operating level; Page-Hinkley runs on the
    raw rewards, so a sharp regime shift triggers within a few epochs
    while slow seasonal wander (diurnal) stays below ``ph_lambda``.
    """

    def __init__(self, ewma: float = 0.2, ph_delta: float = 0.005,
                 ph_lambda: float = 0.05):
        self.alpha = float(ewma)
        self.level: Optional[float] = None
        self.residual: float = 0.0
        self._ph = PageHinkley(delta=ph_delta, lambda_=ph_lambda)
        self.triggers = 0

    def update(self, reward: float) -> bool:
        r = float(reward)
        if self.level is None:
            self.level = r
        self.residual = r - self.level
        self.level += self.alpha * (r - self.level)
        fired = self._ph.update(r)
        if fired:
            self.triggers += 1
            obs.event("drift.trigger", level=self.level,
                      residual=self.residual, n=self.triggers)
        return fired


def oracle_reward(env_cfg, np_tables, view: pricing.StateView,
                  alive: np.ndarray) -> float:
    """Per-epoch greedy-oracle reward re-solved under ``env_cfg``: score
    every (version, cut) pair for every device through the numpy pricing
    core and average each alive device's best weighted score — exactly
    ``baselines.greedy_oracle``'s objective (Eq. 8 argmax), under
    whatever regime config the schedule has installed. Cluster-mode
    envs widen the grid to every (version, cut, server) triple."""
    V, K = np_tables.n_versions, np_tables.n_cuts
    if env_cfg.cluster is None:
        jj, kk = np.meshgrid(np.arange(V), np.arange(K), indexing="ij")
        pairs = np.stack([jj.ravel(), kk.ravel()], -1).astype(np.int32)
    else:
        S = env_cfg.cluster.n_servers
        jj, kk, ss = np.meshgrid(np.arange(V), np.arange(K),
                                 np.arange(S), indexing="ij")
        pairs = np.stack([jj.ravel(), kk.ravel(), ss.ravel()],
                         -1).astype(np.int32)
    n = np.asarray(view.model_id).shape[0]
    actions = np.broadcast_to(pairs[:, None, :],
                              (pairs.shape[0], n, pairs.shape[1]))
    br = pricing.price_actions(env_cfg, np_tables, view, actions, xp=np)
    w = env_cfg.weights
    s = (w.w_acc * br.acc_score + w.w_lat * br.lat_score
         + w.w_energy * br.energy_score + w.w_stab * br.stab_score)
    valid = np_tables.version_valid[np.asarray(view.model_id)[None, :],
                                    pairs[:, 0][:, None]] > 0   # (VK, n)
    s = np.where(valid, s, -np.inf)
    best = s.max(axis=0)                                     # (n,)
    mask = np.asarray(alive, dtype=np.float64)
    denom = max(float(mask.sum()), 1.0)
    return float(np.sum(best * mask) / denom)


@dataclasses.dataclass
class _RegimeStats:
    index: int
    name: str
    start_epoch: int
    rewards: List[float] = dataclasses.field(default_factory=list)
    oracle: List[float] = dataclasses.field(default_factory=list)
    degraded: bool = False
    recovery_epochs: Optional[int] = None


class AdaptationTracker:
    """Per-regime regret + recovery-time accumulator.

    ``record(epoch, regime, reward, oracle_r)`` per epoch; recovery is
    the first epoch offset within a regime at which the EWMA-smoothed
    policy reward is back within ``recover_frac`` (default 10%) of the
    EWMA-smoothed per-regime oracle reward, *after* the regime has
    pushed it outside that band at least once (a regime that never
    degrades the policy reports recovery 0). Both EWMAs restart at each
    boundary, so early-regime transients count against recovery.
    """

    def __init__(self, ewma: float = 0.2, recover_frac: float = 0.1):
        self.alpha = float(ewma)
        self.recover_frac = float(recover_frac)
        self._regimes: List[_RegimeStats] = []
        self._cur: Optional[_RegimeStats] = None
        self._r_ewma = self._o_ewma = None

    def record(self, epoch: int, regime: int, regime_name: str,
               reward: float, oracle_r: float):
        if self._cur is None or self._cur.index != regime:
            self._cur = _RegimeStats(index=regime, name=regime_name,
                                     start_epoch=epoch)
            self._regimes.append(self._cur)
            self._r_ewma = self._o_ewma = None
            obs.event("drift.regime_enter", epoch=epoch, regime=regime,
                      name=regime_name)
        st = self._cur
        st.rewards.append(float(reward))
        st.oracle.append(float(oracle_r))
        if self._r_ewma is None:
            self._r_ewma, self._o_ewma = float(reward), float(oracle_r)
        else:
            self._r_ewma += self.alpha * (float(reward) - self._r_ewma)
            self._o_ewma += self.alpha * (float(oracle_r) - self._o_ewma)
        if st.recovery_epochs is None:
            gap = self._o_ewma - self._r_ewma
            tol = self.recover_frac * max(abs(self._o_ewma), 1e-9)
            if gap > tol:
                st.degraded = True
            elif st.degraded:
                st.recovery_epochs = epoch - st.start_epoch

    def summary(self, include_series: bool = False) -> Dict:
        regimes = []
        for st in self._regimes:
            r, o = np.asarray(st.rewards), np.asarray(st.oracle)
            entry = {
                "regime": st.index, "name": st.name,
                "start_epoch": st.start_epoch, "epochs": int(r.size),
                "mean_reward": float(r.mean()) if r.size else 0.0,
                "oracle_reward": float(o.mean()) if o.size else 0.0,
                "regret": float((o - r).mean()) if r.size else 0.0,
                # 0 = the regime never degraded the policy past the
                # tolerance band; None = degraded and never recovered
                "recovery_epochs": st.recovery_epochs
                if (st.recovery_epochs is not None or st.degraded)
                else 0,
            }
            if include_series:
                entry["rewards"] = [float(x) for x in st.rewards]
                entry["oracle"] = [float(x) for x in st.oracle]
            regimes.append(entry)
        all_r = np.concatenate([np.asarray(s.rewards)
                                for s in self._regimes]) \
            if self._regimes else np.zeros(0)
        all_o = np.concatenate([np.asarray(s.oracle)
                                for s in self._regimes]) \
            if self._regimes else np.zeros(0)
        return {
            "regimes": regimes,
            "mean_reward": float(all_r.mean()) if all_r.size else 0.0,
            "oracle_reward": float(all_o.mean()) if all_o.size else 0.0,
            "regret": float((all_o - all_r).mean()) if all_r.size else 0.0,
        }

"""Checkpointing: flattened-pytree .npz files (no orbax in this env).

Path-keyed so restores are structure-checked; works for model params,
optimizer state, and the A2C agent alike. Sharded arrays are gathered to
host before save (fine at the sizes we train here; a production TPU run
would write per-shard files — noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_META_KEY = "__meta__"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _restore_into(data, like: Any) -> Any:
    """Rebuild ``like``'s pytree from a loaded npz mapping, with the
    structure/shape checks shared by step checkpoints and single-file
    artifacts. Keys beyond the tree (e.g. ``__meta__``) are ignored only
    when explicitly reserved."""
    flat_like = _flatten(like)
    files = set(data.files) - {_META_KEY}
    missing = set(flat_like) - files
    extra = files - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    treedef = leaves_with_path[1]
    out = []
    for path_k, leaf in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def save_tree(path: str, tree: Any, meta: Optional[Dict] = None) -> str:
    """Save one pytree as a single-file .npz artifact (atomic rename).

    Unlike ``save_checkpoint`` there is no step numbering — this is the
    format for reusable artifacts (e.g. a trained controller policy that
    ``scripts/simulate.py --save-policy`` writes and ``--load-policy``
    reloads without retraining). ``meta`` is a small JSON-able dict
    stored alongside the arrays under a reserved key."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    flat = _flatten(tree)
    if meta is not None:
        flat[_META_KEY] = np.frombuffer(
            json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def load_tree(path: str, like: Any) -> Tuple[Any, Dict]:
    """Load a ``save_tree`` artifact into ``like``'s structure.

    Returns ``(tree, meta)``; restores are structure- and shape-checked
    against ``like`` so a policy artifact can only load into an agent of
    the same architecture (same env dims, same net widths)."""
    data = np.load(path)
    meta: Dict = {}
    if _META_KEY in data.files:
        meta = json.loads(bytes(data[_META_KEY]).decode("utf-8"))
    return _restore_into(data, like), meta


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    name: str = "state") -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"{name}_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str, name: str = "state") -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1))
             for f in os.listdir(ckpt_dir)
             if (m := re.match(rf"{name}_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       name: str = "state") -> Any:
    path = os.path.join(ckpt_dir, f"{name}_{step:08d}.npz")
    return _restore_into(np.load(path), like)

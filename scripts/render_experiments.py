"""Render EXPERIMENTS.md from scenario runs — any registered preset,
stationary or nonstationary, through the one experiment API
(``repro.scenarios.run_scenario``).

    # run presets and render their comparison tables
    PYTHONPATH=src python scripts/render_experiments.py \
        --scenarios paper-mmpp-burst,flash-crowd

    # cheaper budgets for a quick draft
    PYTHONPATH=src python scripts/render_experiments.py --all \
        --requests 4000 --episodes 60 --seeds 0

    # render previously saved reports (scripts/simulate.py --json out)
    PYTHONPATH=src python scripts/render_experiments.py \
        --from-json results/brownout.json results/crowd.json

The historical version of this script hand-plumbed one hard-coded
dry-run results file; it now renders any ``ComparisonReport`` — the
same JSON the simulate CLI writes — including the per-regime
adaptation metrics (regret vs the re-solved greedy oracle, recovery
time) that nonstationary presets report.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.scenarios import get_scenario, run_scenario, scenario_names

_METRIC_COLS = (
    ("requests", "count", "{:.0f}"),
    ("p50 (s)", "p50", "{:.3f}"),
    ("p95 (s)", "p95", "{:.2f}"),
    ("p99 (s)", "p99", "{:.2f}"),
    ("SLO att.", "slo_attainment", "{:.3f}"),
    ("goodput (req/s)", "goodput", "{:.1f}"),
    ("energy/req (J)", "energy_per_request_j", "{:.3f}"),
    ("dropped", "dropped", "{:.0f}"),
)


def _md_table(header, rows):
    out = ["| " + " | ".join(header) + " |",
           "|" + "|".join("---" for _ in header) + "|"]
    out += ["| " + " | ".join(r) + " |" for r in rows]
    return "\n".join(out)


def render_report(data: dict) -> str:
    """One markdown section from a ComparisonReport.to_json() dict."""
    name = data["scenario"]
    lines = [f"## {name}", ""]
    try:
        lines += [get_scenario(name).description, ""]
    except KeyError:
        pass
    meta = (f"trace `{data['trace']}` · seeds {data['seeds']} · "
            f"{data['n_requests']} requests/seed")
    if data.get("schedule"):
        meta += f" · drift `{data['schedule']}`"
    lines += [meta, ""]

    rows = []
    for pname, entry in data["policies"].items():
        m = entry["mean"]
        rows.append([f"`{pname}`"]
                    + [fmt.format(m[key]) for _, key, fmt in _METRIC_COLS])
    lines.append(_md_table(["policy"] + [h for h, _, _ in _METRIC_COLS],
                           rows))
    lines.append("")

    adapt = {p: e["adaptation"] for p, e in data["policies"].items()
             if e.get("adaptation")}
    if adapt:
        lines += ["Per-regime adaptation metrics (reward vs the greedy "
                  "oracle re-solved under each regime's physics; "
                  "recovery = epochs until back within 10% of it):", ""]
        arows = []
        for pname, a in adapt.items():
            for reg in a["regimes"]:
                rec = reg["recovery_epochs"]
                arows.append([
                    f"`{pname}`", f"{reg['regime']} ({reg['name']})",
                    f"{reg['mean_reward']:+.3f}",
                    f"{reg['oracle_reward']:+.3f}",
                    f"{reg['regret']:.3f}",
                    "never" if rec is None else f"{rec:.0f}",
                ])
            onl = a.get("online")
            if onl:
                arows.append([f"`{pname}`", "(online totals)",
                              f"{a['mean_reward']:+.3f}", "",
                              f"{a['regret']:.3f}",
                              f"{onl['updates']:.0f} updates / "
                              f"{onl['bursts']:.0f} bursts"])
        lines.append(_md_table(
            ["policy", "regime", "reward", "oracle", "regret",
             "recovery (epochs)"], arows))
        lines.append("")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scenarios",
                    help="comma-separated preset names to run")
    ap.add_argument("--all", action="store_true",
                    help="run every registered preset (execute presets "
                    "skipped)")
    ap.add_argument("--from-json", nargs="+", metavar="PATH",
                    help="render saved ComparisonReport JSONs instead of "
                    "running")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seed override")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()

    sections = []
    if args.from_json:
        for path in args.from_json:
            with open(path) as f:
                sections.append(render_report(json.load(f)))
    else:
        if args.scenarios:
            names = args.scenarios.split(",")
        elif args.all:
            names = [n for n in scenario_names()
                     if not get_scenario(n).execute]
        else:
            ap.error("pick --scenarios, --all, or --from-json")
        seeds = tuple(int(s) for s in args.seeds.split(",")) \
            if args.seeds else None
        for name in names:
            sc = get_scenario(name)      # KeyError lists valid names
            rep = run_scenario(sc, n_requests=args.requests,
                               episodes=args.episodes, seeds=seeds,
                               verbose=True)
            sections.append(render_report(rep.to_json()))

    body = "\n".join(["# Experiments",
                      "",
                      "Rendered by `scripts/render_experiments.py` from "
                      "`repro.scenarios` ComparisonReports.",
                      ""] + sections)
    with open(args.out, "w") as f:
        f.write(body)
    print(f"rendered {args.out} ({len(sections)} scenario sections, "
          f"{len(body)} chars)")


if __name__ == "__main__":
    main()

"""Latency parameters for the end-to-end model (paper Eqs. 4-5).

The formulas themselves live in ``repro.core.pricing`` — the single
backend-polymorphic cost core — and are re-exported here for API
compatibility. Throughputs are effective (not peak) FLOP/s for the
TX2 / PowerEdge regime.
"""
from __future__ import annotations

import dataclasses

from repro.core.pricing import (local_time, remote_time, total_time,
                                transmit_time)

__all__ = ["LatencyParams", "local_time", "transmit_time", "remote_time",
           "total_time"]


@dataclasses.dataclass(frozen=True)
class LatencyParams:
    device_flops: float = 0.25e12     # Jetson TX2 effective
    server_flops: float = 0.8e12      # 16-core 3.2 GHz PowerEdge effective
    job_service_s: float = 0.05       # mean service time of a queued job
    bw_min_bps: float = 16e6          # 2 MB/s
    bw_max_bps: float = 320e6         # 40 MB/s

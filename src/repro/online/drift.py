"""Regime-switching world model: timed EnvPatches over a running fleet.

EdgeRL's premise is inference tuning in *ad-hoc* edge environments, yet
a stationary EnvConfig can never exercise the paper's core claim of
re-aligning (version, cut) decisions as conditions change. A
``WorldSchedule`` is a sequence of timed ``EnvPatch``es that mutate
EnvConfig fields mid-run — link-bandwidth brownout, battery decay/cliff,
server slowdown, flash-crowd rate shifts, device churn — and
``compile()`` resolves them into per-regime ``Regime`` records the fleet
loop switches between at epoch boundaries.

One patch, three consistent views of the shifted physics:

- the **jnp env**: ``Regime.env_cfg`` is a full EnvConfig, so training
  rollouts, ``env.action_costs`` and ``baselines.greedy_oracle`` price
  the regime exactly;
- the **numpy pricing snapshot**: the fleet loop rebuilds its
  ``AnalyticalBackend`` (which re-snapshots via ``pricing.numpy_tables``)
  from the same ``Regime.env_cfg``, so both sim backends price the same
  shifted physics (``tests/test_online.py`` asserts numpy==jnp parity
  per regime);
- the **trace stream**: ``Regime.trace_scale`` thins (binomial) or
  augments (conditional Poisson) the per-epoch arrival counts through
  ``scale_counts`` — drawn from the fleet's trace rng in a
  policy-independent order, so paired seeds stay paired under drift.

Observation semantics: the controller's *sensors* keep the base-regime
normalization constants (a deployed policy does not learn that the
world's config file changed); only the physics — pricing, reward,
dynamics — follow the patched config. That split is what makes drift
detectable from the reward stream (``repro.online.monitor``) rather
than trivially visible in the features.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class EnvPatch:
    """One timed mutation of the operating regime.

    ``env`` sets EnvConfig fields to absolute values and ``env_scale``
    multiplies them; keys are dotted paths into the nested frozen
    dataclasses (``"latency.bw_max_bps"``, ``"power.p_compute"``,
    ``"peak_rps"``). ``reset=True`` starts from the *base* config again
    before applying this patch's own updates (regime recovery).

    World-state side effects applied once at the boundary:
    ``battery_scale`` multiplies every device's remaining charge (decay
    cliff), ``kill_devices`` zeroes the listed batteries (churn out),
    ``revive_devices`` restores listed devices to a full battery (churn
    in). ``trace_scale`` multiplies the offered arrival rate from this
    patch onward (``None`` inherits the previous regime's scale).
    """
    at_epoch: int
    name: str = ""
    env: Mapping[str, float] = dataclasses.field(default_factory=dict)
    env_scale: Mapping[str, float] = dataclasses.field(default_factory=dict)
    reset: bool = False
    trace_scale: Optional[float] = None
    battery_scale: Optional[float] = None
    kill_devices: Tuple[int, ...] = ()
    revive_devices: Tuple[int, ...] = ()


def _patch_path(cfg, path: str, value):
    """Functional set of one dotted field path on nested frozen
    dataclasses; unknown segments fail loudly (a silently ignored patch
    would simulate the wrong physics)."""
    head, _, rest = path.partition(".")
    if not dataclasses.is_dataclass(cfg) or not any(
            f.name == head for f in dataclasses.fields(cfg)):
        valid = [f.name for f in dataclasses.fields(cfg)] \
            if dataclasses.is_dataclass(cfg) else []
        raise KeyError(f"EnvPatch path {path!r}: no field {head!r} on "
                       f"{type(cfg).__name__} (has {sorted(valid)})")
    cur = getattr(cfg, head)
    new = _patch_path(cur, rest, value) if rest else value
    return dataclasses.replace(cfg, **{head: new})


def apply_env_patch(cfg, patch: EnvPatch):
    """Apply ``patch.env`` / ``patch.env_scale`` to an EnvConfig."""
    for path, value in patch.env.items():
        cfg = _patch_path(cfg, path, value)
    for path, factor in patch.env_scale.items():
        cur = cfg
        for seg in path.split("."):
            cur = getattr(cur, seg)
        cfg = _patch_path(cfg, path, cur * factor)
    return cfg


@dataclasses.dataclass(frozen=True)
class Regime:
    """One resolved operating regime: [start_epoch, next boundary)."""
    index: int
    start_epoch: int
    name: str
    env_cfg: object
    trace_scale: float = 1.0
    battery_scale: Optional[float] = None     # applied once on entry
    kill_devices: Tuple[int, ...] = ()
    revive_devices: Tuple[int, ...] = ()
    # pricing backend cached at compile() time for patched-config
    # regimes (None when env_cfg is the caller's base config — the
    # fleet then reuses its own backend). Excluded from equality/repr:
    # it is a derived cache, not part of the regime's identity.
    backend: object = dataclasses.field(default=None, compare=False,
                                        repr=False)


@dataclasses.dataclass(frozen=True)
class WorldSchedule:
    """Ordered timed patches; epoch 0 is the unpatched base regime."""
    patches: Tuple[EnvPatch, ...]
    name: str = "schedule"

    def __post_init__(self):
        object.__setattr__(self, "patches", tuple(self.patches))
        epochs = [p.at_epoch for p in self.patches]
        if any(e <= 0 for e in epochs):
            raise ValueError("EnvPatch.at_epoch must be > 0 (epoch 0 is "
                             "the base regime)")
        if epochs != sorted(set(epochs)):
            raise ValueError(f"patch epochs must be strictly increasing; "
                             f"got {epochs}")

    @property
    def n_regimes(self) -> int:
        return len(self.patches) + 1

    @property
    def boundaries(self) -> Tuple[int, ...]:
        return tuple(p.at_epoch for p in self.patches)

    def regime_at(self, epoch: int) -> int:
        i = 0
        for p in self.patches:
            if epoch >= p.at_epoch:
                i += 1
        return i

    def compile(self, base_cfg, tables=None) -> List[Regime]:
        """Resolve patches cumulatively into per-regime records. Each
        patch applies on top of the previous regime's config (or the
        base config under ``reset=True``); ``trace_scale`` inherits.

        With ``tables``, each patched-config regime also carries a
        ready ``AnalyticalBackend`` (one numpy table snapshot per
        regime, built here once) so the fleet's regime switches inside
        the epoch loop never rebuild pricing state. Regimes whose
        config *is* ``base_cfg`` (pure resets) leave ``backend=None``
        and price through the fleet's own backend."""
        def make_backend(cfg):
            if tables is None or cfg is base_cfg:
                return None
            from repro.sim.backends import AnalyticalBackend
            return AnalyticalBackend(cfg, tables)

        regimes = [Regime(index=0, start_epoch=0, name="base",
                          env_cfg=base_cfg)]
        cfg, scale = base_cfg, 1.0
        for i, p in enumerate(self.patches):
            if p.reset:
                cfg, scale = base_cfg, 1.0
            cfg = apply_env_patch(cfg, p)
            if p.trace_scale is not None:
                scale = float(p.trace_scale)
            regimes.append(Regime(
                index=i + 1, start_epoch=p.at_epoch,
                name=p.name or f"regime{i + 1}", env_cfg=cfg,
                trace_scale=scale, battery_scale=p.battery_scale,
                kill_devices=tuple(p.kill_devices),
                revive_devices=tuple(p.revive_devices),
                backend=make_backend(cfg)))
        return regimes


def scale_counts(rng: np.random.Generator, counts: np.ndarray,
                 scale: float) -> np.ndarray:
    """Scale a per-device arrival-count draw to ``scale``x the offered
    rate: binomial thinning for scale < 1 (exact for Poisson arrivals),
    a conditional-Poisson augmentation for scale > 1 (mean lambda*scale
    given the base draw; slightly over-dispersed, which only makes a
    flash crowd burstier). Draws come from the caller's trace rng in an
    epoch-indexed, policy-independent order, so two policies under one
    seed still face the identical shifted request stream."""
    if scale == 1.0:
        return counts
    if scale < 0:
        raise ValueError(f"trace_scale must be >= 0, got {scale}")
    if scale < 1.0:
        return rng.binomial(counts, scale)
    return counts + rng.poisson(counts * (scale - 1.0))


# --------------------------------------------------------------------------
# named schedule factories (the nonstationary preset worlds)
# --------------------------------------------------------------------------

def link_brownout(onset: int = 60, recover: int = 220,
                  bw_max_bps: float = 6e6, bw_min_bps: float = 3e6,
                  server_scale: float = 0.1) -> WorldSchedule:
    """Edge-infrastructure brownout: the uplink collapses below the
    design-time floor and the edge server's effective share degrades
    with it (congested backhaul), then the world recovers."""
    patches = [EnvPatch(
        at_epoch=onset, name="brownout",
        env={"latency.bw_max_bps": bw_max_bps,
             "latency.bw_min_bps": bw_min_bps},
        env_scale={"latency.server_flops": server_scale,
                   "queue_service_per_slot": server_scale})]
    if recover:
        patches.append(EnvPatch(at_epoch=recover, name="recovered",
                                reset=True))
    return WorldSchedule(tuple(patches), name="link-brownout")


def battery_cliff(at: int = 70, battery_scale: float = 0.25,
                  compute_scale: float = 3.0,
                  recover: int = 0) -> WorldSchedule:
    """Battery decay cliff: remaining charge drops to ``battery_scale``
    of nominal at once and degraded cells draw ``compute_scale``x the
    compute power thereafter."""
    patches = [EnvPatch(at_epoch=at, name="cliff",
                        env_scale={"power.p_compute": compute_scale},
                        battery_scale=battery_scale)]
    if recover:
        patches.append(EnvPatch(at_epoch=recover, name="recovered",
                                reset=True))
    return WorldSchedule(tuple(patches), name="battery-cliff")


def flash_crowd(onset: int = 60, relax: int = 220, scale: float = 4.0,
                peak_rps: Optional[float] = None,
                queue_scale: float = 6.0) -> WorldSchedule:
    """Flash crowd: offered arrival rate jumps to ``scale``x and the
    shared server's background workload surges with it. ``peak_rps``
    re-calibrates the stability term's saturation rate for the crowd
    regime (the operator knows the crowd is on)."""
    env = {"peak_rps": peak_rps} if peak_rps is not None else {}
    patches = [EnvPatch(at_epoch=onset, name="crowd", env=env,
                        env_scale={"queue_arrival_rate": queue_scale},
                        trace_scale=scale)]
    if relax:
        patches.append(EnvPatch(at_epoch=relax, name="relaxed",
                                reset=True))
    return WorldSchedule(tuple(patches), name="flash-crowd")


def device_churn(leave_at: int = 60, rejoin_at: int = 160,
                 leave: Tuple[int, ...] = (0, 1)) -> WorldSchedule:
    """Device churn: the listed devices drop out of the fleet (battery
    dead, requests dropped) and later rejoin with fresh batteries."""
    patches = [EnvPatch(at_epoch=leave_at, name="churn-out",
                        kill_devices=tuple(leave))]
    if rejoin_at:
        patches.append(EnvPatch(at_epoch=rejoin_at, name="churn-in",
                                revive_devices=tuple(leave)))
    return WorldSchedule(tuple(patches), name="device-churn")


SCHEDULES: Dict[str, object] = {
    "link-brownout": link_brownout,
    "battery-cliff": battery_cliff,
    "flash-crowd": flash_crowd,
    "device-churn": device_churn,
}


def schedule_names() -> Tuple[str, ...]:
    return tuple(sorted(SCHEDULES))


def get_schedule(name: str, **kw) -> WorldSchedule:
    """Canonical-name lookup; a miss names every valid schedule (same
    convention as the policy/scenario/trace registries)."""
    if name not in SCHEDULES:
        raise KeyError(f"unknown drift schedule {name!r}; valid names: "
                       f"{', '.join(schedule_names())}")
    return SCHEDULES[name](**kw)

"""Split execution (head/tail) correctness + serving engine behaviour +
hillclimb-variant numerical parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.partition import cut_points, split_forward
from repro.models import decode_step, forward_logits, init, prefill
from repro.serving import ServeConfig, ServingEngine, SplitServingEngine
from tests.conftest import make_batch

ARCHS_SPLIT = ["qwen2-0.5b", "falcon-mamba-7b", "recurrentgemma-2b",
               "deepseek-v2-lite-16b", "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", ARCHS_SPLIT)
def test_split_forward_equals_full(arch):
    cfg = get_config(arch).reduced()
    params = init(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    del batch["targets"]
    full = forward_logits(cfg, params, batch)
    for cut in cut_points(cfg):
        got = split_forward(cfg, params, batch, cut)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)


def test_serving_engine_generates():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=8))
    batch = make_batch(cfg, B=3, S=12)
    del batch["targets"]
    toks = eng.generate(batch)
    assert toks.shape == (3, 8)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab_size


def test_serving_greedy_is_deterministic():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=6))
    batch = make_batch(cfg, B=2, S=10)
    del batch["targets"]
    t1 = np.asarray(eng.generate(batch))
    t2 = np.asarray(eng.generate(batch))
    np.testing.assert_array_equal(t1, t2)


def test_split_serving_activation_bytes():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    eng = SplitServingEngine(cfg, params)
    batch = make_batch(cfg)
    del batch["targets"]
    logits, nbytes = eng.infer(batch, cut_points(cfg)[0])
    B, S = batch["tokens"].shape
    assert nbytes == B * S * cfg.d_model * 4   # f32 activation
    assert logits.shape == (B, S, cfg.vocab_size)


def test_mla_absorb_decode_parity():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    params = init(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    del batch["targets"]
    _, cache = prefill(cfg, params, batch)
    tok = jnp.asarray([1, 2], jnp.int32)
    l_base, _ = decode_step(cfg, params, cache, tok, jnp.int32(16))
    l_abs, _ = decode_step(cfg.with_overrides(mla_absorb=True), params,
                           cache, tok, jnp.int32(16))
    np.testing.assert_allclose(np.asarray(l_abs), np.asarray(l_base),
                               rtol=2e-4, atol=2e-4)


def test_moe_gather_parity():
    cfg = get_config("mixtral-8x22b").reduced()
    params = init(cfg, jax.random.key(1))
    batch = make_batch(cfg)
    del batch["targets"]
    f1 = forward_logits(cfg, params, batch)
    f2 = forward_logits(cfg.with_overrides(moe_impl="gather"), params, batch)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1),
                               rtol=2e-4, atol=2e-4)


def test_attention_chunk_sizes_do_not_change_results():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    B, S = 1, 4096    # force the chunked path (> threshold)
    toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 13) % cfg.vocab_size
    f1 = forward_logits(cfg, params, {"tokens": toks})
    f2 = forward_logits(cfg.with_overrides(attn_q_chunk=2048,
                                           attn_kv_chunk=4096),
                        params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1),
                               rtol=2e-4, atol=2e-4)

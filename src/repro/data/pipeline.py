"""Deterministic synthetic data pipeline.

No datasets ship in this container, so the pipeline synthesizes token
streams with a seeded Zipf-ish unigram + Markov bigram mixture — enough
structure that a language model's loss demonstrably *decreases* (used by
the end-to-end training example and tests), while staying fully
deterministic and offline.

Produces the same batch dict the models consume ({tokens, targets,
[media|enc_frames]}), handles packing into fixed seq_len, and shards
host arrays onto a mesh with jax.device_put.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    vocab_zipf_a: float = 1.2
    markov_states: int = 64    # bigram structure the model can learn


class SyntheticLMDataset:
    """Seeded infinite stream of (tokens, targets) with learnable structure."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        rng = np.random.default_rng(data.seed)
        V = cfg.vocab_size
        m = min(data.markov_states, V)
        # sparse bigram transition table over m "hub" tokens
        self._hubs = rng.choice(V, size=m, replace=False)
        self._next = rng.integers(0, m, size=(m, 4))
        ranks = np.arange(1, V + 1, dtype=np.float64)
        probs = ranks ** (-data.vocab_zipf_a)
        self._probs = probs / probs.sum()

    def _sample_stream(self, rng: np.random.Generator, n: int) -> np.ndarray:
        m = len(self._hubs)
        out = np.empty(n, dtype=np.int64)
        state = rng.integers(0, m)
        for i in range(n):
            if rng.random() < 0.75:
                state = self._next[state, rng.integers(0, 4)]
                out[i] = self._hubs[state]
            else:
                out[i] = rng.choice(len(self._probs), p=self._probs)
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        d = self.data
        rng = np.random.default_rng((self.data.seed, step))
        toks = np.stack([self._sample_stream(rng, d.seq_len + 1)
                         for _ in range(d.batch_size)])
        b = {"tokens": toks[:, :-1].astype(np.int32),
             "targets": toks[:, 1:].astype(np.int32)}
        if self.cfg.cross_attn_every:
            b["media"] = rng.standard_normal(
                (d.batch_size, self.cfg.n_media_tokens,
                 self.cfg.d_model)).astype(np.float32)
        if self.cfg.enc_dec:
            b["enc_frames"] = rng.standard_normal(
                (d.batch_size, self.cfg.encoder_seq,
                 self.cfg.d_model)).astype(np.float32)
        return b


def shard_batch(batch: Dict[str, np.ndarray], shardings=None) -> Dict:
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(jnp.asarray(v), shardings[k])
            for k, v in batch.items()}


def make_train_iterator(cfg: ModelConfig, data: DataConfig,
                        shardings=None) -> Iterator[Dict]:
    ds = SyntheticLMDataset(cfg, data)
    step = 0
    while True:
        yield shard_batch(ds.batch(step), shardings)
        step += 1

"""Analytic per-block FLOPs for the assigned transformer architectures.

Used by (a) EdgeRL transformer profiles (core/profiles.py) and
(b) MODEL_FLOPS in the roofline report (analysis/roofline.py).
"""
from __future__ import annotations

from typing import List

from repro.configs.base import ModelConfig


def _attn_flops(cfg: ModelConfig, seq_ctx: int) -> float:
    d, Dh = cfg.d_model, cfg.resolved_head_dim
    H, HK = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        proj = 2 * d * H * qd                       # q
        proj += 2 * d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        proj += 2 * cfg.kv_lora_rank * H * (cfg.qk_nope_head_dim
                                            + cfg.v_head_dim)
        proj += 2 * H * cfg.v_head_dim * d          # out
        score = 2 * H * qd * seq_ctx + 2 * H * cfg.v_head_dim * seq_ctx
    else:
        proj = 2 * d * H * Dh + 2 * 2 * d * HK * Dh + 2 * H * Dh * d
        score = 2 * H * Dh * seq_ctx * 2
    return proj + score


def _mlp_flops(cfg: ModelConfig, d_ff: int) -> float:
    mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    return 2.0 * mats * cfg.d_model * d_ff


def _moe_flops(cfg: ModelConfig) -> float:
    active = cfg.top_k + cfg.n_shared_experts
    return 2.0 * 3 * cfg.d_model * cfg.moe_d_ff * active \
        + 2.0 * cfg.d_model * cfg.n_experts          # router


def _ssm_flops(cfg: ModelConfig) -> float:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    f = 2 * d * 2 * di                 # in_proj
    f += cfg.ssm_conv * di             # conv
    f += 2 * di * (r + 2 * n)          # x_proj
    f += 2 * r * di                    # dt_proj
    f += 6 * di * n                    # scan update + output
    f += 2 * di * d                    # out_proj
    return float(f)


def _rec_flops(cfg: ModelConfig) -> float:
    d, w = cfg.d_model, cfg.resolved_lru_width
    f = 2 * d * w * 2                  # two branches
    f += cfg.ssm_conv * w
    f += 2 * w * w * 2                 # gates
    f += 8 * w                         # recurrence
    f += 2 * w * d                     # out
    return float(f)


def block_flops_per_token(cfg: ModelConfig, seq_ctx: int = None, *,
                          weights_only: bool = False) -> List[float]:
    """FLOPs per token per block, in layer order.

    ``weights_only=True`` zeroes every attention-score context (self,
    cross, media), leaving just the weight-matmul terms — so dividing by
    2 gives a per-block *parameter count* that is independent of the
    profiling shape (used for weight-shipping bytes)."""
    ctx = 0 if weights_only else (seq_ctx if seq_ctx is not None else 2048)
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    enc_ctx = 0 if weights_only else cfg.encoder_seq
    media_ctx = 0 if weights_only else cfg.n_media_tokens
    out = []
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind == "ssm":
            out.append(_ssm_flops(cfg))
        elif kind == "rec":
            out.append(_rec_flops(cfg) + _mlp_flops(cfg, cfg.d_ff))
        elif kind == "xattn":
            out.append(_attn_flops(cfg, media_ctx)
                       + _mlp_flops(cfg, cfg.d_ff))
        elif cfg.enc_dec:
            # whisper decoder block: self-attn + cross-attn(enc) + mlp
            out.append(_attn_flops(cfg, ctx)
                       + _attn_flops(cfg, enc_ctx)
                       + _mlp_flops(cfg, cfg.d_ff))
        else:
            lctx = min(ctx, cfg.local_window) if cfg.block_pattern else ctx
            mlp = (_moe_flops(cfg) if (cfg.moe and i >= cfg.first_dense_layers)
                   else _mlp_flops(cfg, cfg.d_ff if cfg.d_ff else 4 * cfg.d_model))
            out.append(_attn_flops(cfg, lctx) + mlp)
    return out


def block_params(cfg: ModelConfig) -> List[float]:
    """Per-block parameter-count estimate: weight-matmul FLOPs / 2 with
    all attention contexts zeroed (shape-independent, unlike raw FLOPs).

    MoE layers are corrected to count ALL experts — FLOPs only touch the
    routed top-k, but shipping/storing a layer moves every expert."""
    out = [f / 2.0 for f in block_flops_per_token(cfg, weights_only=True)]
    if cfg.moe:
        inactive = 3.0 * cfg.d_model * cfg.moe_d_ff \
            * (cfg.n_experts - cfg.top_k)
        for i, kind in enumerate(cfg.layer_kinds()):
            if kind == "attn" and i >= cfg.first_dense_layers:
                out[i] += inactive
    return out


def _attn_proj_flops(cfg: ModelConfig) -> float:
    """Projection-only attention FLOPs that route through layers.dense.

    For MLA only wq/wo are dense-consumed (w_dkv/w_uk/w_uv are
    reshaped/einsum'd and stay full precision under quantization)."""
    d, Dh = cfg.d_model, cfg.resolved_head_dim
    H, HK = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        return 2.0 * d * H * qd + 2.0 * H * cfg.v_head_dim * d
    return 2.0 * d * H * Dh + 2.0 * 2 * d * HK * Dh + 2.0 * H * Dh * d


def block_dense_flops(cfg: ModelConfig) -> List[float]:
    """Per-block FLOPs of the dense-consumed projections — the share that
    actually executes with QTensor weights under a quantized version
    (mirrors quant.quantize.DENSE_WEIGHTS + the moe-subtree exclusion).
    Attention scores, MoE experts and SSM/LRU mixers are NOT in this
    share; version FLOP scaling must only touch these terms."""
    out = []
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind == "ssm":
            out.append(0.0)                       # mixer is einsum-consumed
        elif kind == "rec":
            out.append(_mlp_flops(cfg, cfg.d_ff))  # mixer excluded, MLP in
        elif kind == "xattn":
            out.append(_attn_proj_flops(cfg) + _mlp_flops(cfg, cfg.d_ff))
        elif cfg.enc_dec:
            # self-attn + cross-attn projections + mlp
            out.append(2.0 * _attn_proj_flops(cfg)
                       + _mlp_flops(cfg, cfg.d_ff))
        else:
            moe_layer = cfg.moe and i >= cfg.first_dense_layers
            mlp = 0.0 if moe_layer else _mlp_flops(
                cfg, cfg.d_ff if cfg.d_ff else 4 * cfg.d_model)
            out.append(_attn_proj_flops(cfg) + mlp)
    return out


def active_params(cfg: ModelConfig) -> float:
    """Parameter count with only active MoE experts (for 6*N_active*D)."""
    from repro.models.model import n_params
    total = float(n_params(cfg))
    if not cfg.moe:
        return total
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    expert_params = 3.0 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts \
        * n_moe_layers
    active_frac = cfg.top_k / cfg.n_experts
    return total - expert_params * (1.0 - active_frac)


def model_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS per roofline spec: 6*N*D train, 2*N*D inference."""
    n = active_params(cfg)
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token per sequence

"""Quickstart: train the EdgeRL A2C controller on the paper's testbed env
(3 UAVs running VGG / ResNet / DenseNet against one edge server) and
compare the learned policy with the static baselines — all policies
built through the canonical registry (repro.policies).

    PYTHONPATH=src python examples/quickstart.py [--episodes 300]
"""
import argparse

import jax

from repro.core import RewardWeights, evaluate_policy, make_paper_env
from repro.policies import build_policy, get_policy_spec, policy_names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=300)
    ap.add_argument("--w-acc", type=float, default=1 / 3)
    ap.add_argument("--w-lat", type=float, default=1 / 3)
    ap.add_argument("--w-energy", type=float, default=1 / 3)
    args = ap.parse_args()

    weights = RewardWeights(w_acc=args.w_acc, w_lat=args.w_lat,
                            w_energy=args.w_energy)
    cfg, tables = make_paper_env(weights=weights)
    print(f"env: {cfg.n_uavs} UAVs, models={tables.names}, "
          f"delta={cfg.slot_seconds}s, weights=({args.w_acc:.2f},"
          f"{args.w_lat:.2f},{args.w_energy:.2f})")

    print(f"\ntraining A2C for {args.episodes} episodes ...")
    a2c = build_policy("a2c", cfg, tables, episodes=args.episodes,
                       entropy_coef=0.01)
    a2c.train(log_every=max(args.episodes // 6, 1))

    print("\npolicy comparison (2 eval episodes each):")
    statics = [n for n in policy_names()
               if not get_policy_spec(n).trainable
               and not get_policy_spec(n).needs_cluster]
    for name in statics + ["a2c"]:
        pol = a2c if name == "a2c" else build_policy(name, cfg, tables)
        m = evaluate_policy(cfg, tables, pol, jax.random.key(1), episodes=2)
        modal = " ".join(f"{k}=v{v[0]}c{v[1]}"
                         for k, v in m["modal_selection"].items())
        print(f"  {name:14s} reward={m['reward']:+.3f} "
              f"lat={m['latency']*1e3:6.1f}ms E={m['energy']:.3f}J  {modal}")
    print("\n(v = model version index, c = cut-point index; see Table I)")


if __name__ == "__main__":
    main()

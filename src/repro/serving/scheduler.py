"""Continuous-batching scheduler: slot-based request admission over a fixed
decode batch, the serving pattern real inference frameworks (vLLM/JetStream)
use — requests arrive asynchronously, prefill on admission, decode in
lockstep, retire on EOS/max-tokens, refill the freed slot.

Single-program JAX realization:
  - a fixed pool of B slots, each with its own ring KV cache region
    (slot dim = batch dim of one shared cache tree),
  - per-slot position counters (positions differ per slot — the models'
    positional masking is per-slot via the `pos` argument vectorization),
  - prefill runs per admitted request (B=1) and its cache is scattered
    into the pool slot.

Because model decode_step takes one shared scalar `pos`, the engine keeps
per-slot streams aligned by decoding each slot group with its own pos via
vmap-free masking: we instead track a per-slot offset and rewrite positions
through the ring-cache property that slot validity is positional. For
simplicity and exactness, slots decode in *cohorts* that share a position
(cohort = requests admitted together); this keeps the jitted step identical
to the production serve_step while still giving continuous admission.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (S,)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.out and self.out[-1] == self.eos_id:
            return True
        return len(self.out) >= self.max_new_tokens


@dataclasses.dataclass
class ServerStats:
    admitted: int = 0
    completed: int = 0
    decode_steps: int = 0
    prefills: int = 0


class ContinuousBatchingServer:
    """Cohort-based continuous batching over the functional model API."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.queue: Deque[Request] = deque()
        self.stats = ServerStats()

        def _prefill(params, batch):
            return M.prefill(cfg, params, batch, total_len=cache_len)

        def _decode(params, cache, tok, pos):
            return M.decode_step(cfg, params, cache, tok, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        # cohorts: list of dicts {requests, cache, tok, pos}
        self._cohorts: List[Dict] = []

    # -- client API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive admission + decode until queue and cohorts drain."""
        finished: List[Request] = []
        steps = 0
        while (self.queue or self._cohorts) and steps < max_steps:
            self._admit()
            finished.extend(self._step_all())
            steps += 1
        return finished

    # -- internals ----------------------------------------------------------

    def _slots_in_use(self) -> int:
        return sum(len(c["requests"]) for c in self._cohorts)

    def _extra_batch(self, n: int) -> Dict:
        b = {}
        if self.cfg.cross_attn_every:
            b["media"] = jnp.zeros((n, self.cfg.n_media_tokens,
                                    self.cfg.d_model), self.cfg.cdtype)
        if self.cfg.enc_dec:
            b["enc_frames"] = jnp.zeros((n, self.cfg.encoder_seq,
                                         self.cfg.d_model), self.cfg.cdtype)
        return b

    def _admit(self):
        free = self.max_batch - self._slots_in_use()
        admit: List[Request] = []
        # cohort = same-length prompts admitted together (pad to max)
        while self.queue and len(admit) < free:
            admit.append(self.queue.popleft())
        if not admit:
            return
        S = max(len(r.tokens) for r in admit)
        toks = np.zeros((len(admit), S), np.int32)
        for i, r in enumerate(admit):
            toks[i, S - len(r.tokens):] = r.tokens   # left-pad
        batch = {"tokens": jnp.asarray(toks), **self._extra_batch(len(admit))}
        logits, cache = self._prefill(self.params, batch)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i, r in enumerate(admit):
            r.out.append(int(first[i]))
        self._cohorts.append({"requests": admit, "cache": cache,
                              "tok": first, "pos": S})
        self.stats.admitted += len(admit)
        self.stats.prefills += 1

    def _step_all(self) -> List[Request]:
        finished: List[Request] = []
        keep = []
        for c in self._cohorts:
            live = [r for r in c["requests"] if not r.done]
            if not live:
                finished.extend(c["requests"])
                self.stats.completed += len(c["requests"])
                continue
            logits, cache = self._decode(self.params, c["cache"], c["tok"],
                                         jnp.int32(c["pos"]))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for i, r in enumerate(c["requests"]):
                if not r.done:
                    r.out.append(int(nxt[i]))
            c.update(cache=cache, tok=nxt, pos=c["pos"] + 1)
            self.stats.decode_steps += 1
            keep.append(c)
        self._cohorts = keep
        return finished

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Proves the distribution config is coherent without TPU hardware: any
sharding mismatch, compile-time OOM, or unsupported collective is a bug.
Results (memory analysis, cost analysis, collective bytes, jaxpr cost) are
appended to a JSONL cache so reruns skip completed combos.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
# The VERY FIRST lines — before ANY other import — jax locks device count
# on first init. Do NOT set this anywhere global (conftest/pyproject).
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, SHAPES, get_config
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.analysis.jaxpr_cost import analyze_jaxpr
from repro.analysis.hlo_collectives import collective_bytes
from repro.optim import AdamWConfig

# Perf-iteration variants (EXPERIMENTS.md §Perf). Config-level overrides;
# "mbN" additionally switches the train step to N-way gradient accumulation.
VARIANTS = {
    "baseline": {},
    "mla_absorb": {"mla_absorb": True},
    "moe_gather": {"moe_impl": "gather"},
    "moe_chunk512": {"moe_chunk": 512},
    "moe_gather512": {"moe_impl": "gather", "moe_chunk": 512},
    "bigchunk": {"attn_q_chunk": 2048, "attn_kv_chunk": 4096},
    "hugechunk": {"attn_q_chunk": 4096, "attn_kv_chunk": 8192},
    "mb8": {},
    "mb16": {},
    "mb8_gather": {"moe_impl": "gather"},
    "noremat": {"train_remat": False},
    "causal_skip": {"attn_causal_skip": True},
    "noremat_skip": {"train_remat": False, "attn_causal_skip": True},
    "hugechunk_skip": {"attn_q_chunk": 4096, "attn_kv_chunk": 8192,
                       "attn_causal_skip": True},
    "fsdp": {"fsdp": True},
    "fsdp_skip": {"fsdp": True, "attn_causal_skip": True},
}

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.jsonl")


def _dryrun_dtype(cfg):
    """Big models dry-run in bf16 (deployment dtype); small stay f32."""
    return cfg.with_overrides(param_dtype="bfloat16", compute_dtype="bfloat16")


def build_step(cfg, shape_name, variant="baseline"):
    info = SHAPES[shape_name]
    cfg = st.config_for_shape(cfg, shape_name)
    if info["kind"] == "train":
        mb = int(variant[2:].split("_")[0]) if variant.startswith("mb") else 1
        fn = st.make_train_step(cfg, AdamWConfig(), remat=cfg.train_remat,
                                microbatches=mb)
        order = ("params", "opt_state", "batch")
    elif info["kind"] == "prefill":
        fn = st.make_prefill_step(cfg)
        order = ("params", "batch")
    else:
        fn = st.make_serve_step(cfg)
        order = ("params", "cache", "token", "pos")
    return cfg, fn, order


def run_one(arch: str, shape_name: str, mesh_kind: str, *, jaxpr_cost=True,
            variant: str = "baseline"):
    t0 = time.time()
    cfg0 = _dryrun_dtype(get_config(arch)).with_overrides(**VARIANTS[variant])
    cfg, fn, order = build_step(cfg0, shape_name, variant)
    specs = st.input_specs(cfg0, shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shard = st.step_shardings(cfg0, shape_name, mesh)
    args = [specs[k] for k in order]
    in_sh = [shard[k] for k in order]

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant,
           "devices": int(len(mesh.devices.flat)), "status": "ok"}
    try:
        if jaxpr_cost:
            jc = analyze_jaxpr(jax.make_jaxpr(fn)(*args))
            rec["jaxpr_flops"] = jc["flops"]
            rec["jaxpr_bytes"] = jc["bytes"]
            rec["jaxpr_bytes_min"] = jc["bytes_min"]
            rec["jaxpr_bytes_fused"] = jc["bytes_fused"]
        with mesh:
            jitted = jax.jit(fn, in_shardings=tuple(in_sh))
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                    v = getattr(ma, k, None)
                    if v is not None:
                        rec[k] = int(v)
        except Exception as e:   # noqa: BLE001 - memory analysis best-effort
            rec["memory_analysis_error"] = str(e)[:200]
        try:
            ca = compiled.cost_analysis()
            if ca:
                rec["hlo_flops"] = float(ca.get("flops", -1))
                rec["hlo_bytes"] = float(ca.get("bytes accessed", -1))
        except Exception as e:   # noqa: BLE001
            rec["cost_analysis_error"] = str(e)[:200]
        try:
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_len"] = len(hlo)
        except Exception as e:   # noqa: BLE001
            rec["collectives_error"] = str(e)[:200]
    except Exception as e:       # noqa: BLE001 - record the failure
        rec["status"] = "fail"
        rec["error"] = "".join(traceback.format_exception_only(e))[:2000]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def load_done(path):
    done = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                done[(r["arch"], r["shape"], r["mesh"],
                      r.get("variant", "baseline"))] = r
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    done = {} if args.force else load_done(args.out)
    v = args.variant
    todo = [(a, s, m) for a in archs for s in shapes for m in meshes
            if (a, s, m, v) not in done or done[(a, s, m, v)]["status"] != "ok"]
    print(f"dry-run: {len(todo)} combos to run "
          f"({len(done)} cached in {args.out})", flush=True)
    n_fail = 0
    for a, s, m in todo:
        rec = run_one(a, s, m, variant=args.variant)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        ok = rec["status"] == "ok"
        n_fail += (not ok)
        msg = (f"[{'OK' if ok else 'FAIL'}] {a} x {s} x {m} "
               f"({rec['total_s']}s)")
        if not ok:
            msg += f"\n    {rec['error'][:500]}"
        print(msg, flush=True)
    print(f"done; {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""repro.bench: noise model, matrix, history and gate.

The acceptance triangle from the issue: an injected 2x slowdown must be
flagged, pure jitter at realistic CV must pass, and a fingerprint
mismatch must refuse to gate. Plus: error rows never poison baselines,
the gate names the dominant regressed obs phase, and the runner's
records carry samples/CI/phases end-to-end.
"""
import time

import numpy as np
import pytest

from repro import obs
from repro.bench import (Matrix, Timing, baseline_for, bootstrap_ci,
                         compare, fingerprint, format_sig, gate_records,
                         mann_whitney_u, reject_outliers, render, stamp,
                         summarize, timeit)
from repro.bench import history as bhist
from repro.bench import runner as brunner
from repro.bench.gate import attribute_phase

FP = fingerprint()


def _samples(rng, mean_us, cv=0.05, n=5):
    """Realistic timing stream: lognormal-ish positive jitter."""
    return list(np.abs(rng.normal(mean_us, cv * mean_us, size=n)))


# --------------------------------------------------------------------------
# stats
# --------------------------------------------------------------------------

class TestStats:
    def test_timing_is_float_and_scales_samples(self):
        t = Timing(10.0, [10.0, 12.0, 11.0])
        assert float(t) == 10.0 and t.samples == (10.0, 12.0, 11.0)
        half = t / 2
        assert isinstance(half, Timing)
        assert half.samples == (5.0, 6.0, 5.5)
        assert (t * 3).samples == (30.0, 36.0, 33.0)
        assert f"{t:.1f}" == "10.0"          # format sites still work

    def test_timeit_collects_reps(self):
        t = timeit(lambda: sum(range(100)), n=3, reps=4)
        assert len(t.samples) == 4
        assert float(t) == min(t.samples) > 0

    def test_format_sig(self):
        assert format_sig(0.03125) == 0.03125
        assert format_sig(1408.217) == 1408.0
        assert format_sig(0.000123456) == 0.0001235
        assert format_sig(0.0) == 0.0

    def test_reject_outliers_drops_scheduler_spike(self):
        xs = [100.0, 101.0, 99.0, 100.5, 1000.0]
        kept = reject_outliers(xs)
        assert 1000.0 not in kept and len(kept) == 4
        # small streams pass through untouched
        assert reject_outliers([1.0, 50.0]) == [1.0, 50.0]
        # identical samples: degenerate MAD must not divide by zero
        assert reject_outliers([5.0] * 6) == [5.0] * 6

    def test_bootstrap_ci_covers_median_and_is_deterministic(self):
        rng = np.random.default_rng(0)
        xs = _samples(rng, 100.0, cv=0.05, n=20)
        lo, hi = bootstrap_ci(xs)
        assert lo <= float(np.median(xs)) <= hi
        assert (lo, hi) == bootstrap_ci(xs)   # seeded
        assert bootstrap_ci([7.0]) == (7.0, 7.0)

    def test_summarize(self):
        s = summarize([100.0, 102.0, 98.0, 101.0, 5000.0])
        assert s.n == 4 and s.n_raw == 5      # spike rejected
        assert 98.0 <= s.median <= 102.0
        assert s.cv < 0.05

    def test_mann_whitney_separated_vs_null(self):
        rng = np.random.default_rng(1)
        a = _samples(rng, 100.0, n=8)
        b = _samples(rng, 200.0, n=8)
        assert mann_whitney_u(a, b) < 0.01    # b clearly slower
        assert mann_whitney_u(b, a) > 0.9
        same = _samples(rng, 100.0, n=8)
        assert mann_whitney_u(a, same) > 0.05
        # normal-approximation branch agrees on a big separated stream
        big_a = _samples(rng, 100.0, n=200)
        big_b = _samples(rng, 150.0, n=200)
        assert mann_whitney_u(big_a, big_b) < 1e-6


class TestCompareRule:
    """The gate's decision rule on synthetic sample streams."""

    def test_injected_2x_slowdown_is_flagged(self):
        rng = np.random.default_rng(2)
        base = _samples(rng, 100.0, cv=0.05, n=15)   # pooled baseline
        cur = _samples(rng, 200.0, cv=0.05, n=5)     # 2x regression
        c = compare(base, cur)
        assert c.verdict == "regression"
        assert c.effect > 0.8 and c.p_slower < 0.05

    def test_pure_jitter_at_realistic_cv_passes(self):
        rng = np.random.default_rng(3)
        for _ in range(20):       # no false regression across reruns
            base = _samples(rng, 100.0, cv=0.08, n=15)
            cur = _samples(rng, 100.0, cv=0.08, n=5)
            assert compare(base, cur).verdict != "regression"

    def test_tiny_but_significant_shift_passes(self):
        # +3% with vanishing variance: maximally significant, but below
        # the minimum-effect threshold -> must NOT fail CI
        base = [100.0 + 0.01 * i for i in range(20)]
        cur = [103.0 + 0.01 * i for i in range(10)]
        c = compare(base, cur, min_effect=0.10)
        assert c.p_slower < 0.05 and c.verdict == "ok"

    def test_improvement_and_insufficient(self):
        rng = np.random.default_rng(4)
        base = _samples(rng, 200.0, n=15)
        cur = _samples(rng, 100.0, n=5)
        assert compare(base, cur).verdict == "improved"
        assert compare(base, cur[:2]).verdict == "insufficient"
        assert compare(base[:2], cur).verdict == "insufficient"


# --------------------------------------------------------------------------
# matrix
# --------------------------------------------------------------------------

class TestMatrix:
    def _noop(self, **kw):
        return None

    def test_axes_expansion_and_select(self):
        m = Matrix()
        m.add(self._noop, name="solo", tags=("smoke",))
        m.add(self._noop, name="fleet", axes={"n": (8, 64)},
              tags=("system",))
        names = [c.name for c in m.cases()]
        assert names == ["solo", "fleet[n=8]", "fleet[n=64]"]
        assert [c.params for c in m.cases()][1:] == [{"n": 8}, {"n": 64}]
        assert [c.name for c in m.select(only=["fleet"])] == \
            ["fleet[n=8]", "fleet[n=64]"]
        assert [c.name for c in m.select(only=["fleet[n=64]"])] == \
            ["fleet[n=64]"]
        assert [c.name for c in m.select(tags=["smoke"])] == ["solo"]

    def test_lazy_axis_and_unknown_name(self):
        m = Matrix()
        m.add(self._noop, name="sc", axes={"scenario": lambda: ["a", "b"]})
        assert [c.name for c in m.cases()] == \
            ["sc[scenario=a]", "sc[scenario=b]"]
        with pytest.raises(KeyError, match="unknown benchmark"):
            m.select(only=["nope"])


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

class TestRunner:
    def test_records_phases_and_error_encoding(self, capsys):
        m = Matrix()

        def good():
            with obs.span("work.inner"):
                time.sleep(0.002)
            brunner.emit("good", Timing(5.0, [5.0, 6.0, 5.5]), "d=1",
                         devices=4, devices_per_s=123.4)

        def bad():
            raise ValueError("boom, with comma\nand newline")

        m.add(good)
        m.add(bad)
        res = brunner.run(m.cases(), echo=False)
        assert res.errors == 1
        g, b = res.records
        assert g["name"] == "good" and g["case"] == "good"
        assert g["samples"] == [5.0, 6.0, 5.5] and g["n"] == 3
        assert g["ci_lo"] <= g["median"] <= g["ci_hi"]
        assert "work.inner" in g["phases"]
        assert g["phases"]["work.inner"]["count"] == 1
        assert g["phases"]["work.inner"]["total_s"] >= 0.002
        assert "bench" not in g["phases"]
        assert g["extra"] == {"devices": 4, "devices_per_s": 123.4}
        # error rows: no timing fields at all, sanitized message
        assert set(b) == {"name", "error", "case"}
        assert "," not in b["error"] and "\n" not in b["error"]
        # null recorder restored after the run
        assert not obs.get_recorder().enabled


# --------------------------------------------------------------------------
# history
# --------------------------------------------------------------------------

def _hist_rows(rng, runs=3, mean=100.0, name="case_a", fp=None,
               phases=None):
    rows = []
    for i in range(runs):
        rec = {"name": name, "us_per_call": mean,
               "samples": _samples(rng, mean, n=5)}
        if phases:
            rec["phases"] = phases
        rows += stamp([rec], run_id=f"r{i}", t_unix=float(i),
                      sha=f"sha{i}", fp=fp or FP)
    return rows


class TestHistory:
    def test_roundtrip_and_stamp(self, tmp_path):
        p = tmp_path / "h.jsonl"
        rng = np.random.default_rng(5)
        rows = _hist_rows(rng, runs=2)
        bhist.append(str(p), rows[:1])
        bhist.append(str(p), rows[1:])       # append-only across calls
        back = bhist.load(str(p))
        assert back == rows
        assert back[0]["git_sha"] == "sha0"
        assert back[0]["fingerprint"] == FP
        assert bhist.load(str(tmp_path / "missing.jsonl")) == []

    def test_baseline_pools_recent_matching_runs(self):
        rng = np.random.default_rng(6)
        rows = _hist_rows(rng, runs=5)
        b = baseline_for("case_a", FP, rows, pool=3)
        assert len(b.rows) == 3 and len(b.samples) == 15
        assert b.shas == ["sha2", "sha3", "sha4"]   # newest three

    def test_error_rows_never_poison_baselines(self):
        rows = stamp([{"name": "case_a", "error": "ValueError: boom"}],
                     run_id="r0", t_unix=0.0, sha="s", fp=FP)
        assert baseline_for("case_a", FP, rows) is None
        # ... and a -1.0-style record without samples doesn't either
        rows = stamp([{"name": "case_a", "us_per_call": -1.0}],
                     run_id="r0", t_unix=0.0, sha="s", fp=FP)
        assert baseline_for("case_a", FP, rows) is None

    def test_fingerprint_mismatch_yields_no_baseline(self):
        rng = np.random.default_rng(7)
        other = dict(FP, host="other-host")
        rows = _hist_rows(rng, fp=other)
        assert baseline_for("case_a", FP, rows) is None
        assert bhist.has_foreign_fingerprint("case_a", FP, rows)


# --------------------------------------------------------------------------
# gate
# --------------------------------------------------------------------------

PHASES_BASE = {"fleet.queues": {"count": 100, "total_s": 0.050},
               "pricing.analytical": {"count": 100, "total_s": 0.048},
               "fleet.decide": {"count": 100, "total_s": 0.020}}


class TestGate:
    def test_unchanged_run_passes(self):
        rng = np.random.default_rng(8)
        hist = _hist_rows(rng, runs=3, phases=PHASES_BASE)
        cur = [{"name": "case_a", "us_per_call": 100.0,
                "samples": _samples(rng, 100.0, n=5),
                "phases": PHASES_BASE}]
        rep = gate_records(cur, hist, FP)
        assert not rep.failed and not rep.refused
        assert rep.verdicts[0].status in ("ok", "improved")

    def test_slowdown_fails_and_names_dominant_phase(self):
        rng = np.random.default_rng(9)
        hist = _hist_rows(rng, runs=3, phases=PHASES_BASE)
        cur_phases = {"fleet.queues": {"count": 100, "total_s": 0.052},
                      "pricing.analytical": {"count": 100,
                                             "total_s": 0.148},
                      "fleet.decide": {"count": 100, "total_s": 0.021}}
        cur = [{"name": "case_a", "us_per_call": 200.0,
                "samples": _samples(rng, 200.0, n=5),
                "phases": cur_phases}]
        rep = gate_records(cur, hist, FP)
        assert rep.failed
        v = rep.verdicts[0]
        assert v.status == "regression"
        assert v.phase == "pricing.analytical"
        assert "+" in v.phase_detail
        txt = render(rep, cur)
        assert "FAIL" in txt and "pricing.analytical" in txt

    def test_fingerprint_mismatch_refuses_to_gate(self):
        rng = np.random.default_rng(10)
        other = dict(FP, cpu_count=64)
        hist = _hist_rows(rng, fp=other)
        # even a 10x slowdown must not "fail" against a foreign machine
        cur = [{"name": "case_a", "us_per_call": 1000.0,
                "samples": _samples(rng, 1000.0, n=5)}]
        rep = gate_records(cur, hist, FP)
        assert rep.refused and not rep.failed
        assert rep.verdicts[0].status == "fingerprint_mismatch"
        assert "refusing to gate" in rep.reason
        assert "REFUSED" in render(rep, cur)

    def test_error_and_new_records_are_skipped_not_gated(self):
        rng = np.random.default_rng(11)
        hist = _hist_rows(rng, runs=3)
        cur = [{"name": "case_a", "error": "RuntimeError: x"},
               {"name": "case_new", "us_per_call": 5.0,
                "samples": [5.0, 5.1, 5.2]}]
        rep = gate_records(cur, hist, FP)
        assert not rep.failed
        assert {v.status for v in rep.verdicts} == {"error", "new"}

    def test_attribution_prefers_absolute_contribution(self):
        # a 2us phase that quadrupled must not outrank the critical-path
        # phase that grew 50%
        base = [{"phases": {"big": {"count": 1, "total_s": 1.0},
                            "tiny": {"count": 1, "total_s": 2e-5}}}]
        cur = {"phases": {"big": {"count": 1, "total_s": 1.5},
                          "tiny": {"count": 1, "total_s": 8e-5}}}
        phase, detail = attribute_phase(base, cur)
        assert phase == "big" and "+50%" in detail

    def test_gate_report_json_roundtrips(self):
        rng = np.random.default_rng(12)
        hist = _hist_rows(rng, runs=3)
        cur = [{"name": "case_a", "us_per_call": 100.0,
                "samples": _samples(rng, 100.0, n=5)}]
        d = gate_records(cur, hist, FP).to_json()
        import json
        assert json.loads(json.dumps(d)) == d
        assert d["counts"] and d["fingerprint"] == FP

"""repro.bench — variance-aware perf harness with regression gating.

The perf trajectory is an observable: benchmarks declare a case matrix
(``matrix``), the runner executes it under ``repro.obs`` recording with
per-case phase breakdowns (``runner``), timings carry repeated samples
with a robust noise model (``stats``), every run appends
fingerprint-stamped rows to ``BENCH_history.jsonl`` (``history``), and
the gate (``gate`` + ``scripts/benchgate.py``) fails CI on
statistically significant regressions — naming the regressed obs
*phase*, not just the case. See DESIGN.md §10.

    # 1. measure (benchmarks/run.py rides this package)
    PYTHONPATH=src python benchmarks/run.py --only fleet_sim \
        --json BENCH_results.json
    # 2. gate vs history (and append this run)
    PYTHONPATH=src python scripts/benchgate.py BENCH_results.json \
        --history BENCH_history.jsonl
"""
from repro.bench.gate import (CaseVerdict, GateReport, attribute_phase,
                              gate_records, render)
from repro.bench.history import (Baseline, append, baseline_for,
                                 fingerprint, fp_key, git_sha, load,
                                 stamp)
from repro.bench.matrix import Case, Matrix
from repro.bench.runner import RunResult, Sink, emit, fold_phases, run
from repro.bench.stats import (Comparison, SampleStats, Timing,
                               bootstrap_ci, compare, format_sig,
                               mann_whitney_u, reject_outliers,
                               summarize, timeit)

__all__ = [
    "Matrix", "Case",
    "Timing", "timeit", "SampleStats", "summarize", "reject_outliers",
    "bootstrap_ci", "mann_whitney_u", "compare", "Comparison",
    "format_sig",
    "Sink", "emit", "run", "RunResult", "fold_phases",
    "fingerprint", "fp_key", "git_sha", "append", "load", "stamp",
    "baseline_for", "Baseline",
    "gate_records", "GateReport", "CaseVerdict", "attribute_phase",
    "render",
]

"""Router baselines for the cluster action space (version, cut, server).

Each router fixes the *server* column with a classic dispatch rule and
lets the greedy (V, K) grid pick the execution profile under that
target — so router comparisons isolate the routing decision itself
(what A2C/PPO must learn end-to-end) from profile selection:

- ``round_robin``     cycle devices across servers each epoch
- ``join_shortest_queue``  every device targets the min-depth server
- ``local_only``      lightweight version, terminal cut, server 0 —
                      the never-offload floor

JSQ ranks servers by *job count*; on a heterogeneous pool (hetero-4) a
quarter-rate tier with a short queue looks cheap even though its
effective wait is long — exactly the misread a learned router can beat
by pricing depth x service rate per target.

Registered into the ``repro.policies`` registry (the canonical names
above) on ``import repro.policies``; building one against a
non-cluster env raises ValueError.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pricing
from repro.policies.base import PolicySpec, register
from repro.policies.static import StaticPolicy


def _best_pair_given_server(cfg, tables, state, srv):
    """Per-UAV reward argmax over (version, cut) with the server column
    pinned at ``srv`` (n,) int32 — greedy_oracle's scoring restricted to
    the router's chosen target."""
    n = cfg.n_uavs
    V, K = tables.n_versions, tables.n_cuts
    w = cfg.weights
    view = pricing.view_from_state(state)

    jj, kk = jnp.meshgrid(jnp.arange(V), jnp.arange(K), indexing="ij")
    pairs = jnp.stack([jj.ravel(), kk.ravel()], -1).astype(jnp.int32)

    def score(pair):
        actions = jnp.concatenate(
            [jnp.tile(pair[None], (n, 1)), srv[:, None]], -1)
        br = pricing.price_actions(cfg, tables, view, actions)
        valid = tables.version_valid[state["model_id"], pair[0]]
        s = (w.w_acc * br.acc_score + w.w_lat * br.lat_score
             + w.w_energy * br.energy_score + w.w_stab * br.stab_score)
        return jnp.where(valid > 0, s, -jnp.inf)

    scores = jax.vmap(score)(pairs)          # (VK, n)
    best = jnp.argmax(scores, axis=0)        # (n,)
    return jnp.concatenate([pairs[best], srv[:, None]], -1)


def round_robin(cfg, tables, state, rng=None):
    """Cycle devices over servers, rotating one slot per epoch so the
    assignment is load-balanced in time as well as across devices."""
    n, S = cfg.n_uavs, cfg.cluster.n_servers
    srv = ((jnp.arange(n) + state["t"]) % S).astype(jnp.int32)
    return _best_pair_given_server(cfg, tables, state, srv)


def join_shortest_queue(cfg, tables, state, rng=None):
    """Every device targets the server with the fewest queued jobs —
    depth-blind to heterogeneous service rates, by construction."""
    n = cfg.n_uavs
    q = jnp.broadcast_to(jnp.asarray(state["queue"]),
                         (cfg.cluster.n_servers,))
    srv = jnp.broadcast_to(jnp.argmin(q), (n,)).astype(jnp.int32)
    return _best_pair_given_server(cfg, tables, state, srv)


def local_only(cfg, tables, state, rng=None):
    """Never offload: lightweight version, terminal cut, server 0 (the
    server column is vestigial — no tail ever reaches it)."""
    n = cfg.n_uavs
    return jnp.stack([jnp.zeros((n,), jnp.int32),
                      jnp.full((n,), tables.n_cuts - 1, jnp.int32),
                      jnp.zeros((n,), jnp.int32)], -1)


def _router(name: str, fn, description: str) -> PolicySpec:
    def factory(env_cfg, tables, **kw):
        if env_cfg.cluster is None:
            raise ValueError(
                f"router policy {name!r} needs a cluster-mode env "
                "(EnvConfig.cluster is set by scenarios with a server "
                "pool, e.g. --scenario edge-cluster)")
        return StaticPolicy(env_cfg, tables, fn)

    return register(PolicySpec(name=name, factory=factory,
                               trainable=False, description=description,
                               needs_cluster=True))


_router("round_robin", round_robin,
        "rotate devices across servers; greedy (version, cut) per target")
_router("join_shortest_queue", join_shortest_queue,
        "all devices target the min-depth server (job-count JSQ)")
_router("local_only", local_only,
        "never offload: light version, terminal cut (cluster floor)")

"""starcoder2-3b [dense] — GQA (kv=2), RoPE. [arXiv:2402.19173]

StarCoder2-3B uses layernorm + gelu MLP and attention biases.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173 (StarCoder 2)",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12_288,
    vocab_size=49_152,
    head_dim=128,
    rope_theta=100_000.0,
    qkv_bias=True,
    attn_bias=True,
    norm="layernorm",
    mlp_act="gelu",
    sliding_window=4096,     # starcoder2 trains with 4k sliding window
))

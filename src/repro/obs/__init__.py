"""repro.obs — structured tracing, metrics and JAX retrace accounting.

One process-global recorder (null by default — zero overhead when off)
behind module-level hooks:

    from repro import obs

    with obs.recording("events.jsonl") as rec:      # enable
        with obs.span("fleet.epoch", epoch=0):       # nested timed span
            obs.event("drift.regime_switch", regime=1)
            obs.inc("fleet.dropped", 3, policy="a2c")  # labeled counter
    # -> versioned JSONL; fold with scripts/obsview.py or obs.report

JAX accounting (``obs.jaxmon``) counts jit re-traces per call site and
compile wall-time process-wide; ``obs.log``/``info``/``debug``/``warn``
is the structured console logger (verbosity-gated print + recorded log
events). See DESIGN.md §9 for the architecture and the rules
(recording never changes results; no host callbacks on traced paths).
"""
from repro.obs import jaxmon, report
from repro.obs.events import (SCHEMA_VERSION, NullRecorder, Recorder,
                              debug, event, get_recorder, get_verbosity,
                              info, log, read_events, recording,
                              set_recorder, set_verbosity, span, warn)
from repro.obs.metrics import Metrics, gauge, inc, observe

__all__ = [
    "SCHEMA_VERSION", "Recorder", "NullRecorder", "Metrics",
    "span", "event", "recording", "get_recorder", "set_recorder",
    "read_events",
    "inc", "gauge", "observe",
    "log", "info", "debug", "warn", "set_verbosity", "get_verbosity",
    "jaxmon", "report",
    # flight recorder (lazy imports below: timeline/slo/traindiag pull
    # numpy/jnp machinery the bare tracing hooks don't need)
    "Timeline", "SLOConfig", "TrainDiag",
]


def __getattr__(name):
    if name in ("Timeline", "write_timeline", "read_timeline"):
        from repro.obs import timeline
        return getattr(timeline, name)
    if name in ("SLOConfig", "SLOReport"):
        from repro.obs import slo
        return getattr(slo, name)
    if name in ("TrainDiag",):
        from repro.obs import traindiag
        return getattr(traindiag, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")

"""Quantized matmul (int8 x int8 -> int32, f32 rescale) as a Pallas TPU kernel.

Grid = (M/bm, N/bn, K/bk) with the K dimension innermost and sequential:
each (i, j) tile accumulates int8 dot products into an int32 VMEM scratch
(the MXU's native int8 path — 2x the bf16 MAC throughput on v5e), then
rescales once with the per-row activation scale and per-column weight scale
on the last K step. Block defaults (128) align with the MXU's 128-lane
tiles; int8 min tile is (32, 128) so 128-padded operands are always legal.

TPU is the TARGET; correctness is validated on CPU with interpret=True
against ``quant_matmul_ref`` (pure jnp oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmm_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_scr, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]                               # (bm, bk) int8
    w = w_ref[...]                               # (bk, bn) int8
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_scr[...].astype(jnp.float32)
        o_ref[...] = out * xs_ref[...] * ws_ref[...]   # (bm,1) * (1,bn)


def quant_matmul_ref(x_q, w_q, x_scale, w_scale):
    """jnp oracle: x_q (M,K) int8, w_q (K,N) int8, x_scale (M,), w_scale (N,).

    Returns f32 (M, N) = (x_q @ w_q) * x_scale[:,None] * w_scale[None,:]
    with the integer dot accumulated exactly in int32."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32)
            * x_scale.reshape(-1, 1) * w_scale.reshape(1, -1))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(x_q, w_q, x_scale, w_scale, *, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = True):
    """Pallas int8 matmul. Same contract as ``quant_matmul_ref``."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    # Never shrink blocks below the int8 minimum tile (32, 128): small
    # operands are padded UP to one full block instead, so the same
    # BlockSpecs lower on hardware and in interpret mode alike.

    def pad(a, blk, axis):
        p = (-a.shape[axis]) % blk
        if p == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, p)
        return jnp.pad(a, widths)

    x_ = pad(pad(x_q, bm, 0), bk, 1)
    w_ = pad(pad(w_q, bk, 0), bn, 1)
    xs_ = pad(x_scale.reshape(-1, 1).astype(jnp.float32), bm, 0)
    ws_ = pad(w_scale.reshape(1, -1).astype(jnp.float32), bn, 1)
    nm, nn, nk = x_.shape[0] // bm, w_.shape[1] // bn, x_.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_.shape[0], w_.shape[1]),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_, w_, xs_, ws_)
    return out[:M, :N]

"""Batched serving engine: prefill + scanned decode with KV caches, plus the
EdgeRL *split* executor (head/tail across device/server submeshes).

``ServingEngine`` is the plain path: jit'd prefill builds the cache, a
jit'd ``lax.scan`` decodes N tokens greedily or with temperature sampling.

``SplitServingEngine`` is the paper's deployment: an EdgeRL controller
decision (version j, cut l) routes each request batch — the head segment
runs as one jit (the "UAV"/head submesh), the cut activation crosses the
link, the tail + decode runs as another jit (the edge-server submesh).
The two jits exercise exactly the partition the paper's Fig. 1 shows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import partition
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0    # 0 => greedy
    cache_len: Optional[int] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.serve = serve

        def _prefill(params, batch):
            total = serve.cache_len
            if total is None:
                total = batch["tokens"].shape[1] + serve.max_new_tokens
            return M.prefill(cfg, params, batch, total_len=total)

        def _generate(params, cache, first_tok, pos0, rng):
            def step(carry, k):
                cache, tok, pos = carry
                logits, cache = M.decode_step(cfg, params, cache, tok, pos)
                if serve.temperature > 0:
                    nxt = jax.random.categorical(
                        k, logits / serve.temperature, axis=-1)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (cache, nxt, pos + 1), nxt
            keys = jax.random.split(rng, serve.max_new_tokens)
            (cache, _, _), toks = jax.lax.scan(
                step, (cache, first_tok, pos0), keys)
            return toks.T, cache             # (B, N)

        self._prefill = jax.jit(_prefill)
        self._generate = jax.jit(_generate)

    def generate(self, batch: Dict, rng=None) -> jnp.ndarray:
        """batch: {tokens (B,S), [media|enc_frames]} -> (B, max_new_tokens)."""
        rng = rng if rng is not None else jax.random.key(0)
        logits, cache = self._prefill(self.params, batch)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos0 = jnp.int32(batch["tokens"].shape[1])
        toks, _ = self._generate(self.params, cache, first, pos0, rng)
        # the prefill argmax IS generated token 0; the scan produced 1..N
        return jnp.concatenate([first[:, None], toks[:, :-1]], axis=1)


class SplitServingEngine:
    """EdgeRL-routed split inference (single forward; classification-style
    scoring of the last position, mirroring the paper's object-classifier
    workload on transformers).

    The engine holds one param tree per *quant version* (repro.quant:
    bf16 / w8 / w4), so the controller's full (version j, cut l) action is
    executable: the chosen version's quantized head runs on the device
    side, the cut activation crosses the link (int8 + scales when the
    version quantizes activations), the matching tail finishes it."""

    def __init__(self, cfg: ModelConfig, params,
                 versions: Sequence[str] = ("bf16",)):
        from repro.quant import get_version

        self.cfg = cfg
        self.params = params
        self.versions = tuple(versions)
        for v in self.versions:
            get_version(v)           # validate names up front
        self._vparams = {}           # built lazily on first infer()
        self._heads = {}
        self._tails = {}

    def _params_for(self, version: str):
        if version not in self.versions:
            raise KeyError(f"version {version!r} not enabled; have "
                           f"{sorted(self.versions)}")
        if version not in self._vparams:
            from repro.quant import build_version_params
            self._vparams[version] = build_version_params(
                self.cfg, self.params, (version,))[version]
        return self._vparams[version]

    def _fns(self, cut: Tuple[str, int], version: str):
        key = (cut, version)
        if key not in self._heads:
            cfg = self.cfg
            self._heads[key] = jax.jit(
                lambda p, b: partition.run_head(cfg, p, b, cut))
            self._tails[key] = jax.jit(
                lambda p, a, b: partition.run_tail(cfg, p, a, b, cut))
        return self._heads[key], self._tails[key]

    def infer(self, batch: Dict, cut: Tuple[str, int],
              version: str = "bf16"):
        """Returns (logits, cut_activation_bytes) — the activation is what
        crosses the device->server link; its *measured* size feeds back
        into the EdgeRL env's cut_bytes axis."""
        from repro.quant import get_version, quantize_act

        params = self._params_for(version)
        head, tail = self._fns(cut, version)
        act = head(params, batch)
        if get_version(version).act_bits == 8:
            # the link carries int8 codes + per-row scales, like the
            # w8a8 matmuls inside the trunk
            q, s = quantize_act(act)
            act_bytes = (q.size * q.dtype.itemsize
                         + s.size * s.dtype.itemsize)
            act = (q.astype(jnp.float32) * s).astype(act.dtype)
        else:
            act_bytes = act.size * act.dtype.itemsize
        logits = tail(params, act, batch)
        return logits, act_bytes

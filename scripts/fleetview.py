"""Render a flight-recorder timeline file as a terminal dashboard:
unicode sparklines per fleet series, annotation markers (drift regime
switches, autoscaler decisions, hot-swaps, SLO pages), per-server
DVFS/replica rows for cluster runs, and the error-budget burn table.

    # record a timeline, then view it
    PYTHONPATH=src python scripts/simulate.py --scenario cluster-brownout \
        --timeline-out flight.json
    PYTHONPATH=src python scripts/fleetview.py flight.json

    # machine-readable export (what CI smoke-asserts on); '-' = stdout
    PYTHONPATH=src python scripts/fleetview.py flight.json --json -

    # static HTML dashboard (inline SVG, no dependencies)
    PYTHONPATH=src python scripts/fleetview.py flight.json --html dash.html

    # pipe straight through without touching disk
    PYTHONPATH=src python scripts/simulate.py --scenario flash-crowd \
        --timeline-out - | PYTHONPATH=src python scripts/fleetview.py -
"""
from __future__ import annotations

import argparse
import html as html_mod
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.timeline import read_timeline

# the fleet series worth a sparkline row, in display order
SERIES = ("arrivals", "goodput", "lat_p95", "lat_mean", "energy_wh",
          "queue_jobs", "dropped", "alive")

# annotation kind -> single-char marker on the epoch axis
MARKERS = {"regime_switch": "R", "autoscale": "A", "hotswap": "H",
           "drift_trigger": "D", "burst_start": "B", "slo_alert": "!"}

BLOCKS = "▁▂▃▄▅▆▇█"


# --------------------------------------------------------------------------
# sparklines
# --------------------------------------------------------------------------

def _column(run: Dict, key: str) -> Optional[np.ndarray]:
    col = run["timeline"]["columns"].get(key)
    if col is None:
        return None
    return np.array([np.nan if v is None else float(v) for v in col])


def _bucket(values: np.ndarray, width: int) -> np.ndarray:
    """Downsample to ``width`` buckets by nan-mean so long horizons fit
    one terminal row; short series pass through unchanged."""
    T = values.shape[0]
    if T <= width:
        return values
    edges = np.linspace(0, T, width + 1).astype(int)
    out = np.full(width, np.nan)
    for i in range(width):
        chunk = values[edges[i]:max(edges[i + 1], edges[i] + 1)]
        if np.any(np.isfinite(chunk)):
            out[i] = np.nanmean(chunk)
    return out


def spark(values: np.ndarray, width: int) -> str:
    """Unicode sparkline; '·' where the bucket has no finite sample
    (e.g. percentile columns under the scan engine)."""
    v = _bucket(values, width)
    finite = v[np.isfinite(v)]
    if finite.size == 0:
        return "·" * v.shape[0]
    lo, hi = float(finite.min()), float(finite.max())
    span = hi - lo
    chars = []
    for x in v:
        if not np.isfinite(x):
            chars.append("·")
        elif span <= 0:
            chars.append(BLOCKS[3])
        else:
            chars.append(BLOCKS[min(int((x - lo) / span * 8), 7)])
    return "".join(chars)


def marker_line(run: Dict, width: int) -> str:
    """One character row under the sparklines marking annotation epochs
    (later annotations win a contested cell; '*' = several kinds)."""
    tl = run["timeline"]
    epochs = tl["columns"].get("epoch", [])
    anns = tl.get("annotations", [])
    if not epochs or not anns:
        return ""
    e0, e1 = epochs[0], epochs[-1]
    span = max(e1 - e0, 1)
    w = min(len(epochs), width)
    cells = [" "] * w
    for a in anns:
        pos = min(int((a["epoch"] - e0) / span * (w - 1)), w - 1) \
            if w > 1 else 0
        m = MARKERS.get(a["kind"], "?")
        cells[pos] = m if cells[pos] in (" ", m) else "*"
    return "".join(cells)


# --------------------------------------------------------------------------
# terminal rendering
# --------------------------------------------------------------------------

def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _series_rows(run: Dict, width: int) -> List[str]:
    lines = []
    for key in SERIES:
        col = _column(run, key)
        if col is None or col.size == 0:
            continue
        finite = col[np.isfinite(col)]
        if finite.size == 0:
            stats = "(no samples)"
        else:
            stats = (f"min={finite.min():.4g} max={finite.max():.4g} "
                     f"last={col[-1]:.4g}" if np.isfinite(col[-1]) else
                     f"min={finite.min():.4g} max={finite.max():.4g}")
        lines.append(f"  {key:11s} {spark(col, width)}  {stats}")
    mk = marker_line(run, width)
    if mk.strip():
        lines.append(f"  {'events':11s} {mk}")
    return lines


def _annotation_rows(run: Dict, limit: int = 20) -> List[str]:
    anns = run["timeline"].get("annotations", [])
    if not anns:
        return []
    lines = ["  annotations:"]
    for a in anns[:limit]:
        attrs = " ".join(f"{k}={_fmt(v)}" for k, v in a.items()
                         if k not in ("epoch", "kind"))
        mark = MARKERS.get(a["kind"], "?")
        lines.append(f"    [{mark}] epoch={a['epoch']:<6d} "
                     f"{a['kind']:14s} {attrs}")
    if len(anns) > limit:
        lines.append(f"    ... {len(anns) - limit} more")
    return lines


def _server_rows(run: Dict, width: int) -> List[str]:
    srv = run["timeline"].get("servers")
    if not srv:
        return []
    names = srv.get("names") or [f"srv{i}" for i in range(srv["n"])]
    lines = [f"  servers ({srv['n']}):"]
    for s, name in enumerate(names):
        parts = [f"    {name:10s}"]
        for key, label in (("srv_queue", "queue"), ("srv_dvfs", "dvfs"),
                           ("srv_replicas", "repl")):
            series = srv.get(key)
            if series is None:
                continue
            col = np.array([np.nan if v is None else float(v)
                            for v in series[s]])
            parts.append(f"{label} {spark(col, max(width // 3, 8))}")
        lines.append(" ".join(parts))
    return lines


def _slo_rows(run: Dict) -> List[str]:
    slo = run["timeline"].get("slo")
    if not slo:
        return []
    tte = slo.get("time_to_exhaustion_epochs")
    lines = [
        "  error budget: "
        f"target={slo['target']:.3f} attainment={slo['attainment']:.4f} "
        f"remaining={slo['budget_remaining']:.3f} "
        f"tte={_fmt(tte)} epochs",
        f"    burn max: fast={slo['max_burn_fast']:.2f} "
        f"(page>{slo['fast_burn']:g}/{slo['fast_window']}ep) "
        f"slow={slo['max_burn_slow']:.2f} "
        f"(page>{slo['slow_burn']:g}/{slo['slow_window']}ep)"]
    for i, a in enumerate(slo.get("alerts_detail", [])):
        end = a["end"] if a["end"] is not None else "run-end"
        lines.append(f"    page #{i + 1}: epochs {a['start']}–{end}  "
                     f"peak burn fast={a['peak_burn_fast']:.1f} "
                     f"slow={a['peak_burn_slow']:.1f}")
    return lines


def render(doc: Dict, width: int = 72) -> str:
    out = []
    meta = doc.get("meta", {})
    head = " ".join(f"{k}={v}" for k, v in meta.items()
                    if isinstance(v, (str, int, float)))
    out.append(f"fleet flight recorder — {len(doc['runs'])} run(s)"
               + (f"  [{head}]" if head else ""))
    for run in doc["runs"]:
        tl = run["timeline"]
        out += ["", f"== {run.get('policy', '?')} seed "
                f"{run.get('seed', '?')}  (engine={tl['engine']}, "
                f"{tl['epochs']} epochs, stride {tl['stride']}) "
                + "=" * 8]
        out += _series_rows(run, width)
        out += _server_rows(run, width)
        out += _slo_rows(run)
        out += _annotation_rows(run)
    legend = " ".join(f"{m}={k}" for k, m in MARKERS.items())
    out += ["", f"markers: {legend}  (*=multiple)"]
    return "\n".join(out)


# --------------------------------------------------------------------------
# machine-readable export
# --------------------------------------------------------------------------

def summarize(doc: Dict) -> Dict:
    """The CI smoke contract: per-run series stats, annotation counts
    by kind, the full annotation/server/slo payloads — everything tests
    assert on without re-parsing the raw columns."""
    runs = []
    for run in doc["runs"]:
        tl = run["timeline"]
        series = {}
        for key, col in tl["columns"].items():
            v = np.array([np.nan if x is None else float(x) for x in col])
            finite = v[np.isfinite(v)]
            series[key] = {
                "n": int(v.shape[0]),
                "min": float(finite.min()) if finite.size else None,
                "max": float(finite.max()) if finite.size else None,
                "mean": float(finite.mean()) if finite.size else None,
                "last": (float(v[-1]) if v.size and np.isfinite(v[-1])
                         else None)}
        by_kind: Dict[str, int] = {}
        for a in tl.get("annotations", []):
            by_kind[a["kind"]] = by_kind.get(a["kind"], 0) + 1
        runs.append({
            "policy": run.get("policy"), "seed": run.get("seed"),
            "engine": tl["engine"], "epochs": tl["epochs"],
            "stride": tl["stride"], "series": series,
            "annotation_counts": by_kind,
            "annotations": tl.get("annotations", []),
            "servers": tl.get("servers"),
            "slo": tl.get("slo")})
    return {"type": "fleetview", "schema": doc["schema"],
            "meta": doc.get("meta", {}), "runs": runs}


# --------------------------------------------------------------------------
# HTML export
# --------------------------------------------------------------------------

def _svg_series(values: np.ndarray, w: int = 640, h: int = 60,
                color: str = "#2a6fdb") -> str:
    finite = values[np.isfinite(values)]
    if finite.size == 0:
        return f'<svg width="{w}" height="{h}"></svg>'
    lo, hi = float(finite.min()), float(finite.max())
    span = hi - lo if hi > lo else 1.0
    T = values.shape[0]
    pts = []
    for i, v in enumerate(values):
        if not np.isfinite(v):
            continue
        x = i / max(T - 1, 1) * (w - 4) + 2
        y = h - 4 - (v - lo) / span * (h - 8)
        pts.append(f"{x:.1f},{y:.1f}")
    return (f'<svg width="{w}" height="{h}">'
            f'<polyline fill="none" stroke="{color}" stroke-width="1.2" '
            f'points="{" ".join(pts)}"/></svg>')


def to_html(doc: Dict) -> str:
    parts = ["<!doctype html><meta charset='utf-8'>"
             "<title>fleet flight recorder</title>"
             "<style>body{font:13px monospace;margin:24px;max-width:760px}"
             "h2{border-bottom:1px solid #ccc}table{border-collapse:"
             "collapse}td,th{padding:2px 8px;border:1px solid #ddd}"
             ".ann{color:#a40}</style>",
             f"<h1>fleet flight recorder — {len(doc['runs'])} run(s)</h1>"]
    for run in doc["runs"]:
        tl = run["timeline"]
        parts.append(f"<h2>{html_mod.escape(str(run.get('policy')))} "
                     f"seed {run.get('seed')} — engine {tl['engine']}, "
                     f"{tl['epochs']} epochs</h2>")
        for key in SERIES:
            col = _column(run, key)
            if col is None or not np.any(np.isfinite(col)):
                continue
            finite = col[np.isfinite(col)]
            parts.append(f"<div><b>{key}</b> "
                         f"min={finite.min():.4g} max={finite.max():.4g}"
                         f"<br>{_svg_series(col)}</div>")
        slo = tl.get("slo")
        if slo:
            parts.append(
                "<table><tr><th>target</th><th>attainment</th>"
                "<th>budget left</th><th>pages</th><th>max burn "
                "fast/slow</th></tr>"
                f"<tr><td>{slo['target']:.3f}</td>"
                f"<td>{slo['attainment']:.4f}</td>"
                f"<td>{slo['budget_remaining']:.3f}</td>"
                f"<td>{slo['alerts']}</td>"
                f"<td>{slo['max_burn_fast']:.1f} / "
                f"{slo['max_burn_slow']:.1f}</td></tr></table>")
        anns = tl.get("annotations", [])
        if anns:
            rows = "".join(
                f"<li>epoch {a['epoch']}: {html_mod.escape(a['kind'])} "
                + html_mod.escape(" ".join(
                    f"{k}={v}" for k, v in a.items()
                    if k not in ("epoch", "kind"))) + "</li>"
                for a in anns[:50])
            parts.append(f"<div class='ann'><b>annotations</b>"
                         f"<ul>{rows}</ul></div>")
    return "\n".join(parts)


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("timeline", help="flight-recorder file from "
                    "simulate.py --timeline-out ('-' = stdin)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable summary ('-' = "
                    "JSON only, to stdout — what CI asserts on)")
    ap.add_argument("--html", metavar="PATH", default=None,
                    help="write a static HTML dashboard (inline SVG)")
    ap.add_argument("--width", type=int, default=72,
                    help="sparkline width in characters (default 72)")
    args = ap.parse_args()

    try:
        doc = read_timeline(args.timeline)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        raise SystemExit(f"fleetview: {e}")

    # File exports happen before the terminal render so a closed stdout
    # (e.g. piping the dashboard to `head`) can't lose them.
    if args.json and args.json != "-":
        with open(args.json, "w") as f:
            json.dump(summarize(doc), f, indent=2, default=str)
    if args.html:
        with open(args.html, "w") as f:
            f.write(to_html(doc))

    try:
        if args.json == "-":
            json.dump(summarize(doc), sys.stdout, indent=2, default=str)
            print()
        else:
            print(render(doc, width=args.width))
            if args.json:
                print(f"\nwrote {args.json}")
            if args.html:
                print(f"wrote {args.html}")
        sys.stdout.flush()
    except BrokenPipeError:
        # Reader went away (| head); the exports above already landed.
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        raise SystemExit(0)


if __name__ == "__main__":
    main()

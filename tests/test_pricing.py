"""The single pricing core: numpy≡jnp parity of every PricingBreakdown
field, terminal-cut gating, weight-ship amortization, the fixed-seed
evaluate_policy equivalence against the historical per-slot loop, the
unbiased random baseline, and batched (vmapped) training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (A2CConfig, evaluate_policy, init_agent,
                        make_paper_env, make_train_episode, make_tpu_env,
                        env_reset, env_step)
from repro.core import pricing
from repro.core.baselines import random_policy
from repro.policies import build_policy
from repro.core.env import action_breakdown, build_tables
from repro.core.profiles import paper_profiles, transformer_profile
from repro.optim import adamw_init


def _random_view_actions(cfg, tables, seed, n):
    r = np.random.default_rng(seed)
    lp, pw = cfg.latency, cfg.power
    view = pricing.StateView(
        model_id=r.integers(0, tables.n_models, n).astype(np.int32),
        bandwidth=r.uniform(lp.bw_min_bps, lp.bw_max_bps, n)
        .astype(np.float32),
        p_tx=r.uniform(pw.p_tx_min, pw.p_tx_max, n).astype(np.float32),
        queue=np.float32(r.uniform(0.0, 12.0)),
        load=r.uniform(0.0, 1.0, n).astype(np.float32))
    actions = np.stack([r.integers(0, tables.n_versions, n),
                        r.integers(0, tables.n_cuts, n)],
                       axis=-1).astype(np.int32)
    return view, actions


def _assert_breakdowns_match(a, b, rtol, atol):
    for f in dataclasses.fields(pricing.PricingBreakdown):
        x, y = np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name))
        if f.name == "offloaded":
            np.testing.assert_array_equal(x, y, err_msg=f.name)
        else:
            np.testing.assert_allclose(x, y, rtol=rtol, atol=atol,
                                       err_msg=f.name)


@pytest.mark.parametrize("env_kind", ["paper", "tpu_ship"])
@pytest.mark.parametrize("n", [1, 16])
def test_pricing_numpy_jnp_parity(env_kind, n):
    """Identical f32 inputs through xp=np and xp=jnp must agree to 1e-6
    relative on every breakdown field — including the weight-ship
    amortization surcharge (tpu env ships tail weights) and the
    stability score."""
    if env_kind == "paper":
        cfg, tables = make_paper_env(peak_rps=20.0)
    else:
        cfg, tables = make_tpu_env(["qwen2-0.5b"], weight_ship_slots=8.0,
                                   peak_rps=50.0)
        assert cfg.weight_ship_slots > 0    # amortization term in play
    np_tables = pricing.numpy_tables(tables)
    for seed in (0, 1):
        view, actions = _random_view_actions(cfg, tables, seed, n)
        br_np = pricing.price_actions(cfg, np_tables, view, actions, xp=np)
        jview = pricing.StateView(*[jnp.asarray(getattr(view, f.name))
                                    for f in dataclasses.fields(view)])
        br_j = pricing.price_actions(cfg, tables, jview,
                                     jnp.asarray(actions), xp=jnp)
        assert isinstance(br_np.t_total, np.ndarray)
        _assert_breakdowns_match(br_np, br_j, rtol=1e-6, atol=1e-6)


def test_pricing_parity_float64_inputs():
    """The numpy path runs the fleet in float64; against the f32 jnp
    tables the fields still agree to f32 precision."""
    cfg, tables = make_paper_env(peak_rps=20.0)
    np_tables = pricing.numpy_tables(tables)
    view, actions = _random_view_actions(cfg, tables, 3, 8)
    view64 = pricing.StateView(
        model_id=view.model_id,
        bandwidth=view.bandwidth.astype(np.float64),
        p_tx=view.p_tx.astype(np.float64),
        queue=float(view.queue), load=view.load.astype(np.float64))
    br_np = pricing.price_actions(cfg, np_tables, view64, actions, xp=np)
    jview = pricing.StateView(
        model_id=jnp.asarray(view.model_id),
        bandwidth=jnp.asarray(view.bandwidth),
        p_tx=jnp.asarray(view.p_tx),
        queue=jnp.float32(view.queue), load=jnp.asarray(view.load))
    br_j = pricing.price_actions(cfg, tables, jview, jnp.asarray(actions))
    _assert_breakdowns_match(br_np, br_j, rtol=1e-5, atol=1e-5)


def test_terminal_cut_never_pays_queue():
    """A terminal cut (tail == 0) runs fully on-device: not offloaded,
    no Eq. 4 queue wait even when the server is congested."""
    cfg, tables = make_paper_env()
    n = 3
    view = pricing.StateView(
        model_id=np.zeros(n, np.int32),
        bandwidth=np.full(n, cfg.latency.bw_min_bps, np.float32),
        p_tx=np.ones(n, np.float32), queue=10.0, load=0.0)
    last = tables.n_cuts - 1
    term = np.tile(np.asarray([[0, last]], np.int32), (n, 1))
    off = np.tile(np.asarray([[0, 0]], np.int32), (n, 1))
    np_tables = pricing.numpy_tables(tables)
    br_t = pricing.price_actions(cfg, np_tables, view, term, xp=np)
    br_o = pricing.price_actions(cfg, np_tables, view, off, xp=np)
    assert not br_t.offloaded.any()
    np.testing.assert_array_equal(br_t.queue_s, 0.0)
    np.testing.assert_array_equal(br_t.tail_s, 0.0)
    assert br_o.offloaded.all()
    assert (br_o.queue_s > 0.0).all()


def test_env_action_costs_is_pricing_wrapper():
    """env.action_costs must return exactly the breakdown's scores."""
    cfg, tables = make_paper_env(peak_rps=10.0)
    state = env_reset(cfg, tables, jax.random.key(0))
    actions = jnp.asarray([[1, 1], [0, 2], [1, 0]], jnp.int32)
    from repro.core.env import action_costs
    acc_s, lat_s, en_s, t_total, e_infer, stab_s = action_costs(
        cfg, tables, state, actions)
    br = action_breakdown(cfg, tables, state, actions)
    for got, want in ((acc_s, br.acc_score), (lat_s, br.lat_score),
                      (en_s, br.energy_score), (t_total, br.t_total),
                      (e_infer, br.energy_j), (stab_s, br.stab_score)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# evaluate_policy: scanned rollout ≡ the historical per-slot loop
# --------------------------------------------------------------------------

def _reference_evaluate(cfg, tables, policy, rng, episodes):
    """The pre-refactor per-slot Python loop, kept as the oracle for the
    scanned/jitted rewrite (same rng threading, same aggregation)."""
    n = cfg.n_uavs
    hist = np.zeros((tables.n_models, tables.n_versions, tables.n_cuts))
    agg = {k: 0.0 for k in ("reward", "latency", "energy", "acc_score",
                            "lat_score", "en_score", "alive_slots")}
    steps = 0
    for ep in range(episodes):
        rng, k0 = jax.random.split(rng)
        state = env_reset(cfg, tables, k0)
        for t in range(cfg.episode_len):
            rng, k = jax.random.split(rng)
            actions = policy.act(state, jax.random.fold_in(k, 7))
            state, r, info = env_step(cfg, tables, state, actions,
                                      jax.random.fold_in(k, 13))
            a_np = np.asarray(actions)
            m_np = np.asarray(state["model_id"])
            alive = np.asarray(info["alive"])
            for u in range(n):
                if alive[u]:
                    hist[m_np[u], a_np[u, 0], a_np[u, 1]] += 1
            agg["reward"] += float(r)
            agg["latency"] += float(jnp.mean(info["t_total"]))
            agg["energy"] += float(jnp.mean(info["e_infer"]))
            agg["acc_score"] += float(jnp.mean(info["acc_s"]))
            agg["lat_score"] += float(jnp.mean(info["lat_s"]))
            agg["en_score"] += float(jnp.mean(info["en_s"]))
            agg["alive_slots"] += float(jnp.sum(info["alive"]))
            steps += 1
    out = {k: v / steps for k, v in agg.items()}
    out["selection_hist"] = hist
    return out


def test_evaluate_policy_matches_reference_loop():
    """Fixed seed, same policy: the scanned evaluate_policy must
    reproduce the per-slot loop's metrics (float-sum tolerance) and its
    selection histogram exactly."""
    cfg, tables = make_paper_env(episode_len=20)
    rand = build_policy("random", cfg, tables)
    got = evaluate_policy(cfg, tables, rand,
                          jax.random.key(5), episodes=2)
    want = _reference_evaluate(cfg, tables, rand,
                               jax.random.key(5), episodes=2)
    np.testing.assert_array_equal(got["selection_hist"],
                                  want["selection_hist"])
    for k in ("reward", "latency", "energy", "acc_score", "lat_score",
              "en_score", "alive_slots"):
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-6,
                                   err_msg=k)


def test_evaluate_policy_deterministic():
    cfg, tables = make_paper_env(episode_len=16)
    oracle = build_policy("greedy_oracle", cfg, tables)
    a = evaluate_policy(cfg, tables, oracle,
                        jax.random.key(1), episodes=2)
    b = evaluate_policy(cfg, tables, oracle,
                        jax.random.key(1), episodes=2)
    assert a["reward"] == b["reward"]
    np.testing.assert_array_equal(a["selection_hist"], b["selection_hist"])


# --------------------------------------------------------------------------
# random baseline: uniform over each model's valid versions
# --------------------------------------------------------------------------

def test_random_policy_uniform_over_valid_versions():
    """With a 2-version model padded into a 3-version table the old
    randint % nv sampling put 2/3 of the mass on version 0; uniform
    sampling puts 1/2 on each valid version and none on padding."""
    vgg = paper_profiles()["vgg"]                       # 2 versions
    qwen = transformer_profile(                          # 3 versions
        __import__("repro.configs", fromlist=["get_config"])
        .get_config("qwen2-0.5b").reduced(), seq_len=8)
    tables = build_tables([vgg, qwen])
    assert tables.n_versions == 3
    assert int(tables.version_valid[0].sum()) == 2
    cfg, _ = make_paper_env(n_uavs=2)
    state = env_reset(cfg, tables, jax.random.key(0))   # model_ids [0, 1]
    keys = jax.random.split(jax.random.key(42), 4000)
    acts = jax.vmap(lambda k: random_policy(cfg, tables, state, k))(keys)
    v_dev0 = np.asarray(acts[:, 0, 0])                  # model 0: nv = 2
    assert v_dev0.max() <= 1                            # never padding
    frac0 = float(np.mean(v_dev0 == 0))
    assert abs(frac0 - 0.5) < 0.04, frac0               # not the 2/3 bias
    v_dev1 = np.asarray(acts[:, 1, 0])                  # model 1: nv = 3
    for v in range(3):
        assert abs(float(np.mean(v_dev1 == v)) - 1 / 3) < 0.04


# --------------------------------------------------------------------------
# batched training
# --------------------------------------------------------------------------

def test_batched_train_episode_deterministic_and_finite():
    cfg, tables = make_paper_env(episode_len=24)
    ac = A2CConfig(episodes=2, batch_envs=4)
    params = init_agent(cfg, tables, ac, jax.random.key(0))
    opt = adamw_init(params)
    step = make_train_episode(cfg, tables, ac)
    _, _, s1 = step(params, opt, jax.random.key(7))
    _, _, s2 = step(params, opt, jax.random.key(7))
    assert float(s1["loss"]) == float(s2["loss"])
    assert all(np.isfinite(float(v)) for v in s1.values())


def test_batched_train_accepts_per_env_task_seq():
    cfg, tables = make_paper_env(episode_len=24, peak_rps=20.0)
    ac = A2CConfig(episodes=2, batch_envs=3)
    params = init_agent(cfg, tables, ac, jax.random.key(0))
    opt = adamw_init(params)
    step = make_train_episode(cfg, tables, ac)
    r = np.random.default_rng(0)
    seq = jnp.asarray(r.uniform(0, 1, (3, cfg.episode_len, cfg.n_uavs)),
                      jnp.float32)
    _, _, s_env = step(params, opt, jax.random.key(9), seq)
    # distinct per-env traces must actually change the rollout vs a
    # shared 2-D sequence broadcast across envs
    shared = jnp.broadcast_to(seq[0][None], seq.shape)
    _, _, s_shared = step(params, opt, jax.random.key(9), shared)
    assert float(s_env["loss"]) != float(s_shared["loss"])
    _, _, s_2d = step(params, opt, jax.random.key(9), seq[0])
    assert float(s_2d["loss"]) == pytest.approx(float(s_shared["loss"]))


def test_batched_ppo_trains():
    from repro.core import ppo as PPO
    cfg, tables = make_paper_env(episode_len=24)
    _, hist = PPO.train(cfg, tables,
                        PPO.PPOConfig(episodes=3, batch_envs=4),
                        jax.random.key(0))
    assert len(hist) == 3
    assert all(np.isfinite(h["mean_reward"]) for h in hist)

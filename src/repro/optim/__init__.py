from repro.optim.adamw import (adamw_init, adamw_update, AdamWConfig,
                               cosine_schedule, global_norm, clip_by_global_norm)

__all__ = ["adamw_init", "adamw_update", "AdamWConfig", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]

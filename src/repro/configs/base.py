"""Model configuration system.

One ``ModelConfig`` dataclass covers every assigned architecture family:
dense (GQA), MoE (top-k routed + shared experts, MLA), SSM (Mamba-1),
hybrid (RG-LRU + local attention), encoder-decoder audio (Whisper) and
VLM (interleaved cross-attention). Every config file in this package
instantiates one ``ModelConfig`` with the exact assigned hyper-parameters
and cites its source in ``source``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # -- identity -----------------------------------------------------------
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation (arXiv id / model card)

    # -- trunk --------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: Optional[int] = None   # default: d_model // n_heads
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp_act: str = "swiglu"          # swiglu | gelu | geglu
    tie_embeddings: bool = False

    # -- attention ----------------------------------------------------------
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qkv_bias: bool = False           # qwen2-style QKV bias
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    sliding_window: Optional[int] = None   # SWA window; None = full attention
    attn_bias: bool = False          # bias on all attn projections (whisper)

    # -- MLA (deepseek-v2) ----------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    mla_absorb: bool = False         # decode-time weight absorption (opt)

    # -- MoE ------------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_dense_layers: int = 0      # deepseek: leading dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "einsum"         # einsum (GShard baseline) | gather (opt)
    moe_chunk: int = 1024            # dispatch chunk (perf knob)

    # -- SSM (mamba-1) ----------------------------------------------------------
    ssm: bool = False
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: Optional[int] = None    # default ceil(d_model / 16)

    # -- hybrid (RG-LRU, recurrentgemma) -----------------------------------
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn")
    lru_width: Optional[int] = None        # default d_model
    local_window: int = 2048

    # -- encoder-decoder (whisper) ------------------------------------------
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frame-embedding count

    # -- VLM (llama-3.2-vision) ----------------------------------------------
    cross_attn_every: int = 0        # insert one cross-attn layer every N
    n_media_tokens: int = 0          # stub patch-embedding count

    # -- numerics -------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_q_chunk: int = 512          # chunked-attention tile sizes (perf knobs)
    attn_kv_chunk: int = 1024
    attn_causal_skip: bool = False   # skip fully-masked kv blocks (perf)
    train_remat: bool = True         # activation checkpointing in train
    fsdp: bool = False               # ZeRO-3-style: shard param "embed" dims
                                     # over the data axis (all-gather at use)

    # -- EdgeRL execution-profile metadata -------------------------------------
    #   versions: quantization levels of this model available as EdgeRL
    #   versions (repro.quant registry names; paper analogue: VGG11/19).
    #   cut_points resolved at runtime from layer profiles (core/profiles.py).
    versions: Tuple[str, ...] = ("bf16", "w8", "w4")

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def resolved_dt_rank(self) -> int:
        if self.dt_rank is not None:
            return self.dt_rank
        return -(-self.d_model // 16)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width if self.lru_width is not None else self.d_model

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the decoder trunk.

        Kinds: "attn" (self-attention block), "rec" (RG-LRU block),
        "ssm" (mamba block), "xattn" (cross-attention block).
        """
        if self.ssm:
            return ("ssm",) * self.n_layers
        if self.block_pattern:
            p = self.block_pattern
            return tuple(p[i % len(p)] for i in range(self.n_layers))
        if self.cross_attn_every:
            kinds = []
            for i in range(self.n_layers):
                # every Nth slot is a gated cross-attention block
                if (i + 1) % self.cross_attn_every == 0:
                    kinds.append("xattn")
                else:
                    kinds.append("attn")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def active_params_per_token_factor(self) -> float:
        """Fraction of MoE expert params active per token (1.0 for dense)."""
        if not self.moe or self.n_experts == 0:
            return 1.0
        return (self.top_k + self.n_shared_experts) / (
            self.n_experts + self.n_shared_experts
        )

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers (per stack), d_model<=512, <=4 experts."""
        kw = dict(
            n_layers=max(2, min(2, self.n_layers)),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.resolved_head_dim >= 64 else self.resolved_head_dim,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.moe:
            kw.update(
                n_experts=min(self.n_experts, 4),
                top_k=min(self.top_k, 2),
                moe_d_ff=min(self.moe_d_ff, 256),
                first_dense_layers=min(self.first_dense_layers, 1),
                # non-dropping capacity: capacity-based routing drops tokens
                # chunk-dependently, which would make prefill-vs-decode
                # consistency checks impossible (production keeps 1.25)
                capacity_factor=float(self.n_experts) / max(self.top_k, 1),
            )
        if self.use_mla:
            kw.update(kv_lora_rank=64, qk_rope_head_dim=16,
                      qk_nope_head_dim=32, v_head_dim=32)
        if self.ssm:
            kw.update(ssm_state=8, dt_rank=16)
        if self.block_pattern:
            # keep one full period plus remainder handling exercised
            kw.update(n_layers=max(2, len(self.block_pattern)),
                      lru_width=min(self.resolved_lru_width, 256),
                      local_window=64)
        if self.enc_dec:
            kw.update(n_encoder_layers=2, encoder_seq=16)
        if self.cross_attn_every:
            kw.update(n_layers=4, cross_attn_every=2, n_media_tokens=8)
        if self.sliding_window is not None:
            kw.update(sliding_window=min(self.sliding_window, 64))
        return self.with_overrides(**kw)


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro.configs import ALL_ARCHS  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    from repro.configs import ALL_ARCHS  # noqa: F401

    return dict(_REGISTRY)

"""whisper-large-v3 [audio] — encoder-decoder, conv frontend STUB.

32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA, kv=20),
gelu MLP, layernorm, attention biases, sinusoidal positions (no RoPE).
The mel-spectrogram + conv feature extractor is a STUB: ``input_specs``
supplies 1500 precomputed frame embeddings. [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356 (Whisper) / hf:openai/whisper-large-v3",
    n_layers=32,             # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    head_dim=64,
    enc_dec=True,
    n_encoder_layers=32,
    encoder_seq=1500,
    use_rope=False,
    attn_bias=True,
    norm="layernorm",
    mlp_act="gelu",
))

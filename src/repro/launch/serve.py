"""Serving launcher: batched generation through the ServingEngine, with
optional EdgeRL split routing (see examples/split_serving.py for the
controller-in-the-loop version).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.models import init
from repro.serving import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, temperature=args.temperature))
    toks = (jnp.arange(args.batch * args.prompt_len, dtype=jnp.int32)
            .reshape(args.batch, args.prompt_len) * 101) % cfg.vocab_size
    batch = {"tokens": toks}
    if cfg.cross_attn_every:
        batch["media"] = jnp.zeros((args.batch, cfg.n_media_tokens,
                                    cfg.d_model))
    if cfg.enc_dec:
        batch["enc_frames"] = jnp.zeros((args.batch, cfg.encoder_seq,
                                         cfg.d_model))
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({dt/args.new_tokens*1e3:.1f} ms/token incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {list(map(int, out[b]))}")


if __name__ == "__main__":
    main()

"""``run_scenario``: the single experiment entry point.

Builds the scenario's world once, resolves every requested policy
through the canonical registry (training — or loading a saved artifact —
where the spec is trainable), and simulates each policy over the *same*
seeds, so comparisons are paired by construction: two policies under one
seed face the identical request stream.

Nonstationary scenarios (``scenario.drift``) run every policy under the
same ``WorldSchedule``. A roster entry ``"<name>+online"`` (e.g.
``"a2c+online"``) runs the trainable policy with closed-loop online
adaptation (``repro.online``): it shares the pre-drift trained
parameters with its frozen sibling (train once, adapt a copy), restarts
from them for every seed, and reports per-regime adaptation metrics —
regret vs the per-regime greedy oracle and recovery time — in its
``PolicyResult.adaptation``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.policies import get_policy_spec
from repro.scenarios.base import Scenario
from repro.sim import FleetConfig, simulate

_TABLE_HEADER = (f"{'policy':14s} {'requests':>9s} {'p50_s':>8s} "
                 f"{'p95_s':>8s} {'p99_s':>8s} {'slo_att':>8s} "
                 f"{'goodput':>8s} {'E/req_J':>8s} {'drop':>6s}")

def split_policy_name(name: str) -> Tuple[str, bool]:
    """``"a2c+online" -> ("a2c", True)``; any other ``+suffix`` is an
    error (fail before building an env for a typo'd roster)."""
    base, sep, suffix = name.partition("+")
    if not sep:
        return name, False
    if suffix != "online":
        raise KeyError(f"unknown policy modifier {'+' + suffix!r} in "
                       f"{name!r}; the only modifier is '+online'")
    return base, True


@dataclasses.dataclass
class PolicyResult:
    """One policy's paired-seed outcome inside a ComparisonReport."""
    name: str
    mean: Dict[str, float]
    per_seed: List[Dict]
    trained: bool = False
    loaded_from: Optional[str] = None
    saved_to: Optional[str] = None
    cross_check: Optional[Dict] = None
    # seed-averaged drift/adaptation metrics (nonstationary scenarios):
    # per-regime mean reward / oracle / regret / recovery_epochs, plus
    # online-learner counters for "+online" entries
    adaptation: Optional[Dict] = None
    # SLO error budgets (timeline runs): seed-mean + per-seed summaries
    # from repro.obs.slo (target, attainment, budget_remaining, alerts)
    slo: Optional[Dict] = None
    # timeline runs: one repro.obs.timeline.Timeline per seed (kept as
    # live objects; simulate.py --timeline-out serializes them)
    timelines: List = dataclasses.field(default_factory=list)

    def row(self) -> str:
        m = self.mean
        return (f"{self.name:14s} {m['count']:9.0f} {m['p50']:8.3f} "
                f"{m['p95']:8.2f} {m['p99']:8.2f} "
                f"{m['slo_attainment']:8.3f} {m['goodput']:8.1f} "
                f"{m['energy_per_request_j']:8.3f} {m['dropped']:6.0f}")


@dataclasses.dataclass
class ComparisonReport:
    """Paired-seed comparison of N policies under one scenario."""
    scenario: str
    seeds: Tuple[int, ...]
    n_requests: int
    trace: str
    results: Dict[str, PolicyResult]     # insertion-ordered
    schedule: Optional[str] = None       # drift schedule name, if any

    def table(self) -> str:
        return "\n".join([_TABLE_HEADER]
                         + [r.row() for r in self.results.values()])

    def adaptation_table(self) -> str:
        """Per-regime adaptation metrics for every policy that has them
        (empty string for stationary scenarios)."""
        lines = []
        for r in self.results.values():
            if not r.adaptation:
                continue
            lines.append(f"{r.name}: mean_reward="
                         f"{r.adaptation['mean_reward']:+.3f} "
                         f"regret={r.adaptation['regret']:.3f}"
                         + (f" updates={r.adaptation['online']['updates']}"
                            f" bursts={r.adaptation['online']['bursts']}"
                            if r.adaptation.get("online") else ""))
            for reg in r.adaptation["regimes"]:
                rec = reg["recovery_epochs"]
                lines.append(
                    f"  regime {reg['regime']} ({reg['name']}): "
                    f"reward={reg['mean_reward']:+.3f} "
                    f"oracle={reg['oracle_reward']:+.3f} "
                    f"regret={reg['regret']:.3f} recovery="
                    + ("never" if rec is None else f"{rec:.0f} epochs"))
        return "\n".join(lines)

    def to_json(self) -> Dict:
        out = {"scenario": self.scenario, "seeds": list(self.seeds),
               "n_requests": self.n_requests, "trace": self.trace,
               "policies": {}}
        if self.schedule:
            out["schedule"] = self.schedule
        for name, r in self.results.items():
            entry = {"mean": r.mean, "per_seed": r.per_seed,
                     "trained": r.trained}
            if r.loaded_from:
                entry["loaded_from"] = r.loaded_from
            if r.saved_to:
                entry["saved_to"] = r.saved_to
            if r.adaptation:
                entry["adaptation"] = r.adaptation
            if r.slo:
                entry["slo"] = r.slo
            if r.cross_check:
                entry["cross_check"] = {k: v for k, v in
                                        r.cross_check.items()
                                        if k != "records"}
            out["policies"][name] = entry
        return out


def _strip_series(adapt: Dict) -> Dict:
    """Per-seed adaptation dict without the per-epoch reward series
    (SimResult keeps them; the report stores summaries)."""
    out = dict(adapt)
    out["regimes"] = [{k: v for k, v in reg.items()
                       if k not in ("rewards", "oracle")}
                      for reg in adapt["regimes"]]
    return out


def _mean_adaptation(per_seed: List[Dict]) -> Dict:
    """Seed-average the adaptation summaries: scalar fields averaged,
    per-regime entries averaged by regime index, recovery averaged over
    the seeds that recovered (None if none did)."""
    out = {k: float(np.mean([a[k] for a in per_seed]))
           for k in ("mean_reward", "oracle_reward", "regret")}
    out["schedule"] = per_seed[0].get("schedule")
    regimes = []
    # regimes reached differ per seed (epoch count to serve n_requests
    # is seed-dependent): aggregate over the union, averaging each
    # regime over the seeds that reached it
    n_regimes = max(len(a["regimes"]) for a in per_seed)
    for i in range(n_regimes):
        regs = [a["regimes"][i] for a in per_seed
                if i < len(a["regimes"])]
        entry = {"regime": regs[0]["regime"], "name": regs[0]["name"],
                 "start_epoch": regs[0]["start_epoch"],
                 "seeds_reached": len(regs)}
        for k in ("mean_reward", "oracle_reward", "regret"):
            entry[k] = float(np.mean([r[k] for r in regs]))
        recs = [r["recovery_epochs"] for r in regs
                if r["recovery_epochs"] is not None]
        entry["recovery_epochs"] = float(np.mean(recs)) if recs else None
        entry["recovered_seeds"] = len(recs)
        regimes.append(entry)
    out["regimes"] = regimes
    online = [a["online"] for a in per_seed if a.get("online")]
    if online:
        out["online"] = dict(
            online[0],
            updates=float(np.mean([o["updates"] for o in online])),
            triggers=float(np.mean([o["triggers"] for o in online])),
            bursts=float(np.mean([o["bursts"] for o in online])))
    out["per_seed"] = [_strip_series(a) for a in per_seed]
    return out


def run_scenario(scenario: Scenario,
                 policies: Optional[Sequence[str]] = None, *,
                 n_requests: Optional[int] = None,
                 seeds: Optional[Sequence[int]] = None,
                 episodes: Optional[int] = None,
                 load_policies: Optional[Mapping[str, str]] = None,
                 save_policies: Optional[Mapping[str, str]] = None,
                 verbose: bool = False,
                 timeline: bool = False) -> ComparisonReport:
    """Run ``policies`` (default: the scenario's own roster) through the
    scenario; returns a paired-seed ComparisonReport.

    ``load_policies``/``save_policies`` map policy name -> artifact path:
    a mapped trainable policy loads instead of training (identical
    paired-seed metrics to the run that saved it, no retraining), and
    saves right after training. ``n_requests``/``seeds``/``episodes``
    override the scenario without mutating it.

    ``timeline=True`` turns on the flight recorder for every simulation
    (``FleetConfig.timeline``): each ``PolicyResult`` carries one
    ``repro.obs.timeline.Timeline`` per seed plus the SLO error-budget
    summaries — results stay bit-identical to a recording-off run.
    """
    names = tuple(policies) if policies else scenario.policies
    parsed = [split_policy_name(n) for n in names]
    specs = [get_policy_spec(b) for b, _ in parsed]   # fail fast on typos
    for (base, is_online), spec in zip(parsed, specs):
        if is_online and not spec.trainable:
            raise KeyError(f"policy {base!r} is not trainable; '+online' "
                           "adaptation needs a trainable policy (a2c, ppo)")
    seeds = tuple(seeds) if seeds is not None else scenario.seeds
    n_req = int(n_requests) if n_requests is not None \
        else scenario.n_requests
    eps = int(episodes) if episodes is not None else scenario.episodes

    with obs.span("scenario.build", scenario=scenario.name):
        env_cfg, tables, model_ids, backend_factory = scenario.build_env()
        trace = scenario.build_trace()
        schedule = scenario.build_schedule()
        autoscaler = scenario.build_autoscaler()
    fleet = FleetConfig(slo_s=scenario.slo_s, engine=scenario.engine,
                        timeline=timeline,
                        slo_target=scenario.slo_target)

    # verbose routes the narration at info level (console by default,
    # silenced by --quiet); non-verbose runs still record it at debug,
    # so a traced run keeps its story in the JSONL either way
    say = obs.info if verbose else obs.debug
    say(f"scenario {scenario.name}: {scenario.devices} devices "
        f"({scenario.env} env), trace={trace.name} "
        f"(mean {trace.mean_rps:.1f} rps/device), "
        f"slo={scenario.slo_s}s, requests={n_req} x seeds "
        f"{list(seeds)}"
        + (f", drift={schedule.name} "
           f"(boundaries {list(schedule.boundaries)})"
           if schedule else ""))

    results: Dict[str, PolicyResult] = {}
    trained_params: Dict[str, object] = {}   # base name -> pre-drift params
    header_printed = False
    for name, (base, is_online), spec in zip(names, parsed, specs):
        kw = {}
        if spec.trainable:
            kw = dict(episodes=eps, entropy_coef=scenario.entropy_coef,
                      batch_envs=scenario.batch_envs)
        policy = spec.build(env_cfg, tables, **kw)
        trained, loaded_from, saved_to = False, None, None
        if spec.trainable:
            loaded_from = (load_policies or {}).get(name) \
                or (load_policies or {}).get(base)
            if base in trained_params:
                # the frozen and "+online" variants of one controller
                # share a single pre-drift training run by construction
                policy.set_params(trained_params[base])
                loaded_from = loaded_from or f"(shared: {base})"
                say(f"{name}: sharing {base}'s trained parameters")
            elif loaded_from:
                policy.load(loaded_from)
                say(f"{name}: loaded artifact {loaded_from}")
            else:
                say(f"{name}: training ({eps} episodes) ...")
                with obs.span("scenario.train", policy=name, episodes=eps):
                    hist = policy.train(
                        seed=scenario.train_seed,
                        trace=scenario.build_train_trace())
                trained = True
                last = np.mean([h["mean_reward"] for h in hist[-15:]])
                say(f"  trained: mean reward (last 15 episodes) = "
                    f"{last:+.3f}")
            shared = base in trained_params and not trained \
                and (loaded_from or "").startswith("(shared")
            trained_params.setdefault(base, policy.params)
            saved_to = (save_policies or {}).get(name) \
                or (save_policies or {}).get(base)
            if saved_to and shared:
                saved_to = None      # the sibling entry owns the artifact
            if saved_to:
                policy.save(saved_to)
                say(f"{name}: saved artifact {saved_to}")

        online_cfg = scenario.build_online(
            algo=getattr(policy, "algo", "a2c")) if is_online else None
        snapshot = policy.params if spec.trainable else None
        per_seed, per_adapt, cross = [], [], None
        timelines, per_slo = [], []
        for seed in seeds:
            if is_online and snapshot is not None:
                # every seed adapts from the same pre-drift parameters
                policy.set_params(snapshot)
            with obs.span("scenario.simulate", policy=name, seed=seed):
                res = simulate(env_cfg, tables, policy, trace,
                               n_requests=n_req, seed=seed, fleet=fleet,
                               backend=backend_factory(),
                               model_ids=model_ids,
                               schedule=schedule, online=online_cfg,
                               autoscaler=autoscaler)
            per_seed.append(res.summary)
            if res.adaptation is not None:
                per_adapt.append(res.adaptation)
            if res.timeline is not None:
                timelines.append(res.timeline)
                if res.timeline.slo_report is not None:
                    per_slo.append(res.timeline.slo_report.summary())
            cross = res.cross_check or cross
        if is_online and snapshot is not None:
            policy.set_params(snapshot)      # leave pre-drift params
        mean = {k: float(np.mean([s[k] for s in per_seed]))
                for k in per_seed[0] if k != "unit"}
        slo = None
        if per_slo:
            # seed-mean the scalar fields; time_to_exhaustion may be
            # None (never exhausts) on some seeds — average the rest
            slo_mean = {}
            for k in per_slo[0]:
                vals = [s[k] for s in per_slo
                        if isinstance(s[k], (int, float))]
                slo_mean[k] = float(np.mean(vals)) if vals else None
            slo = {"mean": slo_mean, "per_seed": per_slo}
        results[name] = PolicyResult(
            name=name, mean=mean, per_seed=per_seed, trained=trained,
            loaded_from=loaded_from, saved_to=saved_to, cross_check=cross,
            adaptation=_mean_adaptation(per_adapt) if per_adapt else None,
            slo=slo, timelines=timelines)
        if not header_printed:
            say("\n" + _TABLE_HEADER)
            header_printed = True
        say(results[name].row())

    report = ComparisonReport(scenario=scenario.name, seeds=seeds,
                              n_requests=n_req, trace=trace.name,
                              results=results,
                              schedule=schedule.name if schedule else None)
    if schedule:
        say("\nadaptation metrics (per regime):")
        say(report.adaptation_table())
    return report

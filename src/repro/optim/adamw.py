"""AdamW + schedules in pure JAX (no optax in this environment).

Optimizer state is a pytree mirroring params: {"m", "v"} in f32 plus a
scalar step. ``adamw_update`` is jit/pjit-friendly: purely functional,
works under sharded params (m/v inherit param shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                     state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}

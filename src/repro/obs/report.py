"""Fold a run's events into a timing report.

``fold(events)`` aggregates span events by name (count / total / mean /
min / max), collects point events, logs, metrics, the drift/online
timeline and the JAX compile summary into one JSON-serializable dict;
``render(report)`` turns it into the aligned text tables
``scripts/obsview.py`` prints.

Span totals are wall-time sums per span *name*: nested spans overlap
their parents (``fleet.decide`` time is inside ``fleet.epoch`` time),
so the per-phase shares are each phase's fraction of the run wall —
they intentionally do not sum to 100%.
"""
from __future__ import annotations

from typing import Dict, List, Optional

# event names folded into the drift/adaptation timeline: drift regime
# machinery, online adaptation, autoscaler decisions, SLO error-budget
# alerts, and timeline bookkeeping events
_TIMELINE_PREFIXES = ("drift.", "online.", "autoscale.", "slo.",
                      "timeline.")


def fold(events: List[Dict], meta: Optional[Dict] = None) -> Dict:
    spans: Dict[str, Dict] = {}
    counts: Dict[str, int] = {}
    timeline: List[Dict] = []
    metrics: List[Dict] = []
    jax_summary: Optional[Dict] = None
    logs = 0
    wall = 0.0
    for ev in events:
        t = float(ev.get("t", 0.0))
        typ = ev.get("type")
        if typ == "span":
            dur = float(ev.get("dur", 0.0))
            wall = max(wall, t + dur)
            s = spans.setdefault(ev["name"], {
                "count": 0, "total_s": 0.0, "min_s": dur, "max_s": dur,
                "depth": ev.get("depth", 0)})
            s["count"] += 1
            s["total_s"] += dur
            s["min_s"] = min(s["min_s"], dur)
            s["max_s"] = max(s["max_s"], dur)
        elif typ == "event":
            wall = max(wall, t)
            counts[ev["name"]] = counts.get(ev["name"], 0) + 1
            if ev["name"].startswith(_TIMELINE_PREFIXES):
                timeline.append({"t": t, "name": ev["name"],
                                 "attrs": ev.get("attrs", {})})
        elif typ == "log":
            logs += 1
        elif typ == "metric":
            metrics.append({k: v for k, v in ev.items()
                            if k not in ("type", "seq", "t")})
        elif typ == "jax":
            jax_summary = {"compile": ev.get("compile", {}),
                           "traces": ev.get("traces", {})}
    for s in spans.values():
        s["mean_us"] = s["total_s"] / s["count"] * 1e6
        s["share"] = s["total_s"] / wall if wall > 0 else 0.0
    return {"meta": dict(meta or {}), "wall_s": wall,
            "phases": spans, "events": counts, "timeline": timeline,
            "logs": logs, "metrics": metrics, "jax": jax_summary}


def load(path: str) -> Dict:
    """events.jsonl -> folded report."""
    from repro.obs.events import read_events
    meta, events = read_events(path)
    return fold(events, meta=meta.get("meta"))


# --------------------------------------------------------------------------
# rendering
# --------------------------------------------------------------------------

def phase_table(report: Dict) -> str:
    """Per-phase timing breakdown, widest total first."""
    rows = sorted(report["phases"].items(),
                  key=lambda kv: -kv[1]["total_s"])
    if not rows:
        return "(no spans recorded)"
    lines = [f"{'span':24s} {'count':>7s} {'total_s':>9s} {'mean_us':>10s} "
             f"{'min_us':>10s} {'max_us':>10s} {'%wall':>6s}"]
    for name, s in rows:
        lines.append(
            f"{name:24s} {s['count']:7d} {s['total_s']:9.3f} "
            f"{s['mean_us']:10.1f} {s['min_s']*1e6:10.1f} "
            f"{s['max_s']*1e6:10.1f} {s['share']*100:6.1f}")
    return "\n".join(lines)


def timeline_table(report: Dict, limit: int = 40) -> str:
    """Drift/online events in time order (regime switches, triggers,
    bursts, hot-swaps)."""
    tl = report["timeline"]
    if not tl:
        return "(no drift/online events)"
    lines = []
    for e in tl[:limit]:
        attrs = " ".join(f"{k}={v}" for k, v in e["attrs"].items())
        lines.append(f"  t={e['t']:9.3f}s {e['name']:24s} {attrs}")
    if len(tl) > limit:
        lines.append(f"  ... {len(tl) - limit} more")
    return "\n".join(lines)


def jax_table(report: Dict) -> str:
    j = report.get("jax")
    if not j:
        return "(no jax accounting)"
    c = j.get("compile", {})
    lines = []
    for phase in ("jaxpr_trace", "mlir_lower", "backend_compile"):
        n = c.get(phase + "_n", 0)
        s = c.get(phase + "_s", 0.0)
        lines.append(f"  {phase:18s} n={int(n):5d} total={s:8.3f}s")
    tr = j.get("traces", {})
    if tr:
        lines.append("  jit traces by site:")
        for site, n in sorted(tr.items()):
            lines.append(f"    {site:30s} {n}")
    return "\n".join(lines)


def metrics_table(report: Dict) -> str:
    ms = report["metrics"]
    if not ms:
        return "(no metrics)"
    lines = []
    for m in ms:
        labels = ",".join(f"{k}={v}" for k, v in m.get("labels", {}).items())
        name = m["name"] + (f"{{{labels}}}" if labels else "")
        if m["kind"] == "histogram":
            lines.append(f"  {name:40s} n={m['count']:<6d} "
                         f"mean={m['mean']:.3f} p50={m['p50']:.3f} "
                         f"p95={m['p95']:.3f} p99={m['p99']:.3f} "
                         f"max={m['max']:.3f}")
        else:
            lines.append(f"  {name:40s} {m['kind']}={m['value']:g}")
    return "\n".join(lines)


def render(report: Dict) -> str:
    parts = [f"wall: {report['wall_s']:.3f}s   spans: "
             f"{sum(s['count'] for s in report['phases'].values())}   "
             f"events: {sum(report['events'].values())}   "
             f"logs: {report['logs']}",
             "", "per-phase timing:", phase_table(report)]
    if report["timeline"]:
        parts += ["", "drift/online timeline:", timeline_table(report)]
    if report["metrics"]:
        parts += ["", "metrics:", metrics_table(report)]
    if report.get("jax"):
        parts += ["", "jax compile accounting:", jax_table(report)]
    return "\n".join(parts)

"""DNN execution profiles: per-layer FLOPs / activation bytes / params.

The paper profiles VGG{11,19}, ResNet{18,50}, DenseNet{121,161} on a Jetson
TX2 and picks 4 candidate cut points per version (Table I). This container
has no Jetson, so profiles are derived *analytically* from the architectures
(224x224x3 ImageNet input, op-level enumeration mirroring torchvision's
features+classifier indexing so Table I indices land on meaningful ops).
Accuracies are the published ImageNet top-1 numbers.

The same ``ModelProfile`` abstraction also wraps the assigned transformer
architectures (built from ModelConfig) so the EdgeRL controller can pick
(version, cut) for them too — that is the TPU adaptation path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

BYTES_PER_ELT = 4  # fp32 activations on-device (TX2 regime)


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    name: str
    flops: float          # FLOPs to execute this op (per frame)
    out_bytes: float      # activation bytes leaving this op
    params: int
    weight_bytes: float = 0.0   # actual weight bytes of this op under the
                                # owning version (0 -> derive from params)


@dataclasses.dataclass(frozen=True)
class VersionProfile:
    model: str
    version: str
    accuracy: float                   # top-1, [0,1]
    layers: Tuple[LayerProfile, ...]
    cut_points: Tuple[int, ...]       # candidate cut layer indices (Table I)
    bytes_per_param: float = 4.0      # weight-shipping cost (quant versions <4)

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def total_flops(self) -> float:
        return float(sum(l.flops for l in self.layers))

    def head_flops(self, cut: int) -> float:
        return float(sum(l.flops for l in self.layers[:cut]))

    def tail_flops(self, cut: int) -> float:
        return float(sum(l.flops for l in self.layers[cut:]))

    def cut_bytes(self, cut: int) -> float:
        if cut <= 0:
            # full offload: ship the input frame
            return 224 * 224 * 3 * BYTES_PER_ELT
        if cut >= len(self.layers):
            return 16.0   # just the class id
        return self.layers[cut - 1].out_bytes

    def tail_weight_bytes(self, cut: int) -> float:
        """Bytes to place this version's tail on the server — the
        weight-shipping side of a (version, cut) switch. Uses per-layer
        measured weight_bytes when the profile provides them (quantized
        transformer versions price only the dense share at the reduced
        width); otherwise params x bytes_per_param (CNN paper profiles)."""
        tail = self.layers[cut:]
        wb = float(sum(l.weight_bytes for l in tail))
        if wb > 0:
            return wb
        return float(sum(l.params for l in tail)) * self.bytes_per_param


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    versions: Tuple[VersionProfile, ...]


# --------------------------------------------------------------------------
# CNN shape inference (conv / pool / fc ops)
# --------------------------------------------------------------------------

def _conv(name, cin, cout, k, s, hw, p=None):
    """Returns (layer, new_hw)."""
    pad = k // 2 if p is None else p
    out = (hw + 2 * pad - k) // s + 1
    flops = 2.0 * k * k * cin * cout * out * out
    return LayerProfile(name, flops, cout * out * out * BYTES_PER_ELT,
                        k * k * cin * cout + cout), out


def _act(name, c, hw):
    n = c * hw * hw
    return LayerProfile(name, float(n), n * BYTES_PER_ELT, 0)


def _pool(name, c, hw, k=2, s=2):
    out = hw // s
    return LayerProfile(name, float(c * out * out * k * k),
                        c * out * out * BYTES_PER_ELT, 0), out


def _fc(name, din, dout):
    return LayerProfile(name, 2.0 * din * dout, dout * BYTES_PER_ELT,
                        din * dout + dout)


# -- VGG (torchvision features indexing: conv,relu,[pool]) ------------------

_VGG_CFG = {
    "11": [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    "19": [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
           512, 512, 512, 512, "M", 512, 512, 512, 512, "M"],
}


def _vgg(version: str) -> List[LayerProfile]:
    layers, cin, hw = [], 3, 224
    for v in _VGG_CFG[version]:
        if v == "M":
            l, hw = _pool(f"pool{len(layers)}", cin, hw)
            layers.append(l)
        else:
            l, hw = _conv(f"conv{len(layers)}", cin, v, 3, 1, hw)
            layers.append(l)
            layers.append(_act(f"relu{len(layers)}", v, hw))
            cin = v
    # classifier: fc-relu-fc-relu-fc (dropouts folded out)
    layers.append(_fc("fc1", cin * 7 * 7, 4096))
    layers.append(LayerProfile("relu_fc1", 4096.0, 4096 * BYTES_PER_ELT, 0))
    layers.append(_fc("fc2", 4096, 4096))
    layers.append(LayerProfile("relu_fc2", 4096.0, 4096 * BYTES_PER_ELT, 0))
    layers.append(_fc("fc3", 4096, 1000))
    return layers


# -- ResNet (block-level enumeration) ---------------------------------------

_RESNET_CFG = {
    "18": ("basic", [2, 2, 2, 2]),
    "50": ("bottleneck", [3, 4, 6, 3]),
}


def _resnet(version: str) -> List[LayerProfile]:
    kind, blocks = _RESNET_CFG[version]
    layers: List[LayerProfile] = []
    hw = 224
    l, hw = _conv("stem_conv", 3, 64, 7, 2, hw, p=3)
    layers.append(l)
    layers.append(_act("stem_relu", 64, hw))
    l, hw = _pool("stem_pool", 64, hw, k=3, s=2)
    layers.append(l)
    cin = 64
    widths = [64, 128, 256, 512]
    for stage, (w, n) in enumerate(zip(widths, blocks)):
        for b in range(n):
            s = 2 if (stage > 0 and b == 0) else 1
            if kind == "basic":
                l1, hw2 = _conv(f"s{stage}b{b}c1", cin, w, 3, s, hw)
                l2, _ = _conv(f"s{stage}b{b}c2", w, w, 3, 1, hw2)
                flops = l1.flops + l2.flops
                cout = w
            else:
                l1, hw1 = _conv(f"s{stage}b{b}c1", cin, w, 1, 1, hw, p=0)
                l2, hw2 = _conv(f"s{stage}b{b}c2", w, w, 3, s, hw1)
                l3, _ = _conv(f"s{stage}b{b}c3", w, 4 * w, 1, 1, hw2, p=0)
                flops = l1.flops + l2.flops + l3.flops
                cout = 4 * w
            if s == 2 or cin != cout:
                ld, _ = _conv(f"s{stage}b{b}ds", cin, cout, 1, s, hw, p=0)
                flops += ld.flops
            hw = hw // s
            layers.append(LayerProfile(
                f"s{stage}b{b}", flops, cout * hw * hw * BYTES_PER_ELT, 0))
            cin = cout
    layers.append(LayerProfile("gap", float(cin * hw * hw),
                               cin * BYTES_PER_ELT, 0))
    layers.append(_fc("fc", cin, 1000))
    return layers


# -- DenseNet (dense-block-level enumeration: 14 coarse ops) ----------------

_DENSENET_CFG = {
    "121": (32, [6, 12, 24, 16], 64),
    "161": (48, [6, 12, 36, 24], 96),
}


def _densenet(version: str) -> List[LayerProfile]:
    growth, blocks, init = _DENSENET_CFG[version]
    layers: List[LayerProfile] = []
    hw = 224
    l, hw = _conv("stem_conv", 3, init, 7, 2, hw, p=3)
    layers.append(l)
    layers.append(_act("stem_relu", init, hw))
    l, hw = _pool("stem_pool", init, hw, k=3, s=2)
    layers.append(l)
    cin = init
    for i, n in enumerate(blocks):
        flops = 0.0
        for b in range(n):
            l1, _ = _conv(f"d{i}b{b}c1", cin + b * growth, 4 * growth, 1, 1,
                          hw, p=0)
            l2, _ = _conv(f"d{i}b{b}c2", 4 * growth, growth, 3, 1, hw)
            flops += l1.flops + l2.flops
        cin = cin + n * growth
        layers.append(LayerProfile(f"dense{i}", flops,
                                   cin * hw * hw * BYTES_PER_ELT, 0))
        if i < len(blocks) - 1:
            lt, _ = _conv(f"t{i}", cin, cin // 2, 1, 1, hw, p=0)
            cin = cin // 2
            hw = hw // 2
            layers.append(LayerProfile(
                f"trans{i}", lt.flops, cin * hw * hw * BYTES_PER_ELT, 0))
        else:
            layers.append(LayerProfile("final_norm", float(cin * hw * hw),
                                       cin * hw * hw * BYTES_PER_ELT, 0))
    layers.append(LayerProfile("gap", float(cin * hw * hw),
                               cin * BYTES_PER_ELT, 0))
    layers.append(_fc("fc", cin, 1000))
    return layers


# --------------------------------------------------------------------------
# paper profiles (Table I cut points, published top-1 accuracies)
# --------------------------------------------------------------------------

_PAPER_ACC = {
    ("vgg", "11"): 0.690, ("vgg", "19"): 0.724,
    ("resnet", "18"): 0.698, ("resnet", "50"): 0.761,
    ("densenet", "121"): 0.744, ("densenet", "161"): 0.771,
}

_TABLE_I = {
    ("vgg", "11"): (3, 6, 11, 27),
    ("vgg", "19"): (5, 10, 19, 43),
    ("resnet", "18"): (4, 15, 20, 49),
    ("resnet", "50"): (4, 13, 20, 115),
    ("densenet", "121"): (4, 6, 8, 14),
    ("densenet", "161"): (4, 6, 8, 14),
}

_BUILDERS = {"vgg": _vgg, "resnet": _resnet, "densenet": _densenet}


def _clip_cuts(cuts: Sequence[int], n: int) -> Tuple[int, ...]:
    """Map Table I cut indices onto our op enumeration.

    The paper indexes torchvision's op-level module list; our profiles
    enumerate at (coarser) block level for ResNet/DenseNet. When the
    table's deepest index exceeds our layer count, map indices
    proportionally so each candidate lands at the same fractional depth.
    """
    if max(cuts) > n:
        scale = n / max(cuts)
        mapped = [max(1, round(c * scale)) for c in cuts]
        # de-duplicate while preserving order/monotonicity
        out = []
        for c in mapped:
            while c in out and c < n:
                c += 1
            out.append(min(c, n))
        return tuple(out)
    return tuple(min(c, n) for c in cuts)


# (model, version) pairs of the paper's Table I, in table order —
# public so the benchmark harness can build (and time) each version's
# profile individually
PAPER_VERSIONS: Tuple[Tuple[str, str], ...] = tuple(_TABLE_I)


def paper_version_profile(model: str, version: str) -> VersionProfile:
    """Build one paper model version's layer profile + Table I cuts."""
    layers = tuple(_BUILDERS[model](version))
    cuts = _clip_cuts(_TABLE_I[(model, version)], len(layers))
    return VersionProfile(model, version, _PAPER_ACC[(model, version)],
                          layers, cuts)


def paper_profiles() -> Dict[str, ModelProfile]:
    out = {}
    for model, version in PAPER_VERSIONS:
        vp = paper_version_profile(model, version)
        if model not in out:
            out[model] = ModelProfile(model, (vp,))
        else:
            out[model] = ModelProfile(model, out[model].versions + (vp,))
    return out


# --------------------------------------------------------------------------
# transformer profiles (assigned architectures) — the TPU adaptation
# --------------------------------------------------------------------------

def build_quant_versions(cfg, per_layer, *, seq_len: int,
                         cuts: Tuple[int, ...],
                         flops_scale: float = 1.0
                         ) -> Tuple[VersionProfile, ...]:
    """One VersionProfile per quant-registry entry, derived from the real
    quantized execution path (shared by transformer_profile and
    roofline_env.dryrun_profile):

      accuracy     — baseline degraded by the version's measured
                     quantization error (quant.versions.accuracy_proxy)
      flops        — ``per_layer`` per-token FLOPs with the version's MXU
                     cost scale applied ONLY to the dense-projection
                     share (the part that really executes int8 x int8 at
                     2x throughput); attention scores, MoE experts and
                     SSM/LRU mixers stay full precision in execution and
                     so in the tables. ``flops_scale`` carries dry-run
                     calibration and covers the whole block.
      out_bytes    — cut activation in the width the version ships:
                     int8 for w8a8, else the config's compute dtype
      weight_bytes — only the dense share prices at the version's code
                     width; everything quantize_tree leaves alone (MoE
                     experts, mixers, embeddings-free blocks) ships at
                     the config's param-dtype width
    """
    from repro.core.transformer_cost import block_dense_flops, block_params
    from repro.quant.versions import accuracy_proxy, get_version

    dense_share = block_dense_flops(cfg)           # quantizable share
    params_pl = block_params(cfg)
    pw = cfg.pdtype.itemsize                       # full-precision widths
    aw = cfg.cdtype.itemsize
    # accuracy, like FLOPs and bytes, only degrades on the quantized share
    dense_frac = sum(dense_share) / max(sum(per_layer), 1.0)
    versions = []
    for vname in cfg.versions:
        qv = get_version(vname)
        act_width = 1 if qv.act_bits == 8 else aw
        act_bytes = cfg.d_model * act_width * seq_len
        layers = []
        for i, (f, df, p) in enumerate(zip(per_layer, dense_share,
                                           params_pl)):
            flops = (df * qv.matmul_cost_scale + (f - df)) \
                * seq_len * flops_scale
            dense_p = df / 2.0
            if qv.mode is None:
                wb = p * pw
            else:
                wb = dense_p * qv.bytes_per_param + (p - dense_p) * pw
            layers.append(LayerProfile(f"block{i}", flops, act_bytes,
                                       int(p), weight_bytes=wb))
        versions.append(VersionProfile(
            cfg.name, vname, accuracy_proxy(qv, dense_frac=dense_frac),
            tuple(layers), cuts, bytes_per_param=qv.bytes_per_param))
    return tuple(versions)


def spread_cuts(n_layers: int, n_cuts: int) -> Tuple[int, ...]:
    """Candidate cut layers at even fractional depths."""
    return tuple(max(1, round(n_layers * (i + 1) / (n_cuts + 1)))
                 for i in range(n_cuts))


def transformer_profile(cfg, *, seq_len: int = 2048,
                        n_cuts: int = 4) -> ModelProfile:
    """Build an EdgeRL ModelProfile from a ModelConfig.

    Layer = one decoder block; activation at the cut = (seq, d_model).
    The version axis is the *quantization level* of the same trunk
    (repro.quant: bf16 / w8 / w4) — the transformer analogue of the
    paper's compressed variants — with every table entry derived from the
    real quantized execution path (see build_quant_versions).
    """
    from repro.core.transformer_cost import block_flops_per_token

    per_layer = block_flops_per_token(cfg)         # list, len n_layers
    cuts = spread_cuts(len(per_layer), n_cuts)
    versions = build_quant_versions(cfg, per_layer, seq_len=seq_len,
                                    cuts=cuts)
    return ModelProfile(cfg.name, versions)

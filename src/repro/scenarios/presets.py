"""Named scenario presets + registry.

Each preset is a complete operating regime; ``scripts/simulate.py
--scenario <name>`` (flags still override individual fields) and
``run_scenario`` consume them, and the scenario-determinism test runs
every one of them twice. Registering a new requirement is one
``register_scenario`` call — no call-site plumbing.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.reward import RewardWeights
from repro.scenarios.base import Scenario

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; valid names: "
                       f"{', '.join(scenario_names())}")
    return _REGISTRY[name]


# --------------------------------------------------------------------------
# presets
# --------------------------------------------------------------------------

register_scenario(Scenario(
    name="paper-exact",
    description="the paper's 3-UAV testbed, faithful reward (no "
                "stability term), 30 s slots, ~1 fps reconnaissance "
                "load per device",
    devices=3, models="cycle",
    weights=RewardWeights(),                 # thirds, w_stab = 0
    slot_seconds=30.0, peak_rps=0.0,         # paper-faithful
    server_flops_per_device=None, bw_max_bps=None,   # testbed latency
    trace="poisson", trace_kw={"rate_rps": 1.0},
    slo_s=5.0, seeds=(0, 1, 2), n_requests=10_000,
    policies=("a2c", "greedy_oracle", "device_only", "full_offload"),
    episodes=300, entropy_coef=0.01, train_trace=None))

register_scenario(Scenario(
    name="paper-mmpp-burst",
    description="4-device fleet under 2-state MMPP bursts (2 -> 30 "
                "rps/device); the stability-aware controller's "
                "acceptance regime",
    devices=4, models="vgg",
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 30.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    seeds=(0, 2, 4), n_requests=20_000,
    policies=("a2c", "device_only", "full_offload"),
    episodes=500))

register_scenario(Scenario(
    name="diurnal-fleet",
    description="8-device fleet under a sinusoidal day/night load "
                "(2 -> 30 rps/device) with mixed model assignment",
    devices=8, models="cycle",
    trace="diurnal", trace_kw={"base_rps": 2.0, "peak_rps": 30.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    seeds=(0, 1, 2), n_requests=50_000,
    policies=("a2c", "device_only", "full_offload"),
    episodes=300))

register_scenario(Scenario(
    name="degraded-link",
    description="uplink collapse: WiFi ceiling cut to 64 Mb/s (floor "
                "4 Mb/s) under MMPP bursts — offloading must be "
                "re-earned per decision",
    devices=4, models="cycle",
    bw_max_bps=64e6, bw_min_bps=4e6,
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 20.0},
    slot_seconds=10.0, peak_rps=20.0, slo_s=2.0,
    seeds=(0, 1, 2), n_requests=20_000,
    policies=("a2c", "device_only", "full_offload"),
    episodes=400))

# -- nonstationary worlds (repro.online): each preset pairs the online-
# -- adapted controller against the same controller frozen at its
# -- pre-drift parameters, under a timed WorldSchedule ---------------------

register_scenario(Scenario(
    name="link-brownout",
    description="edge-infrastructure brownout: uplink collapses below "
                "the design floor (1 Gb/s -> 6 Mb/s) and the server's "
                "effective share degrades 10x from epoch 60, recovering "
                "at 240 — the online-adapted controller must re-learn "
                "local execution, then re-earn offloading",
    devices=4, models="vgg", battery_wh=200.0,
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 15.0},
    slot_seconds=10.0, peak_rps=20.0, slo_s=2.0,
    drift="link-brownout", drift_kw={"onset": 60, "recover": 240},
    seeds=(0, 1), n_requests=70_000,
    policies=("a2c+online", "a2c", "device_only", "full_offload"),
    episodes=300, entropy_coef=0.03, batch_envs=4))

register_scenario(Scenario(
    name="flash-crowd",
    description="flash crowd: offered rate jumps 1.75x (8 -> 14 "
                "rps/device) and the server's background workload "
                "surges 8x from epoch 50, relaxing at 220 — offloading "
                "silently drowns in a queue the controller only sees "
                "clipped (resnet fleet: every local action stays "
                "FIFO-stable, so the mistake is recoverable)",
    devices=4, models="resnet", battery_wh=200.0,
    trace="poisson", trace_kw={"rate_rps": 8.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    drift="flash-crowd",
    drift_kw={"onset": 50, "relax": 220, "scale": 1.75,
              "queue_scale": 8.0},
    seeds=(0, 1), n_requests=140_000,
    policies=("a2c+online", "a2c", "device_only", "full_offload"),
    episodes=300, entropy_coef=0.03, batch_envs=4))

register_scenario(Scenario(
    name="battery-cliff",
    description="battery decay cliff: remaining charge drops to 25% at "
                "epoch 70 and degraded cells draw 3x compute power — "
                "the adapted controller shifts to energy-light actions "
                "to keep the fleet alive",
    devices=4, models="vgg", battery_wh=120.0,
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 15.0},
    slot_seconds=10.0, peak_rps=20.0, slo_s=2.0,
    drift="battery-cliff",
    drift_kw={"at": 70, "battery_scale": 0.25, "compute_scale": 3.0},
    seeds=(0, 1), n_requests=60_000,
    policies=("a2c+online", "a2c", "device_only"),
    episodes=300, entropy_coef=0.03, batch_envs=4))

register_scenario(Scenario(
    name="device-churn",
    description="device churn: devices 0-1 drop out of a 6-device mixed "
                "fleet at epoch 60 and rejoin with fresh batteries at "
                "160; the schedule exercises per-regime metrics under "
                "fleet-composition drift",
    devices=6, models="cycle", battery_wh=200.0,
    trace="poisson", trace_kw={"rate_rps": 6.0},
    slot_seconds=10.0, peak_rps=20.0, slo_s=2.0,
    drift="device-churn",
    drift_kw={"leave_at": 60, "rejoin_at": 160, "leave": (0, 1)},
    seeds=(0, 1), n_requests=50_000,
    policies=("a2c+online", "a2c", "device_only", "full_offload"),
    episodes=300, entropy_coef=0.03, batch_envs=4))

# -- server clusters (repro.cluster): heterogeneous pools, learned
# -- routing over the widened (version, cut, server) action space ----------

register_scenario(Scenario(
    name="edge-cluster",
    description="heterogeneous 4-server edge pool (1x..0.2x tiers) "
                "behind a near-far radio topology with hysteresis "
                "autoscaling; A2C learns (version, cut, server) "
                "end-to-end against the classic dispatch routers",
    devices=8, models="cycle",
    pool="hetero-4", topology="near-far",
    autoscale="hysteresis",
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 25.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    seeds=(0, 1, 2), n_requests=20_000,
    policies=("a2c", "round_robin", "join_shortest_queue", "local_only"),
    episodes=400, entropy_coef=0.03, batch_envs=4))

register_scenario(Scenario(
    name="cluster-brownout",
    description="flash crowd over the heterogeneous pool: offered rate "
                "jumps 1.75x and the servers' background workload "
                "surges 6x from epoch 50, relaxing at 220 — job-count "
                "JSQ misreads the slow tiers as cheap while the learned "
                "router prices depth x service rate per target",
    devices=8, models="cycle", battery_wh=200.0,
    pool="hetero-4", topology="near-far",
    autoscale="hysteresis",
    trace="poisson", trace_kw={"rate_rps": 8.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    drift="flash-crowd",
    drift_kw={"onset": 50, "relax": 220, "scale": 1.75,
              "queue_scale": 6.0},
    seeds=(0, 1), n_requests=60_000,
    policies=("a2c", "round_robin", "join_shortest_queue",
              "device_only"),
    episodes=400, entropy_coef=0.03, batch_envs=4))

register_scenario(Scenario(
    name="megafleet",
    description="mega-fleet scale: 100k devices under a diurnal load "
                "through the vectorized epoch engine "
                "(sim.megafleet) — static policies only (the fused "
                "epoch is the product under test; trainable nets "
                "would dominate wall-clock at this width)",
    devices=100_000, models="cycle",
    trace="diurnal", trace_kw={"base_rps": 2.0, "peak_rps": 8.0},
    slot_seconds=1.0, peak_rps=10.0, slo_s=1.0,
    seeds=(0,), n_requests=5_000_000,
    policies=("greedy_oracle", "device_only", "full_offload"),
    engine="vectorized"))

register_scenario(Scenario(
    name="tpu-submesh",
    description="TPU adaptation: 2 head submeshes serving reduced "
                "qwen2-0.5b, version axis = {bf16, w8, w4}, ICI uplink, "
                "analytical pricing",
    env="tpu", devices=2, arch="qwen2-0.5b",
    trace="poisson", trace_kw={"rate_rps": 100.0},
    slot_seconds=1.0, peak_rps=200.0, slo_s=0.05,
    seeds=(0, 1), n_requests=20_000,
    policies=("greedy_oracle", "device_only", "full_offload"),
    episodes=200))

register_scenario(Scenario(
    name="tpu-execute",
    description="tpu-submesh plus the execute cross-check: a sampled "
                "subset of requests runs through the real "
                "SplitServingEngine (act-bytes must match exactly)",
    env="tpu", devices=2, arch="qwen2-0.5b",
    trace="poisson", trace_kw={"rate_rps": 100.0},
    slot_seconds=1.0, peak_rps=200.0, slo_s=0.05,
    seeds=(0,), n_requests=2_000,
    policies=("greedy_oracle",),
    episodes=200, execute=True, sample=8))

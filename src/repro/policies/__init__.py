"""repro.policies: one Policy protocol + canonical name registry.

Importing this package registers the full roster:

- static:    ``device_only``, ``full_offload``, ``random``,
             ``greedy_oracle``
- routers:   ``round_robin``, ``join_shortest_queue``, ``local_only``
             (cluster-mode envs only; repro.cluster.routers)
- trainable: ``a2c`` (the paper's controller), ``ppo`` (ablation)

``build_policy(name, env_cfg, tables, **kw)`` is the one entry point;
unknown names raise a KeyError listing every valid name.
"""
from repro.policies.base import (Policy, PolicySpec, build_policy,
                                 get_policy_spec, policy_names, register)
from repro.policies.static import StaticPolicy
from repro.policies.trainable import A2CPolicy, PPOPolicy, TrainablePolicy

import repro.cluster.routers  # noqa: F401  (registers the router roster)

__all__ = [
    "Policy", "PolicySpec", "StaticPolicy", "TrainablePolicy",
    "A2CPolicy", "PPOPolicy",
    "register", "build_policy", "get_policy_spec", "policy_names",
]

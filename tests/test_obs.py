"""repro.obs: null-recorder overhead contract, JSONL schema round-trip,
nested-span structure, metrics, report folding, the bit-identity
invariant (recording must not change results), and the JAX retrace
accounting — zero re-traces across param hot-swaps, exactly one on a
genuine shape change."""
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import make_paper_env
from repro.core.env import env_reset
from repro.obs import (NullRecorder, Recorder, SCHEMA_VERSION, jaxmon,
                       read_events, recording, report)
from repro.obs.metrics import Metrics
from repro.policies import build_policy
from repro.scenarios import get_scenario, run_scenario


# --------------------------------------------------------------------------
# null default + recorder lifecycle
# --------------------------------------------------------------------------

def test_null_recorder_is_default_and_noop():
    rec = obs.get_recorder()
    assert isinstance(rec, NullRecorder) and not rec.enabled
    # the disabled span is one shared object: no allocation per use
    s1, s2 = obs.span("a", x=1), obs.span("b")
    assert s1 is s2
    with s1:
        pass
    obs.event("nothing", y=2)                      # no-op, no error
    obs.inc("c"), obs.gauge("g", 1.0), obs.observe("h", 2.0)


def test_recording_installs_and_restores(tmp_path):
    before = obs.get_recorder()
    with recording(str(tmp_path / "e.jsonl")) as rec:
        assert obs.get_recorder() is rec and rec.enabled
        obs.event("inside")
    assert obs.get_recorder() is before
    # close() wrote the file and is idempotent
    rec.close()
    meta, events = read_events(str(tmp_path / "e.jsonl"))
    assert meta["schema"] == SCHEMA_VERSION
    assert any(e["type"] == "event" and e["name"] == "inside"
               for e in events)


def test_schema_round_trip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with recording(path, meta={"tool": "test", "n": 3}) as rec:
        with obs.span("outer", k="v"):
            obs.event("point", val=np.float64(1.5))
        rec.metrics.inc("hits", 2.0)
    meta, events = read_events(path)
    assert meta["type"] == "meta" and meta["clock"] == "perf_counter"
    assert meta["meta"] == {"tool": "test", "n": 3}
    types = {e["type"] for e in events}
    assert {"span", "event", "metric"} <= types
    # numpy attrs serialized as plain JSON scalars
    point = next(e for e in events if e.get("name") == "point")
    assert point["attrs"]["val"] == 1.5
    # seq is a total order
    seqs = [e["seq"] for e in events]
    assert seqs == sorted(seqs) == list(range(len(events)))


def test_read_events_rejects_foreign_files(tmp_path):
    p = tmp_path / "x.jsonl"
    p.write_text('{"not": "meta"}\n')
    with pytest.raises(ValueError, match="no meta header"):
        read_events(str(p))
    p.write_text(json.dumps({"type": "meta", "schema": 999}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        read_events(str(p))


def test_nested_spans_depth_parent_ordering():
    rec = Recorder()
    with rec.span("a"):
        with rec.span("b", tag=1):
            pass
        with rec.span("c"):
            pass
    spans = [e for e in rec.events if e["type"] == "span"]
    # spans emit at exit: children precede the parent in the stream
    assert [s["name"] for s in spans] == ["b", "c", "a"]
    b, c, a = spans
    assert b["depth"] == c["depth"] == 1 and a["depth"] == 0
    assert b["parent"] == c["parent"] == "a" and a["parent"] is None
    assert b["attrs"] == {"tag": 1}
    # children are timed within the parent window
    assert a["t"] <= b["t"] and b["t"] + b["dur"] <= a["t"] + a["dur"] + 1e-9


def test_span_attr_may_be_called_name():
    rec = Recorder()
    rec.event("drift.regime_switch", name="brownout")   # no collision
    with rec.span("s", name="inner"):
        pass
    assert rec.events[0]["attrs"] == {"name": "brownout"}


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_metrics_counters_gauges_histograms():
    m = Metrics()
    m.inc("req", 2.0, policy="a2c")
    m.inc("req", 3.0, policy="a2c")
    m.inc("req", 1.0, policy="greedy")
    m.gauge("level", 0.5)
    m.gauge("level", 0.7)                    # last write wins
    for v in range(1, 101):
        m.observe("lat", float(v))
    snap = {(s["name"], tuple(sorted(s.get("labels", {}).items()))): s
            for s in m.snapshot()}
    assert snap[("req", (("policy", "a2c"),))]["value"] == 5.0
    assert snap[("req", (("policy", "greedy"),))]["value"] == 1.0
    assert snap[("level", ())]["value"] == 0.7
    h = snap[("lat", ())]
    assert h["kind"] == "histogram" and h["count"] == 100
    assert h["min"] == 1.0 and h["max"] == 100.0
    assert h["p50"] == pytest.approx(50.5, abs=1.0)
    assert h["p99"] == pytest.approx(99.0, abs=1.5)


def test_module_metrics_route_to_active_recorder(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with recording(path):
        obs.inc("fleet.arrivals", 7, policy="x")
        obs.observe("q", 1.0)
    _, events = read_events(path)
    ms = [e for e in events if e["type"] == "metric"]
    names = {m["name"] for m in ms}
    assert {"fleet.arrivals", "q"} <= names


# --------------------------------------------------------------------------
# report folding
# --------------------------------------------------------------------------

def test_report_fold_and_render(tmp_path):
    path = str(tmp_path / "r.jsonl")
    with recording(path, meta={"tool": "test"}):
        for i in range(3):
            with obs.span("fleet.epoch", epoch=i):
                with obs.span("fleet.decide"):
                    pass
        obs.event("drift.trigger", n=1)
        obs.event("online.hotswap", epoch=2)
        obs.inc("served", 10)
    rep = report.load(path)
    assert rep["phases"]["fleet.epoch"]["count"] == 3
    assert rep["phases"]["fleet.decide"]["count"] == 3
    assert rep["phases"]["fleet.epoch"]["total_s"] >= \
        rep["phases"]["fleet.decide"]["total_s"]
    assert [e["name"] for e in rep["timeline"]] == ["drift.trigger",
                                                    "online.hotswap"]
    assert rep["wall_s"] > 0
    text = report.render(rep)
    for needle in ("per-phase timing:", "fleet.epoch",
                   "drift/online timeline:", "drift.trigger", "metrics:"):
        assert needle in text
    # folded report is JSON-serializable as obsview --json writes it
    json.dumps(rep, default=str)


def test_structured_logging_gates_console(capsys, tmp_path):
    old = obs.get_verbosity()
    try:
        obs.set_verbosity(0)
        with recording(str(tmp_path / "l.jsonl")):
            obs.info("hidden info")
            obs.debug("hidden debug")
            obs.warn("visible warn")
        out = capsys.readouterr()
        assert "hidden" not in out.out and "hidden" not in out.err
        assert "visible warn" in out.err
        # --quiet console still records the full story
        _, events = read_events(str(tmp_path / "l.jsonl"))
        logged = {(e["level"], e["msg"]) for e in events
                  if e["type"] == "log"}
        assert {("info", "hidden info"), ("debug", "hidden debug"),
                ("warn", "visible warn")} <= logged
        obs.set_verbosity(2)
        obs.info("now info")
        obs.debug("now debug")
        out = capsys.readouterr()
        assert "now info" in out.out and "now debug" in out.out
    finally:
        obs.set_verbosity(old)


# --------------------------------------------------------------------------
# bit-identity: recording must not change results
# --------------------------------------------------------------------------

def test_comparison_report_bit_identical_on_vs_off(tmp_path):
    sc = get_scenario("paper-exact")
    roster = ("greedy_oracle", "device_only")
    kw = dict(n_requests=1200, seeds=(0,))
    off = run_scenario(sc, roster, **kw)
    with recording(str(tmp_path / "t.jsonl")):
        on = run_scenario(sc, roster, **kw)
    assert off.to_json() == on.to_json()


# --------------------------------------------------------------------------
# jax accounting: compile listeners + retrace counters
# --------------------------------------------------------------------------

def test_track_compiles_counts_fresh_compiles_only():
    jaxmon.install()

    @jax.jit
    def f(x):
        return x * 2.0

    with jaxmon.track_compiles() as d1:
        f(jnp.ones(3))
    assert d1.get("backend_compile_n", 0) >= 1
    with jaxmon.track_compiles() as d2:
        f(jnp.ones(3))                       # cache hit
    assert d2 == {}


def test_count_trace_fires_at_trace_time_only():
    site = "test.count_trace_site"
    jaxmon.reset_trace_counts()

    @jax.jit
    def g(x):
        jaxmon.count_trace(site)
        return x + 1

    with jaxmon.track_traces() as d:
        g(jnp.ones(4))
        g(jnp.ones(4))                       # cache hit: body not re-run
        g(jnp.ones(5))                       # new shape: one re-trace
    assert d[site] == 2


@pytest.fixture(scope="module")
def tiny_trained_a2c():
    cfg, tables = make_paper_env(n_uavs=3, slot_seconds=10.0,
                                 peak_rps=20.0)
    pol = build_policy("a2c", cfg, tables, episodes=2)
    pol.train(seed=0)
    return cfg, tables, pol


def test_zero_retraces_on_param_hotswap(tiny_trained_a2c):
    cfg, tables, pol = tiny_trained_a2c
    state = env_reset(cfg, tables, jax.random.key(0))
    k = jax.random.key(1)
    site = f"decide.{pol.name}"
    with jaxmon.track_traces() as d:
        first = np.asarray(pol.jitted()(state, k))
    assert d.get(site, 0) == 1
    # hot-swap params repeatedly: the compiled decide re-binds, and the
    # measured invariant is that it never re-traces
    with jaxmon.track_traces() as d:
        for i in range(5):
            bumped = jax.tree.map(lambda x: x + 0.01, pol.params)
            pol.set_params(bumped)
            out = np.asarray(pol.jitted()(state, k))
    assert site not in d, f"param hot-swap re-traced: {d}"
    assert out.shape == first.shape


def test_exactly_one_retrace_on_genuine_shape_change(tiny_trained_a2c):
    cfg, tables, pol = tiny_trained_a2c
    state = env_reset(cfg, tables, jax.random.key(0))
    k = jax.random.key(1)
    site = f"decide.{pol.name}"
    base = np.asarray(pol.jitted()(state, k))        # warm current params
    # queue is a scalar in env_reset; a per-device (n,) zeros vector is
    # numerically identical after _obs_features' broadcast but is a
    # different abstract shape — the one legitimate re-trace
    wide = dict(state, queue=jnp.zeros(cfg.n_uavs, jnp.float32))
    with jaxmon.track_traces() as d:
        out = np.asarray(pol.jitted()(wide, k))
        np.asarray(pol.jitted()(wide, k))            # now cached again
    assert d.get(site, 0) == 1, f"expected exactly one re-trace: {d}"
    np.testing.assert_array_equal(base, out)


def test_online_run_traces_once_per_exploration_rate():
    """The closed-loop acceptance invariant: across a whole online
    adaptation run — bursts, window updates, param hot-swaps every few
    epochs — the decide site traces exactly once per exploration rate
    (greedy + the burst epsilon), never per swap."""
    from repro.online import OnlineConfig, get_schedule
    from repro.sim import FleetConfig, PoissonTrace, simulate

    cfg, tables = make_paper_env(n_uavs=3, slot_seconds=10.0,
                                 peak_rps=20.0)
    trace = PoissonTrace(rate_rps=6.0)
    pol = build_policy("a2c", cfg, tables, episodes=2)
    pol.train(seed=0)
    oc = OnlineConfig(algo="a2c", gate="always", window=16, min_window=4,
                      update_every=1)
    site = f"decide.{pol.name}"
    with jaxmon.track_traces() as d:
        res = simulate(cfg, tables, pol, trace, n_requests=6000, seed=0,
                       fleet=FleetConfig(slo_s=1.0),
                       schedule=get_schedule("link-brownout", onset=5,
                                             recover=0),
                       online=oc)
    assert res.adaptation["online"]["updates"] > 1   # swaps happened
    eps_rates = {0.0, oc.explore_eps}
    assert d.get(site, 0) <= len(eps_rates), \
        f"decide re-traced beyond once-per-eps: {d}"


# --------------------------------------------------------------------------
# bench harness: repeated samples ride along in the records
# --------------------------------------------------------------------------

def _load_bench_module():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "run.py")
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_timeit_reports_samples():
    from repro.bench import runner as brunner
    bench = _load_bench_module()
    t = bench._timeit(lambda: jnp.ones(8), n=2, reps=4)
    assert isinstance(t, float) and len(t.samples) == 4
    assert float(t) == min(t.samples)
    sink = brunner.Sink(echo=False)
    sink.row("x", t, "d")
    sink.row("y", 12.34, "single-sample rows keep working")
    rx, ry = sink.records
    assert rx["samples"] == [float(f"{s:.4g}") for s in t.samples]
    assert rx["us_per_call"] == rx["min"]
    assert rx["mean"] >= rx["min"] and rx["std"] >= 0.0
    assert ry["name"] == "y" and ry["us_per_call"] == 12.34
    assert ry["samples"] == [12.34] and ry["n"] == 1
    assert ry["ci_lo"] == ry["ci_hi"] == 12.34

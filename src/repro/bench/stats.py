"""repro.bench.stats — the perf harness's noise model.

Timing on a shared CPU host is a heavy-tailed nuisance process: the
scheduler, the allocator and JAX dispatch all inject spikes that a
single ``us_per_call`` number hides. This module owns the whole
measurement story:

- ``timeit(fn, n, reps)``: repeated back-to-back samples with a warmup
  (compile) call discarded, returning a ``Timing`` — a float (min
  sample, the least-noise headline every existing format site expects)
  that carries the raw per-repetition samples.
- ``reject_outliers``: modified z-score on the MAD — scheduler spikes
  are one-sided and huge, so a robust location estimate is mandatory.
- ``bootstrap_ci``: percentile bootstrap CI for the median
  (deterministic seed — reruns reproduce the stored bounds).
- ``mann_whitney_u``: one-sided nonparametric test (exact for the small
  sample counts benches produce, normal approximation with tie
  correction beyond that) — no distributional assumption on timings.
- ``compare(baseline, current)``: the gate's decision rule. A case
  *regresses* only when the median slowdown exceeds a minimum effect
  threshold AND the Mann-Whitney test calls the shift significant —
  tiny-but-significant jitter (1% on a million samples) passes, and a
  big-but-noisy blip (one 2x sample) passes too.

Pure numpy + stdlib: importable (and testable) without jax; ``timeit``
only touches jax when the benched value is a jax array.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from itertools import combinations
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


def format_sig(x: float, sig: int = 4) -> float:
    """Round to ``sig`` significant digits (JSON-friendly float) — keeps
    sub-microsecond timings (the distilled-decide target) from
    collapsing to 0.0 the way fixed one-decimal rounding does."""
    x = float(x)
    if x == 0.0 or not math.isfinite(x):
        return x
    return float(f"{x:.{sig}g}")


class Timing(float):
    """us-per-call headline number (min over repetitions — least noise)
    that still *is* a float for every existing format/arithmetic site,
    carrying the per-repetition samples for the JSON records.

    Scaling (``us / 32`` for a per-token number) scales the samples
    too, so derived rows keep their noise model."""

    samples: tuple = ()

    def __new__(cls, value, samples=()):
        t = super().__new__(cls, value)
        t.samples = tuple(float(s) for s in samples) or (float(value),)
        return t

    def __truediv__(self, other):
        return Timing(float(self) / other,
                      [s / other for s in self.samples])

    def __mul__(self, other):
        return Timing(float(self) * other,
                      [s * other for s in self.samples])


def _block(out) -> None:
    """block_until_ready when the result is a jax value; no-op
    otherwise (stats must work without jax importable)."""
    try:
        import jax
        jax.block_until_ready(out)
    except (ImportError, TypeError, ValueError):
        pass


def timeit(fn: Callable[[], object], n: int = 5, reps: int = 5,
           warmup: int = 1) -> Timing:
    """``reps`` back-to-back repetitions of an ``n``-call loop, each
    yielding one us-per-call sample, after ``warmup`` discarded
    (compile-absorbing) calls; returns a ``Timing`` (min sample) whose
    ``.samples`` feed the gate's noise model."""
    for _ in range(max(warmup, 1)):
        _block(fn())
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn()
        _block(out)
        samples.append((time.perf_counter() - t0) / n * 1e6)
    return Timing(min(samples), samples)


# --------------------------------------------------------------------------
# robust summaries
# --------------------------------------------------------------------------

def reject_outliers(samples: Sequence[float], k: float = 3.5
                    ) -> List[float]:
    """Drop samples whose modified z-score (0.6745·|x−med|/MAD) exceeds
    ``k`` — the standard robust cut for one-sided scheduler spikes.
    Fewer than 4 samples pass through untouched (MAD is meaningless)."""
    xs = [float(s) for s in samples]
    if len(xs) < 4:
        return xs
    med = float(np.median(xs))
    mad = float(np.median([abs(x - med) for x in xs]))
    if mad == 0.0:
        # degenerate: most samples identical — fall back to mean abs dev
        mad = float(np.mean([abs(x - med) for x in xs]))
        if mad == 0.0:
            return xs
    return [x for x in xs if 0.6745 * abs(x - med) / mad <= k]


def bootstrap_ci(samples: Sequence[float], alpha: float = 0.05,
                 n_boot: int = 2000, seed: int = 0,
                 stat: Callable = np.median) -> Tuple[float, float]:
    """Percentile-bootstrap (1−alpha) CI for ``stat`` (median). The rng
    is seeded so the bounds written into BENCH history are
    reproducible from the stored samples."""
    xs = np.asarray(samples, dtype=np.float64)
    if xs.size == 0:
        return (float("nan"), float("nan"))
    if xs.size == 1:
        return (float(xs[0]), float(xs[0]))
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, xs.size, size=(n_boot, xs.size))
    stats = np.asarray(stat(xs[idx], axis=1))
    lo, hi = np.quantile(stats, [alpha / 2, 1 - alpha / 2])
    return (float(lo), float(hi))


@dataclass(frozen=True)
class SampleStats:
    """Robust summary of one case's samples (post outlier rejection)."""
    n: int              # samples kept
    n_raw: int          # samples collected
    min: float
    median: float
    mean: float
    std: float
    cv: float           # std/mean — the run's own noise estimate
    ci_lo: float        # bootstrap CI of the median
    ci_hi: float


def summarize(samples: Sequence[float], alpha: float = 0.05
              ) -> SampleStats:
    raw = [float(s) for s in samples]
    xs = reject_outliers(raw)
    arr = np.asarray(xs, dtype=np.float64)
    mean = float(arr.mean())
    std = float(arr.std())
    lo, hi = bootstrap_ci(xs, alpha=alpha)
    return SampleStats(n=len(xs), n_raw=len(raw), min=float(arr.min()),
                       median=float(np.median(arr)), mean=mean, std=std,
                       cv=std / mean if mean else 0.0, ci_lo=lo, ci_hi=hi)


# --------------------------------------------------------------------------
# nonparametric comparison (the gate's decision rule)
# --------------------------------------------------------------------------

_EXACT_LIMIT = 30_000   # max C(n+m, m) enumerated for the exact test


def _ranks(values: Sequence[float]) -> np.ndarray:
    """Average ranks (ties shared), 1-based."""
    xs = np.asarray(values, dtype=np.float64)
    order = np.argsort(xs, kind="mergesort")
    ranks = np.empty(xs.size, dtype=np.float64)
    i = 0
    while i < xs.size:
        j = i
        while j + 1 < xs.size and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def mann_whitney_u(a: Sequence[float], b: Sequence[float]) -> float:
    """One-sided Mann-Whitney U: p-value for H1 "``b`` is stochastically
    greater than ``a``" (b slower, for timings). Exact permutation
    distribution when C(n+m, m) is small (the bench regime: a handful
    of samples vs a pooled baseline), normal approximation with tie and
    continuity corrections otherwise."""
    n, m = len(a), len(b)
    if n == 0 or m == 0:
        return 1.0
    ranks = _ranks(list(a) + list(b))
    rb = float(ranks[n:].sum())
    try:
        total = math.comb(n + m, m)
    except OverflowError:       # pragma: no cover
        total = _EXACT_LIMIT + 1
    if total <= _EXACT_LIMIT:
        # exact: fraction of m-subsets of the combined ranks whose rank
        # sum is >= observed (ties handled by the shared average ranks)
        ge = sum(1 for comb in combinations(ranks, m)
                 if sum(comb) >= rb - 1e-12)
        return ge / total
    u = rb - m * (m + 1) / 2.0
    mu = n * m / 2.0
    # tie-corrected variance
    _, counts = np.unique(np.concatenate([np.asarray(a, dtype=np.float64),
                                          np.asarray(b, dtype=np.float64)]),
                          return_counts=True)
    nm = n + m
    tie = float(((counts ** 3 - counts).sum()) / (nm * (nm - 1))) \
        if nm > 1 else 0.0
    var = n * m / 12.0 * (nm + 1 - tie)
    if var <= 0:
        return 1.0
    z = (u - mu - 0.5) / math.sqrt(var)
    return 0.5 * math.erfc(z / math.sqrt(2.0))


@dataclass(frozen=True)
class Comparison:
    """Outcome of one baseline-vs-current case comparison."""
    verdict: str            # ok | regression | improved | insufficient
    effect: float           # median(cur)/median(base) - 1  (+ = slower)
    p_slower: float         # MWU p for "current slower"
    p_faster: float         # MWU p for "current faster"
    base_median: float
    cur_median: float
    n_base: int
    n_cur: int
    cur_ci: Tuple[float, float]   # bootstrap CI of current median
    base_ci: Tuple[float, float]


def compare(baseline: Sequence[float], current: Sequence[float],
            min_effect: float = 0.10, alpha: float = 0.05,
            min_samples: int = 3) -> Comparison:
    """The gate rule. Regression ⇔ median slowdown > ``min_effect`` AND
    one-sided MWU p < ``alpha``; symmetric for improvement. Fewer than
    ``min_samples`` on either side → ``insufficient`` (never fails —
    single-shot benches are reported, not gated)."""
    base = reject_outliers(baseline)
    cur = reject_outliers(current)
    bmed = float(np.median(base)) if base else float("nan")
    cmed = float(np.median(cur)) if cur else float("nan")
    effect = (cmed / bmed - 1.0) if base and cur and bmed > 0 else 0.0
    kw = dict(effect=effect, base_median=bmed, cur_median=cmed,
              n_base=len(base), n_cur=len(cur),
              cur_ci=bootstrap_ci(cur, alpha=alpha),
              base_ci=bootstrap_ci(base, alpha=alpha))
    if len(base) < min_samples or len(cur) < min_samples:
        return Comparison(verdict="insufficient", p_slower=1.0,
                          p_faster=1.0, **kw)
    p_slower = mann_whitney_u(base, cur)
    p_faster = mann_whitney_u(cur, base)
    if effect > min_effect and p_slower < alpha:
        verdict = "regression"
    elif effect < -min_effect and p_faster < alpha:
        verdict = "improved"
    else:
        verdict = "ok"
    return Comparison(verdict=verdict, p_slower=p_slower,
                      p_faster=p_faster, **kw)

"""Attention math: plain masked attention + chunked (flash-style) attention.

Both are pure jnp; the chunked path keeps live score blocks at
(B, G*HK, q_chunk, kv_chunk) so 32k-token prefill lowers without
materializing (S, S) scores. These functions double as the oracle
reference for the Pallas flash-attention kernel (kernels/ref.py imports
``plain_attention``).

Conventions: q (B, Sq, H, Dh); k, v (B, Skv, HK, Dh) with H % HK == 0 (GQA).
positions are absolute token indices; masking is positional so ring-buffer
(sliding-window) caches work with the same code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qp, kp, *, causal: bool, window: Optional[int]):
    """qp: (Sq,), kp: (Skv,) absolute positions; kp < 0 marks invalid slots."""
    m = kp[None, :] >= 0
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window is not None:
        m &= (qp[:, None] - kp[None, :]) < window
    return m  # (Sq, Skv)


def plain_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                    window=None, logit_scale=None):
    B, Sq, H, Dh = q.shape
    HK = k.shape[2]
    G = H // HK
    scale = logit_scale if logit_scale is not None else Dh ** -0.5
    qg = q.reshape(B, Sq, G, HK, Dh)
    scores = jnp.einsum("bqghd,bkhd->bghqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = _mask(q_positions, kv_positions, causal=causal, window=window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bghqk,bkhd->bqghd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def chunked_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                      window=None, q_chunk=512, kv_chunk=1024,
                      logit_scale=None):
    """Flash-style online-softmax attention, scan over q and kv chunks."""
    B, Sq, H, Dh = q.shape
    Skv, HK = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // HK
    scale = logit_scale if logit_scale is not None else Dh ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad to multiples
    def pad_to(x, n, axis, value=0):
        pad = (-x.shape[axis]) % n
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths, constant_values=value)

    qp = pad_to(q_positions, q_chunk, 0, value=0)
    kp = pad_to(kv_positions, kv_chunk, 0, value=-1)   # padded kv = invalid
    q_ = pad_to(q, q_chunk, 1)
    k_ = pad_to(k, kv_chunk, 1)
    v_ = pad_to(v, kv_chunk, 1)
    NQ, NK = q_.shape[1] // q_chunk, k_.shape[1] // kv_chunk

    qb = q_.reshape(B, NQ, q_chunk, G, HK, Dh).astype(jnp.float32)
    kb = k_.reshape(B, NK, kv_chunk, HK, Dh).transpose(
        1, 0, 2, 3, 4).astype(jnp.float32)
    vb = v_.reshape(B, NK, kv_chunk, HK, Dv).transpose(
        1, 0, 2, 3, 4).astype(jnp.float32)
    qpb = qp.reshape(NQ, q_chunk)
    kpb = kp.reshape(NK, kv_chunk)

    def q_block(carry, qi):
        qcb, qpos = qi   # (B, qc, G, HK, Dh), (qc,)

        def kv_block(acc, ki):
            m_run, l_run, o_run = acc
            kcb, vcb, kpos = ki
            s = jnp.einsum("bqghd,bkhd->bghqk", qcb, kcb) * scale
            mask = _mask(qpos, kpos, causal=causal, window=window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            o_new = o_run * alpha[..., None] + jnp.einsum(
                "bghqk,bkhd->bghqd", p, vcb)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, G, HK, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, HK, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, G, HK, q_chunk, Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), (kb, vb, kpb))
        out = o / jnp.maximum(l, 1e-30)[..., None]          # (B,G,HK,qc,Dh)
        return carry, out.transpose(0, 3, 1, 2, 4)          # (B,qc,G,HK,Dh)

    _, outs = jax.lax.scan(q_block, None,
                           (qb.transpose(1, 0, 2, 3, 4, 5), qpb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, NQ * q_chunk, H, Dv)
    return out[:, :Sq].astype(q.dtype)


def chunked_attention_causal_skip(q, k, v, *, q_positions, kv_positions,
                                  window=None, logit_scale=None,
                                  q_chunk=512, kv_chunk=1024):
    """Causal chunked attention that only COMPUTES the kv prefix each q
    block can see (python loop over q blocks, static prefix slices) —
    halves attention FLOPs vs the masked-full scan at the cost of a
    larger HLO (NQ distinct block programs). Perf-iteration variant."""
    B, Sq, H, Dh = q.shape
    assert Sq == k.shape[1], "causal_skip assumes aligned self-attention"
    q_chunk = min(q_chunk, Sq)
    nq = -(-Sq // q_chunk)
    outs = []
    for i in range(nq):
        lo, hi = i * q_chunk, min((i + 1) * q_chunk, Sq)
        kv_hi = hi  # causal: block i sees keys < hi
        outs.append(chunked_attention(
            q[:, lo:hi], k[:, :kv_hi], v[:, :kv_hi],
            q_positions=q_positions[lo:hi], kv_positions=kv_positions[:kv_hi],
            causal=True, window=window, logit_scale=logit_scale,
            q_chunk=q_chunk, kv_chunk=kv_chunk))
    return jnp.concatenate(outs, axis=1)


def attention(q, k, v, *, q_positions, kv_positions, causal=True, window=None,
              logit_scale=None, chunked_threshold=2048,
              q_chunk=512, kv_chunk=1024, causal_skip=False):
    """Dispatch: Pallas flash kernel (REPRO_USE_PALLAS), else chunked for
    long sequences, else plain."""
    from repro.kernels import ops as kops
    if (kops.use_pallas() and q.shape[1] == k.shape[1]
            and q.shape[1] % 8 == 0):
        out = kops.attention_bhsd(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            logit_scale=logit_scale)
        return out.transpose(0, 2, 1, 3)
    if (causal_skip and causal and q.shape[1] == k.shape[1]
            and q.shape[1] > q_chunk):
        return chunked_attention_causal_skip(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            window=window, logit_scale=logit_scale, q_chunk=q_chunk,
            kv_chunk=kv_chunk)
    if q.shape[1] * k.shape[1] > chunked_threshold ** 2:
        return chunked_attention(q, k, v, q_positions=q_positions,
                                 kv_positions=kv_positions, causal=causal,
                                 window=window, logit_scale=logit_scale,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk)
    return plain_attention(q, k, v, q_positions=q_positions,
                           kv_positions=kv_positions, causal=causal,
                           window=window, logit_scale=logit_scale)

"""Baseline execution-profile policies the paper implicitly compares
against: device-only, full-offload, random, and a per-step greedy oracle.

The greedy oracle enumerates every (version, cut) pair per UAV under the
*current* state and picks the per-UAV reward argmax — since Eq. 8 averages
a per-UAV score, per-UAV argmax is the per-step optimum (the RL agent can
only beat it through multi-step battery/queue effects). It scores the
full (V, K) grid through the single pricing core (``core.pricing``), so
it ranks actions under exactly the physics the env rewards and the fleet
simulator meters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pricing
from repro.core.env import EnvConfig, ProfileTables


def _with_server(cfg: EnvConfig, actions, srv=None):
    """Append a server column when the env runs in cluster mode; static
    baselines default to server 0 (the conventional primary target)."""
    if cfg.cluster is None:
        return actions
    n = actions.shape[0]
    if srv is None:
        srv = jnp.zeros((n,), jnp.int32)
    return jnp.concatenate([actions, srv[:, None].astype(jnp.int32)], -1)


def device_only(cfg: EnvConfig, tables: ProfileTables, state, rng=None):
    """Lightweight version, run everything locally (last cut)."""
    n = cfg.n_uavs
    a = jnp.stack([jnp.zeros((n,), jnp.int32),
                   jnp.full((n,), tables.n_cuts - 1, jnp.int32)], -1)
    return _with_server(cfg, a)


def full_offload(cfg: EnvConfig, tables: ProfileTables, state, rng=None):
    """Heavy version, cut as early as possible."""
    n = cfg.n_uavs
    j = (tables.version_valid[state["model_id"]].sum(-1) - 1).astype(jnp.int32)
    return _with_server(cfg, jnp.stack([j, jnp.zeros((n,), jnp.int32)], -1))


def random_policy(cfg: EnvConfig, tables: ProfileTables, state, rng):
    """Uniform over each device's *valid* versions and all cuts (and, in
    cluster mode, servers). Sampling randint(0, n_versions) % nv would
    bias toward low version indices whenever a model has fewer versions
    than the padded table width; randint takes a per-device maxval, so
    sample [0, nv) directly."""
    n = cfg.n_uavs
    k1, k2, k3 = jax.random.split(rng, 3)
    nv = tables.version_valid[state["model_id"]].sum(-1).astype(jnp.int32)
    j = jax.random.randint(k1, (n,), 0, nv)
    k = jax.random.randint(k2, (n,), 0, tables.n_cuts)
    a = jnp.stack([j, k], -1).astype(jnp.int32)
    if cfg.cluster is None:
        return a
    srv = jax.random.randint(k3, (n,), 0, cfg.cluster.n_servers)
    return _with_server(cfg, a, srv)


def greedy_oracle(cfg: EnvConfig, tables: ProfileTables, state, rng=None):
    """Per-step per-UAV reward argmax over all (j, k) — and over the
    server axis too in cluster mode. Canonical registry name:
    ``greedy_oracle`` (repro.policies)."""
    n = cfg.n_uavs
    V, K = tables.n_versions, tables.n_cuts
    S = 1 if cfg.cluster is None else cfg.cluster.n_servers
    w = cfg.weights
    view = pricing.view_from_state(state)

    if cfg.cluster is None:
        jj, kk = jnp.meshgrid(jnp.arange(V), jnp.arange(K), indexing="ij")
        cands = jnp.stack([jj.ravel(), kk.ravel()], -1)          # (VK, 2)
    else:
        jj, kk, ss = jnp.meshgrid(jnp.arange(V), jnp.arange(K),
                                  jnp.arange(S), indexing="ij")
        cands = jnp.stack([jj.ravel(), kk.ravel(), ss.ravel()], -1)
    cands = cands.astype(jnp.int32)                              # (VKS, A)

    def score(cand):
        actions = jnp.tile(cand[None], (n, 1))
        br = pricing.price_actions(cfg, tables, view, actions)
        valid = tables.version_valid[state["model_id"], cand[0]]
        s = (w.w_acc * br.acc_score + w.w_lat * br.lat_score
             + w.w_energy * br.energy_score + w.w_stab * br.stab_score)
        return jnp.where(valid > 0, s, -jnp.inf)

    scores = jax.vmap(score)(cands)          # (VKS, n)
    best = jnp.argmax(scores, axis=0)        # (n,)
    return cands[best]

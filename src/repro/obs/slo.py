"""repro.obs.slo — SRE-style error budgets over the fleet's SLO series.

The scenario declares an SLO attainment *target* (e.g. 0.95: at most 5%
of offered requests may miss the latency deadline or be dropped). The
complement ``budget = 1 - target`` is the error budget; this module
turns the timeline's per-epoch (arrivals, slo_hits) series into:

- **burn rate** — the windowed miss fraction divided by the budget. A
  burn of 1.0 spends the budget exactly at the sustainable pace; 10x
  exhausts it in a tenth of the time.
- **multi-window alerts** — the Google SRE multi-window multi-burn rule:
  page only when *both* a fast window (is it happening right now?) and
  a slow window (is it material, not a blip?) exceed their thresholds;
  the alert clears when the fast window recovers. Fast-window
  confirmation keeps a long-past incident from paging forever; the
  slow-window condition keeps one bad epoch from paging at all.
- **remaining budget / time-to-exhaustion** — the fraction of the
  run's total allowed misses still unspent, and how many epochs the
  current slow-window miss rate would take to spend the rest.

``compute`` is pure numpy over recorded series (cumulative sums, O(T))
and runs after the simulation — it reads no live state and changes no
results. ``emit_events`` mirrors alerts into the active obs recorder as
``slo.*`` events (null-recorder no-op), which ``obsview`` folds into
the run timeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro import obs


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Error-budget policy: target attainment + alert windows.

    Defaults follow the SRE playbook shape scaled to epoch units: the
    fast window is ~minutes-equivalent (8 epochs), the slow window
    ~an hour-equivalent (32 epochs); page at a 4x slow burn confirmed
    by an 8x fast burn.
    """
    target: float = 0.95          # SLO attainment objective in [0, 1)
    fast_window: int = 8          # epochs; "is it happening right now?"
    slow_window: int = 32         # epochs; "is it material?"
    fast_burn: float = 8.0        # page threshold on the fast window
    slow_burn: float = 4.0        # page threshold on the slow window

    def __post_init__(self):
        if not 0.0 <= self.target < 1.0:
            raise ValueError(f"target must be in [0, 1), got "
                             f"{self.target}")
        if self.fast_window < 1 or self.slow_window < self.fast_window:
            raise ValueError("windows must satisfy 1 <= fast_window <= "
                             f"slow_window, got {self.fast_window}/"
                             f"{self.slow_window}")

    @property
    def budget(self) -> float:
        return 1.0 - self.target


def _windowed_rate(cum: np.ndarray, window: int) -> np.ndarray:
    """Trailing-window sum / epoch count from a cumulative series; the
    first ``window`` epochs use the partial window actually observed."""
    T = cum.shape[0]
    lo = np.maximum(np.arange(T) - window + 1, 0)
    prev = np.where(lo > 0, cum[lo - 1], 0.0)
    return cum - prev, np.arange(T) - lo + 1


def _burn(cum_miss, cum_off, window, budget):
    miss_w, _ = _windowed_rate(cum_miss, window)
    off_w, _ = _windowed_rate(cum_off, window)
    with np.errstate(invalid="ignore", divide="ignore"):
        rate = np.where(off_w > 0, miss_w / np.maximum(off_w, 1e-12), 0.0)
    return rate / budget


@dataclasses.dataclass
class SLOReport:
    """Error-budget outcome for one run's timeline."""
    cfg: SLOConfig
    epochs: int
    offered: int                  # total requests offered
    misses: int                   # SLO misses + drops
    budget_remaining: float       # fraction of allowed misses unspent
    time_to_exhaustion: Optional[float]   # epochs; None = never
    burn_fast: np.ndarray         # per-epoch fast-window burn rate
    burn_slow: np.ndarray         # per-epoch slow-window burn rate
    alerts: List[Dict]            # fired pages: start/end/peak burns
    epoch: np.ndarray             # the epoch axis the burns index

    @property
    def attainment(self) -> float:
        return 1.0 - self.misses / self.offered if self.offered else 1.0

    def summary(self) -> Dict:
        """The scalar slice ComparisonReport folds per policy/seed."""
        return {
            "target": self.cfg.target,
            "attainment": self.attainment,
            "budget_remaining": self.budget_remaining,
            "time_to_exhaustion_epochs": self.time_to_exhaustion,
            "alerts": len(self.alerts),
            "page_epochs": int(sum(
                (a["end"] if a["end"] is not None else self.epochs)
                - a["start"] for a in self.alerts)),
            "max_burn_fast": float(self.burn_fast.max())
            if self.burn_fast.size else 0.0,
            "max_burn_slow": float(self.burn_slow.max())
            if self.burn_slow.size else 0.0,
        }

    def to_json(self) -> Dict:
        return {**self.summary(),
                "fast_window": self.cfg.fast_window,
                "slow_window": self.cfg.slow_window,
                "fast_burn": self.cfg.fast_burn,
                "slow_burn": self.cfg.slow_burn,
                "alerts_detail": list(self.alerts),
                "burn_fast": [round(float(v), 4) for v in self.burn_fast],
                "burn_slow": [round(float(v), 4) for v in self.burn_slow],
                "epoch": [int(e) for e in self.epoch]}


def compute(epoch, arrivals, slo_hits,
            cfg: Optional[SLOConfig] = None) -> SLOReport:
    """Error budgets from per-epoch series: ``arrivals`` are offered
    requests (drops included), ``slo_hits`` the requests that met the
    deadline — misses are their difference, so drops burn budget."""
    cfg = cfg if cfg is not None else SLOConfig()
    epoch = np.asarray(epoch, np.int64)
    off = np.asarray(arrivals, np.float64)
    miss = off - np.asarray(slo_hits, np.float64)
    T = epoch.shape[0]
    cum_off, cum_miss = np.cumsum(off), np.cumsum(miss)
    burn_fast = _burn(cum_miss, cum_off, cfg.fast_window, cfg.budget)
    burn_slow = _burn(cum_miss, cum_off, cfg.slow_window, cfg.budget)

    # multi-window page state machine: fire when both windows breach,
    # clear when the fast window recovers
    alerts: List[Dict] = []
    active: Optional[Dict] = None
    for i in range(T):
        firing = (burn_fast[i] > cfg.fast_burn
                  and burn_slow[i] > cfg.slow_burn)
        if active is None and firing:
            active = {"start": int(epoch[i]), "end": None,
                      "peak_burn_fast": float(burn_fast[i]),
                      "peak_burn_slow": float(burn_slow[i])}
            alerts.append(active)
        elif active is not None:
            if burn_fast[i] <= cfg.fast_burn:
                active["end"] = int(epoch[i])
                active = None
            else:
                active["peak_burn_fast"] = max(active["peak_burn_fast"],
                                               float(burn_fast[i]))
                active["peak_burn_slow"] = max(active["peak_burn_slow"],
                                               float(burn_slow[i]))

    total_off = float(cum_off[-1]) if T else 0.0
    total_miss = float(cum_miss[-1]) if T else 0.0
    allowed = cfg.budget * total_off
    remaining = max(0.0, 1.0 - total_miss / allowed) if allowed > 0 \
        else 1.0
    # exhaustion horizon at the current slow-window miss pace
    tte: Optional[float] = None
    if T and remaining > 0.0:
        miss_w, n_w = _windowed_rate(cum_miss, cfg.slow_window)
        recent = miss_w[-1] / max(n_w[-1], 1)
        if recent > 0:
            tte = remaining * allowed / recent
    elif remaining == 0.0:
        tte = 0.0
    return SLOReport(cfg=cfg, epochs=T, offered=int(total_off),
                     misses=int(total_miss), budget_remaining=remaining,
                     time_to_exhaustion=tte, burn_fast=burn_fast,
                     burn_slow=burn_slow, alerts=alerts, epoch=epoch)


def emit_events(report: SLOReport) -> None:
    """Mirror the report into the active obs recorder (no-op when
    recording is off): one ``slo.burn_alert``/``slo.burn_clear`` pair
    per page plus a final ``slo.budget`` summary event."""
    for a in report.alerts:
        obs.event("slo.burn_alert", epoch=a["start"],
                  burn_fast=a["peak_burn_fast"],
                  burn_slow=a["peak_burn_slow"])
        if a["end"] is not None:
            obs.event("slo.burn_clear", epoch=a["end"])
    obs.event("slo.budget", target=report.cfg.target,
              attainment=report.attainment,
              remaining=report.budget_remaining,
              alerts=len(report.alerts),
              time_to_exhaustion=report.time_to_exhaustion)

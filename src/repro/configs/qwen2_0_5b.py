"""qwen2-0.5b [dense] — GQA (kv=2), QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    source="arXiv:2407.10671 (Qwen2 Technical Report)",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151_936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    mlp_act="swiglu",
    tie_embeddings=True,
))

"""repro.policies: canonical registry semantics, the PPO path exposed
end-to-end, and trained-policy artifacts (train → save → load → act
bit-identical)."""
import jax
import numpy as np
import pytest

from repro.core import make_paper_env
from repro.policies import (A2CPolicy, build_policy, get_policy_spec,
                            policy_names)
from repro.scenarios import get_scenario, run_scenario
from repro.sim.traces import RandomRateTrace


# --------------------------------------------------------------------------
# registry: one canonical name per policy, clear misses
# --------------------------------------------------------------------------

def test_registry_has_canonical_roster():
    names = policy_names()
    for name in ("a2c", "ppo", "greedy_oracle", "device_only",
                 "full_offload", "random"):
        assert name in names, names
    assert get_policy_spec("a2c").trainable
    assert get_policy_spec("ppo").trainable
    assert not get_policy_spec("greedy_oracle").trainable


def test_registry_miss_lists_valid_names():
    """The historical 'oracle' alias is gone: one canonical name per
    policy, and a miss names every valid one."""
    with pytest.raises(KeyError) as e:
        get_policy_spec("oracle")
    msg = str(e.value)
    for name in policy_names():
        assert name in msg
    with pytest.raises(KeyError):
        build_policy("no-such-policy", *make_paper_env())


def test_static_policy_has_no_artifact_lifecycle():
    cfg, tables = make_paper_env()
    pol = build_policy("device_only", cfg, tables)
    with pytest.raises(NotImplementedError):
        pol.save("/tmp/unused.npz")
    with pytest.raises(NotImplementedError):
        pol.train()


def test_untrained_policy_refuses_to_act():
    cfg, tables = make_paper_env()
    pol = build_policy("a2c", cfg, tables, episodes=1)
    state = {"model_id": np.zeros(cfg.n_uavs, np.int32)}
    with pytest.raises(RuntimeError, match="train"):
        pol.act(state, jax.random.key(0))


# --------------------------------------------------------------------------
# PPO exposed end-to-end: registry -> scenario -> paired mmpp comparison
# --------------------------------------------------------------------------

def test_ppo_mmpp_comparison_smoke():
    """PPO trains (trace-driven, like A2C) and runs through the same
    scenario entry point as every other policy, paired request streams
    included."""
    sc = get_scenario("paper-mmpp-burst")
    rep = run_scenario(sc, ("ppo", "device_only"), n_requests=1200,
                       seeds=(0,), episodes=3)
    ppo, dev = rep.results["ppo"], rep.results["device_only"]
    assert ppo.trained and not dev.trained
    # same seed -> identical offered request stream (paired comparison)
    assert ppo.per_seed[0]["requests"] == dev.per_seed[0]["requests"]
    for r in (ppo, dev):
        assert np.isfinite(r.mean["p95"])
        assert 0.0 <= r.mean["slo_attainment"] <= 1.0


# --------------------------------------------------------------------------
# artifacts: train -> save -> load -> act, bit-identical
# --------------------------------------------------------------------------

def _some_states(cfg, tables, n=4):
    from repro.core import env_reset
    return [env_reset(cfg, tables, jax.random.key(1000 + i))
            for i in range(n)]


@pytest.mark.parametrize("name,batch_envs", [("a2c", 1), ("a2c", 2),
                                             ("ppo", 1)])
def test_checkpoint_round_trip_bit_identical(tmp_path, name, batch_envs):
    """A policy trained with any batch_envs setting saves one artifact
    that reloads into a fresh instance and reproduces bit-identical
    actions under the same rng."""
    cfg, tables = make_paper_env(peak_rps=20.0)
    trained = build_policy(name, cfg, tables, episodes=3,
                           batch_envs=batch_envs)
    trained.train(seed=0, trace=RandomRateTrace(max_rps=20.0))
    path = str(tmp_path / f"{name}_E{batch_envs}.npz")
    trained.save(path)

    fresh = build_policy(name, cfg, tables, episodes=3,
                         batch_envs=batch_envs)
    fresh.load(path)
    for state in _some_states(cfg, tables):
        rng = jax.random.key(7)
        np.testing.assert_array_equal(
            np.asarray(trained.act(state, rng)),
            np.asarray(fresh.act(state, rng)))


def test_load_retraces_the_jitted_decide(tmp_path):
    """``Policy.jitted`` must not serve a decide step compiled against
    stale params after load() swaps them."""
    cfg, tables = make_paper_env()
    pol = build_policy("a2c", cfg, tables, episodes=2)
    pol.train(seed=0)
    before = pol.jitted()
    assert pol.jitted() is before          # stable while params are
    path = str(tmp_path / "ctrl.npz")
    pol.save(path)
    pol.load(path)
    assert pol.jitted() is not before      # params swapped -> re-traced


def test_artifact_refuses_wrong_policy_and_env(tmp_path):
    cfg, tables = make_paper_env(peak_rps=20.0)
    # directly-constructed (not registry-built) policies carry the same
    # canonical name, so their artifacts interoperate with build_policy
    a2c = A2CPolicy(cfg, tables, episodes=2)
    assert a2c.name == "a2c"
    a2c.train(seed=0)
    path = str(tmp_path / "ctrl.npz")
    a2c.save(path)
    loaded = build_policy("a2c", cfg, tables, episodes=2).load(path)
    state = _some_states(cfg, tables, n=1)[0]
    np.testing.assert_array_equal(
        np.asarray(a2c.act(state, jax.random.key(0))),
        np.asarray(loaded.act(state, jax.random.key(0))))
    # wrong algorithm: meta check (match the quoted algo, not the path)
    with pytest.raises(ValueError, match="holds a 'a2c'"):
        build_policy("ppo", cfg, tables, episodes=2).load(path)
    # wrong fleet size: structure/shape check
    cfg6, tables6 = make_paper_env(n_uavs=6, peak_rps=20.0)
    with pytest.raises(ValueError):
        build_policy("a2c", cfg6, tables6, episodes=2).load(path)

"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, (rec,rec,attn).

26 layers with repeating (recurrent, recurrent, local-attention) pattern
per the Griffin paper; remainder layers are recurrent. [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    local_window=2048,
    use_rope=True,
    rope_theta=10_000.0,
    norm="rmsnorm",
    mlp_act="geglu",
    tie_embeddings=True,
))

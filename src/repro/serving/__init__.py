from repro.serving.engine import ServeConfig, ServingEngine, SplitServingEngine
from repro.serving.scheduler import ContinuousBatchingServer, Request

__all__ = ["ServeConfig", "ServingEngine", "SplitServingEngine",
           "ContinuousBatchingServer", "Request"]

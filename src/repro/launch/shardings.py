"""Logical-axis -> mesh resolution and NamedSharding construction.

Every param / cache / batch leaf carries a tuple of logical axis names
(see models/params.py). ``resolve_spec`` greedily assigns mesh axes to
dims left-to-right, honoring divisibility and never reusing a mesh axis
within one spec — so e.g. mixtral's 8 experts fall back to ff-sharding
on a 16-way model axis, and batch=1 long-context decode falls through to
context (cache-sequence) parallelism automatically.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes, model_axes


def logical_rules(cfg: ModelConfig, mesh: Mesh):
    """logical axis name -> ordered tuple of candidate mesh axes."""
    model = model_axes(mesh)
    data = data_axes(mesh)
    msize = 1
    for a in model:
        msize *= mesh.shape[a]
    return {
        "vocab": model,
        # FSDP: weight input dims shard over data (GSPMD all-gathers at use,
        # reduce-scatters grads) — ZeRO-3 semantics via the same resolver
        "embed": data if cfg.fsdp else (),
        "heads": model,
        "kv_heads": model if cfg.n_kv_heads % msize == 0 else (),
        "ff": model,
        "experts": model if (cfg.n_experts and cfg.n_experts % msize == 0) else (),
        "inner": model,
        "lru": model,
        "layers": (),
        "batch": data,
        "kv_cache_seq": data,        # context parallelism when batch won't shard
        "seq": (),
    }


def resolve_spec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                 rules, mesh: Mesh) -> PartitionSpec:
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        want = tuple(rules.get(name, ()) if name is not None else ())
        # candidate assignments: the full tuple first, then suffix/single axes
        candidates = [want]
        if len(want) > 1:
            candidates += [(a,) for a in want]
        assigned = None
        for cand in candidates:
            if not cand or any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                assigned = cand
                used.update(cand)
                break
        if assigned is None:
            out.append(None)
        elif len(assigned) == 1:
            out.append(assigned[0])
        else:
            out.append(assigned)
    return PartitionSpec(*out)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def tree_shardings(mesh: Mesh, axes_tree, shape_tree, rules):
    """axes_tree: pytree of logical-axis tuples; shape_tree: matching pytree
    of arrays / ShapeDtypeStructs. Returns pytree of NamedShardings."""
    axes_leaves, treedef = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)
    shape_leaves = treedef.flatten_up_to(shape_tree)
    shardings = [
        NamedSharding(mesh, resolve_spec(a, s.shape, rules, mesh))
        for a, s in zip(axes_leaves, shape_leaves)]
    return jax.tree.unflatten(treedef, shardings)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())

"""Flash-decode: single-token GQA attention over a ring KV cache, Pallas.

Decode attention is memory-bound (stream the whole cache per token); the
kernel tiles the cache sequence into VMEM blocks, carries the online-softmax
state in scratch, and applies the ring-buffer positional mask *inside* the
kernel (slot s holds absolute position pos - ((pos - s) mod C); slots with
negative positions or outside the sliding window are masked) — so the same
kernel serves full-cache decode_32k and windowed long_500k.

Layout: q (B, H, Dh); k, v (B, HK, C, Dh); pos scalar int32.
grid = (B, H, C/bk); the kv grid dim is sequential and accumulates.
Oracle: models/attention.py decode path (plain_attention over ring cache).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, window: Optional[int],
                   bk: int, nk: int, cache_len: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)             # (Dh,)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, Dh)
    v = v_ref[0, 0].astype(jnp.float32)             # (bk, Dv)

    s = jnp.sum(k * q[None, :], axis=-1) * scale    # (bk,)

    # ring-buffer positional mask
    slots = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    slot_pos = pos - jnp.mod(pos - slots, cache_len)
    mask = (slot_pos >= 0) & (slots < cache_len)
    if window is not None:
        mask &= (pos - slot_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_scr[0] * alpha + jnp.sum(p)
    acc_new = acc_scr[...] * alpha + jnp.sum(p[:, None] * v, axis=0)

    m_scr[0] = m_new
    l_scr[0] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[0], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret",
                                             "logit_scale"))
def flash_decode(q, k, v, pos, *, window: Optional[int] = None,
                 logit_scale: Optional[float] = None, bk: int = 128,
                 interpret: bool = True):
    """q: (B, H, Dh); k, v: (B, HK, C, Dh) ring caches; pos: () int32.

    Returns (B, H, Dv). The current token must already be written at slot
    pos % C (matching models/attention.py decode semantics).
    """
    B, H, Dh = q.shape
    _, HK, C, Dv = v.shape
    assert H % HK == 0
    scale = logit_scale if logit_scale is not None else Dh ** -0.5
    bk = min(bk, C)

    def pad(x):
        p = (-x.shape[2]) % bk
        if p == 0:
            return x
        return jnp.pad(x, ((0, 0), (0, 0), (0, p), (0, 0)))

    k_, v_ = pad(k), pad(v)
    nk = k_.shape[2] // bk
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               bk=bk, nk=nk, cache_len=C)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # pos scalar
            pl.BlockSpec((1, 1, Dh), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j: (b, h % HK, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, j: (b, h % HK, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Dv), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((Dv,), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k_, v_)
    return out

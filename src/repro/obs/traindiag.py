"""repro.obs.traindiag — per-update learner health for A2C/PPO.

The training loops already jit one update per episode batch; this module
adds the standard RL health panel *inside* that jitted update — entropy,
approximate KL, gradient global-norm, explained variance of the value
function, and the advantage distribution — carried out as auxiliary
outputs of the existing ``train_episode`` functions. Nothing here runs
host code on a traced path and nothing changes the update itself: the
diagnostics are pure functions of tensors the update already computes
(plus, for A2C's approx-KL, one extra post-update policy evaluation),
so the PR 6 zero-retrace regression guarantee extends to them
(``jaxmon.count_trace`` sites in a2c/ppo assert exactly one trace per
shape signature).

Reading the panel:

- **entropy** (per device) — falling too fast means premature collapse
  onto one (version, cut-point) arm; flat at the max means the policy
  never left uniform.
- **approx_kl** — mean(logp_old - logp_new) over the update's batch,
  the cheap KL estimate from the PPO literature. Spikes flag
  destructively large steps (A2C) or clipping that has stopped binding
  (PPO).
- **grad_norm** — global norm *before* clipping, from the AdamW
  telemetry; pinned at the clip threshold means the trust region is
  the clip, not the loss surface.
- **explained_var** — 1 - Var[R - V]/Var[R]; 0 means the critic is a
  constant, 1 a perfect fit, negative worse than predicting the mean.
- **adv_mean/adv_std** — the advantage distribution the actor actually
  trains on (pre-normalization); a collapsing std starves the policy
  gradient of signal.

``TrainDiag`` is the host-side columnar view (``EpochLog`` discipline)
built from a training ``history`` list; ``fleetview.py`` renders it as
the learner panel of the flight-recorder dashboard.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

# the per-update series a diagnosed history carries (superset of the
# base stats; missing keys render as absent, not as errors)
DIAG_KEYS = ("entropy", "approx_kl", "grad_norm", "explained_var",
             "adv_mean", "adv_std")


# --------------------------------------------------------------------------
# in-jit helpers (pure jnp; called from inside train_episode)
# --------------------------------------------------------------------------

def explained_variance(returns, values):
    """1 - Var[R - V] / Var[R], the standard critic-fit score; defined
    as 0 when the return batch is constant (Var[R] = 0)."""
    var_r = jnp.var(returns)
    return jnp.where(var_r > 0.0,
                     1.0 - jnp.var(returns - values) / (var_r + 1e-12),
                     0.0)


def approx_kl(logp_old, logp_new):
    """mean(logp_old - logp_new): the first-order KL(old || new)
    estimator — cheap, unbiased in expectation, computed on tensors the
    update already holds."""
    return jnp.mean(logp_old - logp_new)


# --------------------------------------------------------------------------
# host-side accumulator / report
# --------------------------------------------------------------------------

class TrainDiag:
    """Columnar per-update diagnostics view over a training history.

    ``history`` is the list of float dicts ``a2c.train``/``ppo.train``
    return (one per update). Columns are typed numpy arrays; keys a run
    didn't record are simply absent.
    """

    def __init__(self, columns: Dict[str, np.ndarray]):
        self._cols = dict(columns)

    @classmethod
    def from_history(cls, history: List[Dict]) -> "TrainDiag":
        if not history:
            return cls({})
        keys = [k for k in history[0] if isinstance(history[0][k],
                                                    (int, float))]
        return cls({k: np.asarray([h.get(k, np.nan) for h in history],
                                  np.float64) for k in keys})

    @property
    def updates(self) -> int:
        return len(next(iter(self._cols.values()))) if self._cols else 0

    @property
    def keys(self) -> List[str]:
        return [k for k in DIAG_KEYS if k in self._cols]

    def column(self, key: str) -> np.ndarray:
        return self._cols[key]

    def __contains__(self, key: str) -> bool:
        return key in self._cols

    def summary(self) -> Dict:
        """First/last/min/max per diagnostic — the scalar slice for
        reports and smoke assertions."""
        out: Dict = {"updates": self.updates}
        for k in self.keys:
            c = self._cols[k]
            ok = c[~np.isnan(c)]
            if ok.size == 0:
                continue
            out[k] = {"first": float(ok[0]), "last": float(ok[-1]),
                      "min": float(ok.min()), "max": float(ok.max())}
        return out

    def to_json(self) -> Dict:
        return {"updates": self.updates,
                "series": {k: [None if np.isnan(v) else round(float(v), 6)
                               for v in self._cols[k]]
                           for k in self.keys},
                "summary": self.summary()}


def check_health(diag: "TrainDiag", *,
                 kl_limit: float = 1.0,
                 entropy_floor: float = 1e-4) -> List[str]:
    """Cheap post-hoc lints over a finished run: returns human-readable
    warnings (empty = clean). Advisory only — nothing gates on these."""
    warnings: List[str] = []
    if "approx_kl" in diag:
        kl = diag.column("approx_kl")
        bad = np.abs(kl[~np.isnan(kl)])
        if bad.size and bad.max() > kl_limit:
            warnings.append(
                f"approx_kl peaked at {bad.max():.3f} (> {kl_limit}): "
                "destructively large policy steps")
    if "entropy" in diag:
        ent = diag.column("entropy")
        ok = ent[~np.isnan(ent)]
        if ok.size and ok[-1] < entropy_floor:
            warnings.append(
                f"final entropy {ok[-1]:.2e} < {entropy_floor}: policy "
                "collapsed to a deterministic arm")
    if "explained_var" in diag:
        ev = diag.column("explained_var")
        ok = ev[~np.isnan(ev)]
        if ok.size and ok[-1] < 0.0:
            warnings.append(
                f"final explained variance {ok[-1]:+.3f} < 0: the critic "
                "predicts worse than the return mean")
    return warnings

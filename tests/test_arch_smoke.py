"""Per-assigned-architecture smoke tests (deliverable f).

For each of the 10 architectures: instantiate the REDUCED variant of the
same family (<=2 layers per stack, d_model<=512, <=4 experts) and run one
forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill -> decode step consistency check.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import (decode_step, forward_logits, forward_train, init,
                          init_cache, prefill)
from tests.conftest import make_batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            cache[name] = (cfg, init(cfg, jax.random.key(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch, built):
    cfg, params = built(arch)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: forward_train(cfg, p, b, remat=False))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes(arch, built):
    cfg, params = built(arch)
    batch = make_batch(cfg)
    logits = jax.jit(lambda p, b: forward_logits(cfg, p, b))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, built):
    """decode_step(prefill cache) logits == full-forward logits at that pos."""
    cfg, params = built(arch)
    B, S = 2, 16
    batch = make_batch(cfg, B, S + 1, seed=1)
    pre = {k: (v[:, :S] if k in ("tokens", "targets") else v)
           for k, v in batch.items()}
    del pre["targets"]
    logits_pre, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, total_len=S + 1))(params, pre)

    # full forward over S+1 tokens; position S-1 must match prefill output
    full = {k: v for k, v in batch.items() if k != "targets"}
    logits_full = jax.jit(lambda p, b: forward_logits(cfg, p, b))(params, full)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)

    # decode token S: must match full forward at position S
    tok = batch["tokens"][:, S]
    logits_dec, _ = jax.jit(
        lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))(
            params, cache, tok, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cache_structure_matches_init_cache(arch, built):
    cfg, params = built(arch)
    batch = make_batch(cfg)
    del batch["targets"]
    _, cache = jax.jit(lambda p, b: prefill(cfg, p, b))(params, batch)
    c0 = init_cache(cfg, 2, 16)
    assert jax.tree.structure(cache) == jax.tree.structure(c0)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(c0)):
        assert a.shape == b.shape

from repro.models import model
from repro.models.model import (
    init, plan_model, abstract_params, param_axes, n_params,
    forward_train, forward_logits, prefill, decode_step, init_cache,
    stack_defs, enc_stack_defs,
)

__all__ = [
    "model", "init", "plan_model", "abstract_params", "param_axes",
    "n_params", "forward_train", "forward_logits", "prefill", "decode_step",
    "init_cache", "stack_defs", "enc_stack_defs",
]

"""jit'd wrappers dispatching between Pallas kernels and jnp references.

``use_pallas()`` reads REPRO_USE_PALLAS: "interpret" (CPU validation),
"tpu" (real lowering on hardware), or unset/0 (pure-jnp path — default in
this CPU container; the models call these wrappers so flipping one env var
moves the whole stack onto the kernels).
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.mamba_scan import mamba_scan as _mamba_scan
from repro.kernels.quant_matmul import quant_matmul as _quant_matmul
from repro.kernels.quant_matmul import quant_matmul_ref as _quant_matmul_ref
from repro.kernels.rglru_scan import rglru_scan as _rglru_scan
from repro.kernels import ref
from repro.quant.quantize import QTensor, quantize_act


def use_pallas() -> Optional[str]:
    v = os.environ.get("REPRO_USE_PALLAS", "").lower()
    if v in ("interpret", "tpu"):
        return v
    return None


def quantized_dense(x, w: QTensor):
    """Dense projection against a quantized weight leaf.

    Weight-only leaves (w8 / packed w4) dequantize to f32 and use the
    plain matmul; w8a8 leaves quantize the activations per row and run the
    int8 x int8 -> int32 path — the Pallas kernel when REPRO_USE_PALLAS is
    set, the jnp oracle otherwise. models/layers.py::dense routes every
    dense projection here, so a quantized param tree changes no model code.
    """
    if w.act_bits == 8 and w.bits == 8:
        xq, xs = quantize_act(x)
        lead = x.shape[:-1]
        xq2 = xq.reshape(-1, x.shape[-1])
        xs2 = xs.reshape(-1)
        ws = w.scale.reshape(-1)
        mode = use_pallas()
        if mode:
            out = _quant_matmul(xq2, w.q, xs2, ws,
                                interpret=(mode == "interpret"))
        else:
            out = _quant_matmul_ref(xq2, w.q, xs2, ws)
        return out.reshape(*lead, -1).astype(x.dtype)
    return x @ w.dequantize().astype(x.dtype)


def attention_bhsd(q, k, v, *, causal=True, window=None, logit_scale=None):
    """(B,H,S,D) attention via flash kernel or oracle."""
    mode = use_pallas()
    if mode:
        return _flash(q, k, v, causal=causal, window=window,
                      logit_scale=logit_scale,
                      interpret=(mode == "interpret"))
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_scale=logit_scale)


def mamba_scan_full(cfg, p, u, dt, Bm, Cm):
    """Selective scan incl. D-skip. u/dt: (B,S,DI); Bm/Cm: (B,S,N)."""
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    mode = use_pallas()
    if mode:
        y, h = _mamba_scan(u.astype(jnp.float32), dt, Bm, Cm, A,
                           interpret=(mode == "interpret"))
        y = y + u.astype(jnp.float32) * p["d_skip"][None, None]
        return y.astype(u.dtype), h
    from repro.models.ssm import ssm_scan_chunked
    return ssm_scan_chunked(cfg, p, u)


def rglru_scan_full(a, gx):
    """Diagonal recurrence. a/gx: (B,S,W) f32 -> (h_seq, h_last)."""
    mode = use_pallas()
    if mode:
        return _rglru_scan(a, gx, interpret=(mode == "interpret"))
    return ref.rglru_scan_ref(a, gx)


def decode_attention(q_bhd, k_cache, v_cache, pos, *, window=None,
                     logit_scale=None):
    """Single-token ring-cache attention. q: (B,H,Dh); caches (B,HK,C,Dh)."""
    mode = use_pallas()
    if mode:
        return _flash_decode(q_bhd, k_cache, v_cache, pos, window=window,
                             logit_scale=logit_scale,
                             interpret=(mode == "interpret"))
    from repro.models.attention import slot_positions
    from repro.models.attention_core import plain_attention
    C = k_cache.shape[2]
    kv_pos = slot_positions(jnp.asarray(pos, jnp.int32), C)
    out = plain_attention(
        q_bhd[:, None], k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        q_positions=jnp.asarray(pos, jnp.int32).reshape(1),
        kv_positions=kv_pos, causal=True, window=window,
        logit_scale=logit_scale)
    return out[:, 0]

"""repro.cluster: heterogeneous edge-server pool with learned routing.

Widens the EdgeRL action space from (version, cut) to (version, cut,
server): a ``ServerPool`` of per-server service rates / DVFS / replicas
(pool.py), a device->server link ``Topology`` repricing the Eq. 2/3
transmission terms per target (topology.py), and an AutoScale-style
``Autoscaler`` trading replica energy against queue wait (autoscale.py).
Router baselines (round_robin / join_shortest_queue / local_only) live
in routers.py and register themselves into the ``repro.policies``
registry — imported from ``repro.policies`` (not here) to keep this
package importable from ``core.env`` without a cycle.
"""
from repro.cluster.autoscale import Autoscaler, AutoscalerConfig
from repro.cluster.pool import (ClusterParams, PoolEffective, ServerPool,
                                ServerSpec, build_cluster, get_pool,
                                pool_names, register_pool)
from repro.cluster.topology import (Topology, get_topology,
                                    register_topology, topology_names)

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ClusterParams", "PoolEffective",
    "ServerPool", "ServerSpec", "Topology", "build_cluster", "get_pool",
    "get_topology", "pool_names", "register_pool", "register_topology",
    "topology_names",
]

"""Training launcher: real execution on the local device(s).

For the production-mesh *dry-run* (lower+compile only, 512 virtual
devices), use ``python -m repro.launch.dryrun``. This launcher actually
trains: reduced configs on CPU, full configs on real TPU slices.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import ALL_ARCHS, get_config
from repro.checkpointing import save_checkpoint
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models import init, n_params
from repro.optim import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--quiet", action="store_true",
                    help="warnings only on the console")
    args = ap.parse_args()
    if args.quiet:
        obs.set_verbosity(0)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    obs.info(f"arch={args.arch} reduced={args.reduced} "
             f"params={n_params(cfg):,} devices={jax.device_count()}")
    params = init(cfg, jax.random.key(0))
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=cfg.train_remat,
                                      microbatches=args.microbatches))
    ds = SyntheticLMDataset(cfg, DataConfig(batch_size=args.batch,
                                            seq_len=args.seq))
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        with obs.span("train.step", step=i):
            params, opt_state, m = step_fn(params, opt_state, batch)
        if (i + 1) % max(args.steps // 10, 1) == 0:
            obs.info(f"step {i+1:4d} loss={float(m['loss']):.4f} "
                     f"gnorm={float(m['grad_norm']):.2f}")
    obs.info(f"{args.steps} steps in {time.time()-t0:.1f}s")
    if args.ckpt_dir:
        obs.info("checkpoint: " + save_checkpoint(args.ckpt_dir, args.steps,
                                                  params))


if __name__ == "__main__":
    main()

"""Continuous-batching scheduler: admission, retirement, correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init
from repro.serving import ServeConfig, ServingEngine
from repro.serving.scheduler import ContinuousBatchingServer, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    return cfg, params


def test_all_requests_complete(setup):
    cfg, params = setup
    srv = ContinuousBatchingServer(cfg, params, max_batch=3, cache_len=64)
    r = np.random.default_rng(1)
    reqs = [Request(rid=i, tokens=r.integers(
                0, cfg.vocab_size, int(r.integers(3, 10))).astype(np.int32),
                max_new_tokens=4 + i % 3) for i in range(8)]
    for q in reqs:
        srv.submit(q)
    done = srv.run()
    assert len(done) == 8
    assert all(q.done for q in done)
    assert srv.stats.admitted == 8
    # never more than max_batch slots in flight
    assert srv.stats.prefills >= 3   # 8 requests through 3 slots


def test_matches_offline_engine(setup):
    """Same-prompt cohort must produce the same tokens as the plain engine."""
    cfg, params = setup
    r = np.random.default_rng(2)
    prompts = r.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    n_new = 5

    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=n_new,
                                                 cache_len=64))
    want = np.asarray(eng.generate({"tokens": jnp.asarray(prompts)}))

    srv = ContinuousBatchingServer(cfg, params, max_batch=2, cache_len=64)
    for i in range(2):
        srv.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=n_new))
    done = sorted(srv.run(), key=lambda q: q.rid)
    got = np.asarray([q.out for q in done])
    np.testing.assert_array_equal(got, want)


def test_eos_early_stop(setup):
    cfg, params = setup
    srv = ContinuousBatchingServer(cfg, params, max_batch=1, cache_len=64)
    # pick eos = the model's first greedy token so it stops immediately
    probe = ContinuousBatchingServer(cfg, params, max_batch=1, cache_len=64)
    probe.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=1))
    first = probe.run()[0].out[0]
    srv.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                       max_new_tokens=50, eos_id=first))
    done = srv.run()
    assert len(done[0].out) == 1   # stopped at eos immediately


def test_individual_retirement_refills_slot(setup):
    """A finished request must free its slot for new admission while its
    cohort-mates keep decoding — and compaction must not corrupt their
    token streams."""
    cfg, params = setup
    prompts = np.random.default_rng(4).integers(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)
    n_long = 10
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=n_long,
                                                 cache_len=64))
    want = np.asarray(eng.generate({"tokens": jnp.asarray(prompts)}))

    srv = ContinuousBatchingServer(cfg, params, max_batch=2, cache_len=64)
    srv.submit(Request(rid=0, tokens=prompts[0], max_new_tokens=2))
    srv.submit(Request(rid=1, tokens=prompts[1], max_new_tokens=n_long))
    srv.submit(Request(rid=2, tokens=prompts[0], max_new_tokens=2))
    done = sorted(srv.run(), key=lambda q: q.rid)
    assert [len(q.out) for q in done] == [2, n_long, 2]
    # the long request's tokens are unaffected by its mate retiring
    np.testing.assert_array_equal(done[1].out, want[1])
    # rid=2 was admitted into rid=0's reclaimed slot before rid=1 ended
    assert srv.stats.slot_reclaims >= 1
    assert srv.stats.prefills == 2
    assert done[2].first_token_step < done[1].done_step


def test_per_request_latency_stats_schema(setup):
    cfg, params = setup
    srv = ContinuousBatchingServer(cfg, params, max_batch=2, cache_len=64)
    for i in range(4):
        srv.submit(Request(rid=i, tokens=np.arange(3, dtype=np.int32),
                           max_new_tokens=3))
    done = srv.run()
    assert len(srv.stats.ttft_steps) == len(done) == 4
    assert len(srv.stats.e2e_steps) == 4
    assert all(t >= 1 for t in srv.stats.ttft_steps)
    assert all(e >= t for e, t in zip(srv.stats.e2e_steps,
                                      srv.stats.ttft_steps))
    summ = srv.stats.latency_summary(slo_steps=100.0)
    from repro.sim.metrics import LATENCY_SCHEMA
    for k in LATENCY_SCHEMA:
        assert k in summ, k
    assert summ["unit"] == "steps"
    assert summ["slo_attainment"] == 1.0


def test_ring_cache_overflow_truncates_instead_of_wrapping(setup):
    cfg, params = setup
    srv = ContinuousBatchingServer(cfg, params, max_batch=1, cache_len=16)
    srv.submit(Request(rid=0, tokens=np.arange(8, dtype=np.int32),
                       max_new_tokens=100))
    done = srv.run()
    assert done[0].truncated and done[0].done
    # prefill emits 1 token at pos 8; decode may run until pos hits 16
    assert len(done[0].out) == 1 + (16 - 8)
    assert srv.stats.truncated == 1
    # a prompt that cannot fit at all is rejected up front
    with pytest.raises(ValueError):
        srv.submit(Request(rid=1, tokens=np.arange(16, dtype=np.int32)))

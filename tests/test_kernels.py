"""Per-Pallas-kernel validation: shape/dtype sweeps, assert_allclose
against the ref.py pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels import ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("B,H,HK,Sq,Skv,D", [
    (1, 2, 2, 128, 128, 64),
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 256, 256, 128),
    (2, 4, 4, 200, 200, 64),      # non-multiple of block
    (1, 2, 1, 64, 320, 64),       # cross-length (non-causal)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_flash_attention_sweep(B, H, HK, Sq, Skv, D, dtype, causal, window):
    if not causal and Sq != Skv:
        pass  # cross-attention-like case still valid
    if causal and Sq != Skv:
        pytest.skip("causal requires aligned positions in this sweep")
    r = np.random.default_rng(hash((B, H, Sq, Skv, D)) % 2**31)
    q = jnp.asarray(r.normal(size=(B, H, Sq, D)), dtype)
    k = jnp.asarray(r.normal(size=(B, HK, Skv, D)), dtype)
    v = jnp.asarray(r.normal(size=(B, HK, Skv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,S,DI,N", [
    (1, 128, 128, 8), (2, 256, 256, 16), (1, 384, 128, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_mamba_scan_sweep(B, S, DI, N, dtype):
    r = np.random.default_rng(1)
    u = jnp.asarray(r.normal(size=(B, S, DI)), dtype)
    dt = jnp.asarray(r.uniform(0.001, 0.1, size=(B, S, DI)), jnp.float32)
    Bm = jnp.asarray(r.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(r.normal(size=(B, S, N)), jnp.float32)
    A = -jnp.exp(jnp.asarray(r.normal(size=(DI, N)), jnp.float32))

    y, h = mamba_scan(u, dt, Bm, Cm, A, interpret=True)

    # reference: plain sequential recurrence
    def seq_ref():
        hh = np.zeros((B, DI, N), np.float32)
        ys = np.zeros((B, S, DI), np.float32)
        un, dtn = np.asarray(u, np.float32), np.asarray(dt)
        Bn, Cn, An = np.asarray(Bm), np.asarray(Cm), np.asarray(A)
        for t in range(S):
            dA = np.exp(dtn[:, t][..., None] * An[None])
            hh = dA * hh + (dtn[:, t] * un[:, t])[..., None] * Bn[:, t][:, None]
            ys[:, t] = np.einsum("bdn,bn->bd", hh, Cn[:, t])
        return ys, hh
    ys, hh = seq_ref()
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), hh, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,W", [(1, 128, 256), (2, 256, 512),
                                   (1, 384, 128)])
def test_rglru_scan_sweep(B, S, W):
    r = np.random.default_rng(2)
    a = jnp.asarray(r.uniform(0.7, 0.999, size=(B, S, W)), jnp.float32)
    gx = jnp.asarray(r.normal(size=(B, S, W)), jnp.float32)
    y, h = rglru_scan(a, gx, interpret=True)
    yr, hr = ref.rglru_scan_ref(a, gx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_model_level_pallas_parity(monkeypatch):
    """Whole reduced models agree between jnp path and interpret kernels."""
    from repro.configs import get_config
    from repro.models import forward_logits, init

    for name in ("qwen2-0.5b", "falcon-mamba-7b", "recurrentgemma-2b"):
        cfg = get_config(name).reduced()
        if name == "recurrentgemma-2b":
            cfg = cfg.with_overrides(local_window=128)
        params = init(cfg, jax.random.key(0))
        B, S = 2, 256
        toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 7919)
        batch = {"tokens": toks % cfg.vocab_size}
        monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
        want = forward_logits(cfg, params, batch)
        monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
        got = forward_logits(cfg, params, batch)
        monkeypatch.delenv("REPRO_USE_PALLAS")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("B,H,HK,C,D,pos,window", [
    (2, 4, 2, 128, 64, 50, None),    # partially filled cache
    (2, 4, 2, 128, 64, 127, None),   # exactly full
    (1, 8, 1, 256, 64, 300, 128),    # wrapped ring + window
    (2, 2, 2, 200, 32, 450, 96),     # non-multiple cache len, wrapped
    (1, 4, 4, 64, 128, 10, None),    # MHA small
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, H, HK, C, D, pos, window, dtype):
    from repro.kernels.flash_decode import flash_decode
    from repro.models.attention import slot_positions
    from repro.models.attention_core import plain_attention

    r = np.random.default_rng(hash((B, H, C, pos)) % 2**31)
    q = jnp.asarray(r.normal(size=(B, H, D)), dtype)
    k = jnp.asarray(r.normal(size=(B, HK, C, D)), dtype)
    v = jnp.asarray(r.normal(size=(B, HK, C, D)), dtype)
    out = flash_decode(q, k, v, jnp.int32(pos), window=window,
                       interpret=True)
    kv_pos = slot_positions(jnp.int32(pos), C)
    want = plain_attention(
        q[:, None], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        q_positions=jnp.asarray([pos], jnp.int32), kv_positions=kv_pos,
        causal=True, window=window)[:, 0]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_model_decode_kernel_parity(monkeypatch):
    from repro.configs import get_config
    from repro.models import decode_step, init, prefill

    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    toks = (jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) * 31) \
        % cfg.vocab_size
    _, cache = prefill(cfg, params, {"tokens": toks})
    tok = jnp.asarray([3, 5], jnp.int32)
    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    want, _ = decode_step(cfg, params, cache, tok, jnp.int32(16))
    monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
    got, _ = decode_step(cfg, params, cache, tok, jnp.int32(16))
    monkeypatch.delenv("REPRO_USE_PALLAS")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)

"""Device -> server network topology: the per-target link matrix.

A ``Topology`` holds two (n_devices, n_servers) matrices: a bandwidth
multiplier on each device's measured uplink rate (``link_scale``) and a
per-transfer propagation delay (``rtt_s``). The pricing core applies
them to the *chosen* server, repricing the paper's Eq. 2/3 transmission
terms per target: T_trans = 8 D / (B * scale[d, s]) + rtt[d, s] and
E_trans = P_tx * 8 D / (B * scale[d, s]).

Presets are registered under the same KeyError-listing convention as
``get_trace``/``get_schedule``; each factory takes (n_devices,
n_servers) plus preset-specific kwargs and may be deterministic or
seeded (``seed`` kwarg) — topologies are world *structure*, fixed for a
run, never drawn from the simulation's rng streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Per device -> server link matrix (row-major float tuples, so a
    cluster-mode EnvConfig stays hashable)."""
    name: str
    link_scale: Tuple[Tuple[float, ...], ...]   # (n, S)
    rtt_s: Tuple[Tuple[float, ...], ...]        # (n, S)

    @property
    def n_devices(self) -> int:
        return len(self.link_scale)

    @property
    def n_servers(self) -> int:
        return len(self.link_scale[0]) if self.link_scale else 0


def _mat(a) -> Tuple[Tuple[float, ...], ...]:
    return tuple(tuple(float(v) for v in row) for row in np.asarray(a))


_TOPOLOGIES: Dict[str, object] = {}


def register_topology(name: str, factory) -> None:
    if name in _TOPOLOGIES:
        raise ValueError(f"topology {name!r} already registered")
    _TOPOLOGIES[name] = factory


def topology_names() -> Tuple[str, ...]:
    return tuple(sorted(_TOPOLOGIES))


def get_topology(name: str, n_devices: int, n_servers: int,
                 **kw) -> Topology:
    """Named topology preset -> (n_devices, n_servers) link matrix; a
    miss lists every valid name (the get_trace convention)."""
    if name not in _TOPOLOGIES:
        raise KeyError(f"unknown topology {name!r}; valid topologies: "
                       f"{', '.join(topology_names())}")
    return _TOPOLOGIES[name](n_devices, n_servers, **kw)


def _uniform(n: int, S: int) -> Topology:
    """Every link at the device's measured rate, zero added delay — the
    degenerate topology under which a 1-server pool is bit-identical to
    the classic fleet (x1.0 and +0.0 are exact float identities)."""
    return Topology(name="uniform",
                    link_scale=_mat(np.ones((n, S))),
                    rtt_s=_mat(np.zeros((n, S))))


def _near_far(n: int, S: int, far_scale: float = 0.35,
              far_rtt_s: float = 0.02, near_rtt_s: float = 0.002) -> Topology:
    """Each device is radio-adjacent to one server (round-robin by
    device index) and reaches the rest over a degraded multi-hop path:
    ``far_scale`` of its measured rate plus ``far_rtt_s`` per transfer."""
    scale = np.full((n, S), far_scale)
    rtt = np.full((n, S), far_rtt_s)
    near = np.arange(n) % S
    scale[np.arange(n), near] = 1.0
    rtt[np.arange(n), near] = near_rtt_s
    return Topology(name="near-far", link_scale=_mat(scale),
                    rtt_s=_mat(rtt))


def _tiered(n: int, S: int, backhaul_scale: float = 0.5,
            hop_rtt_s: float = 0.01) -> Topology:
    """Server 0 is the shared close micro-edge (full rate, negligible
    delay); servers 1.. sit progressively deeper behind the backhaul,
    each hop halving the rate again and adding ``hop_rtt_s``."""
    scale = np.ones((n, S))
    rtt = np.zeros((n, S))
    for s in range(1, S):
        scale[:, s] = backhaul_scale ** s
        rtt[:, s] = hop_rtt_s * s
    return Topology(name="tiered", link_scale=_mat(scale), rtt_s=_mat(rtt))


register_topology("uniform", _uniform)
register_topology("near-far", _near_far)
register_topology("tiered", _tiered)

"""Coverage for launch/ and analysis/ layers that don't need 512 devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, SHAPES, get_config
from repro.launch import steps as st
from repro.launch.mesh import axis_size, data_axes, model_axes


class FakeMesh:
    def __init__(self, shape, names):
        self.shape = dict(zip(names, shape))
        self.axis_names = names


SINGLE = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_mesh_axis_helpers():
    assert data_axes(MULTI) == ("pod", "data")
    assert model_axes(MULTI) == ("model",)
    assert axis_size(MULTI, ("pod", "data")) == 32
    assert axis_size(SINGLE, ("data",)) == 16


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    """Abstract inputs exist for every (arch x shape) with correct dims."""
    cfg = get_config(arch)
    specs = st.input_specs(cfg, shape)
    info = SHAPES[shape]
    assert "params" in specs
    if info["kind"] == "train":
        assert specs["batch"]["tokens"].shape == (info["global_batch"],
                                                  info["seq_len"])
        assert "opt_state" in specs
    elif info["kind"] == "prefill":
        assert specs["batch"]["tokens"].shape == (info["global_batch"],
                                                  info["seq_len"])
        assert "targets" not in specs["batch"]
    else:
        assert specs["token"].shape == (info["global_batch"],)
        assert specs["pos"].shape == ()
        assert "cache" in specs
        # cache seq dims bounded by min(window, seq_len)
        ccfg = st.config_for_shape(cfg, shape)
        if not ccfg.ssm:
            leaves = jax.tree.leaves(specs["cache"])
            assert max(l.shape[2] if l.ndim > 2 else 0 for l in leaves) \
                <= info["seq_len"]


def test_config_for_shape_long_context_versions():
    """long_500k must select a sub-quadratic version for every arch."""
    for arch in ALL_ARCHS:
        cfg = st.config_for_shape(get_config(arch), "long_500k")
        ok = (cfg.ssm or cfg.block_pattern or cfg.sliding_window is not None)
        assert ok, arch


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b",
                                  "deepseek-v2-lite-16b", "falcon-mamba-7b"])
def test_step_shardings_structure(arch):
    """Sharding trees mirror input-spec trees, with legal specs."""
    from repro.launch import shardings as sh
    cfg = get_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = st.step_shardings(cfg, "train_4k", mesh)
    specs = st.input_specs(cfg, "train_4k")
    assert jax.tree.structure(shard["params"]) == \
        jax.tree.structure(specs["params"])
    # every sharding's spec length <= leaf rank
    for s, spec in zip(jax.tree.leaves(specs["params"]),
                       jax.tree.leaves(shard["params"])):
        assert len(spec.spec) <= len(s.shape)


def test_fsdp_rules_shard_embed_over_data():
    from repro.launch.shardings import logical_rules, resolve_spec
    cfg = get_config("llama-3.2-vision-90b")
    r0 = logical_rules(cfg, SINGLE)
    r1 = logical_rules(cfg.with_overrides(fsdp=True), SINGLE)
    assert r0["embed"] == ()
    assert r1["embed"] == ("data",)
    spec = resolve_spec(("embed", "heads"), (8192, 8192), r1, SINGLE)
    assert spec[0] == "data" and spec[1] == "model"


def test_roofline_enrich_synthetic():
    from repro.analysis.roofline import enrich
    rec = {"arch": "qwen2-0.5b", "shape": "train_4k", "mesh": "single",
           "devices": 256, "status": "ok",
           "jaxpr_flops": 256 * 197e12,          # exactly 1 s compute
           "jaxpr_bytes_fused": 256 * 819e9 / 2,  # 0.5 s memory
           "collectives": {"total_bytes": 256 * 50e9 / 4}}   # 0.25 s
    e = enrich(rec)
    assert abs(e["compute_s"] - 1.0) < 1e-9
    assert abs(e["memory_s"] - 0.5) < 1e-9
    assert abs(e["collective_s"] - 0.25) < 1e-9
    assert e["dominant"] == "compute"
    assert e["model_flops"] > 0


def test_collective_parser_loop_multiplication():
    from repro.analysis.hlo_collectives import collective_bytes
    hlo = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body.1 (t: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %t = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[4,8] get-tuple-element(%t), index=1
  %ag = f32[4,8] all-gather(%x), dimensions={0}
  ROOT %out = (s32[], f32[4,8]) tuple(%i, %ag)
}

%cond.1 (t: (s32[], f32[4,8])) -> pred[] {
  %t = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(26)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  ROOT %w = (s32[], f32[4,8]) while(%p), condition=%cond.1, body=%body.1
}
"""
    out = collective_bytes(hlo)
    # the all-gather inside the loop body must be multiplied by 26 trips
    assert out["all-gather"] == 26 * 4 * 8 * 4
    assert out["n_all-gather"] == 26

"""repro.online: regime-switching drift model, drift detection,
windowed online adaptation, and the closed-loop acceptance run
(adapted A2C beats the same controller frozen at its pre-drift
parameters under link-brownout and flash-crowd)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_paper_env, pricing
from repro.core.env import env_reset
from repro.online import (EnvPatch, OnlineConfig, PageHinkley,
                          ReplayWindow, WorldSchedule, apply_env_patch,
                          get_schedule, oracle_reward, scale_counts,
                          schedule_names)
from repro.policies import build_policy
from repro.scenarios import get_scenario, run_scenario
from repro.sim import FleetConfig, PoissonTrace, simulate


# --------------------------------------------------------------------------
# drift model
# --------------------------------------------------------------------------

def test_env_patch_set_scale_and_reset():
    cfg, _ = make_paper_env()
    p = EnvPatch(at_epoch=5, env={"latency.bw_max_bps": 6e6,
                                  "peak_rps": 40.0},
                 env_scale={"power.p_compute": 3.0})
    cfg2 = apply_env_patch(cfg, p)
    assert cfg2.latency.bw_max_bps == 6e6
    assert cfg2.peak_rps == 40.0
    assert cfg2.power.p_compute == pytest.approx(cfg.power.p_compute * 3)
    # untouched fields and the original config are unchanged
    assert cfg2.latency.server_flops == cfg.latency.server_flops
    assert cfg.latency.bw_max_bps != 6e6


def test_env_patch_unknown_field_fails_loudly():
    cfg, _ = make_paper_env()
    with pytest.raises(KeyError, match="no field"):
        apply_env_patch(cfg, EnvPatch(at_epoch=1,
                                      env={"latency.bogus": 1.0}))


def test_world_schedule_compile_cumulative_and_reset():
    cfg, _ = make_paper_env()
    sched = WorldSchedule((
        EnvPatch(at_epoch=10, name="a", env={"peak_rps": 40.0},
                 trace_scale=2.0),
        EnvPatch(at_epoch=20, name="b",
                 env_scale={"latency.server_flops": 0.5}),
        EnvPatch(at_epoch=30, name="back", reset=True),
    ))
    assert sched.n_regimes == 4
    assert sched.boundaries == (10, 20, 30)
    assert [sched.regime_at(e) for e in (0, 9, 10, 25, 30, 99)] \
        == [0, 0, 1, 2, 3, 3]
    regs = sched.compile(cfg)
    assert regs[0].env_cfg is cfg
    assert regs[1].env_cfg.peak_rps == 40.0 and regs[1].trace_scale == 2.0
    # patches compose cumulatively...
    assert regs[2].env_cfg.peak_rps == 40.0
    assert regs[2].env_cfg.latency.server_flops \
        == pytest.approx(cfg.latency.server_flops * 0.5)
    assert regs[2].trace_scale == 2.0
    # ...and reset=True returns to the base world
    assert regs[3].env_cfg is cfg and regs[3].trace_scale == 1.0


def test_world_schedule_rejects_bad_epochs():
    with pytest.raises(ValueError):
        WorldSchedule((EnvPatch(at_epoch=0),))
    with pytest.raises(ValueError):
        WorldSchedule((EnvPatch(at_epoch=10), EnvPatch(at_epoch=10)))


def test_get_schedule_miss_lists_valid_names():
    with pytest.raises(KeyError) as e:
        get_schedule("no-such-drift")
    for name in schedule_names():
        assert name in str(e.value)


def test_scale_counts_deterministic_and_mean_preserving():
    counts = np.full(2000, 10, dtype=np.int64)
    a = scale_counts(np.random.default_rng(3), counts, 2.5)
    b = scale_counts(np.random.default_rng(3), counts, 2.5)
    np.testing.assert_array_equal(a, b)
    assert a.mean() == pytest.approx(25.0, rel=0.05)
    thin = scale_counts(np.random.default_rng(3), counts, 0.3)
    assert thin.mean() == pytest.approx(3.0, rel=0.1)
    assert (thin <= counts).all()
    np.testing.assert_array_equal(
        scale_counts(np.random.default_rng(0), counts, 1.0), counts)


# --------------------------------------------------------------------------
# monitor: drift detection + per-regime oracle
# --------------------------------------------------------------------------

def test_page_hinkley_triggers_on_drop_not_noise():
    rng = np.random.default_rng(0)
    ph = PageHinkley(delta=0.01, lambda_=0.5)
    fired = [ph.update(0.6 + 0.05 * rng.normal()) for _ in range(200)]
    assert not any(fired)            # stationary noise: quiet
    fired_at = None
    for t in range(50):
        if ph.update(-0.5 + 0.05 * rng.normal()):
            fired_at = t
            break
    assert fired_at is not None and fired_at < 10   # sharp drop: fast


def test_oracle_reward_matches_jnp_greedy_oracle_per_regime():
    """The numpy per-regime oracle must price the same shifted physics
    as the jnp greedy_oracle policy given the same measured view — the
    numpy==jnp consistency guarantee extended to patched configs."""
    from repro.core.baselines import greedy_oracle
    from repro.core.reward import reward as eq8

    base, tables = make_paper_env(n_uavs=4, peak_rps=20.0)
    sched = get_schedule("link-brownout", onset=10, recover=0)
    np_t = pricing.numpy_tables(tables)
    for reg in sched.compile(base):
        cfg = reg.env_cfg
        state = env_reset(cfg, tables, jax.random.key(1))
        state = dict(state, queue=jnp.float32(7.0),
                     task=jnp.full((4,), 0.6))
        acts = greedy_oracle(cfg, tables, state)
        br = pricing.price_actions(cfg, tables,
                                   pricing.view_from_state(state), acts)
        r_jnp = float(eq8(cfg.weights, br.acc_score, br.lat_score,
                          br.energy_score, br.stab_score,
                          mask=jnp.ones(4)))
        view = pricing.StateView(
            model_id=np.asarray(state["model_id"]),
            bandwidth=np.asarray(state["bandwidth"], np.float64),
            p_tx=np.asarray(state["p_tx"], np.float64),
            queue=7.0, load=np.full(4, 0.6))
        r_np = oracle_reward(cfg, np_t, view, np.ones(4))
        assert r_np == pytest.approx(r_jnp, rel=1e-6), reg.name


# --------------------------------------------------------------------------
# replay window
# --------------------------------------------------------------------------

def test_replay_window_flushes_at_regime_boundary():
    win = ReplayWindow(capacity=4)
    for i in range(6):
        win.push({"x": np.float32(i)}, regime=0)
    assert len(win) == 4                       # maxlen honored
    np.testing.assert_array_equal(win.tail(4)["x"], [2, 3, 4, 5])
    win.push({"x": np.float32(99)}, regime=1)  # boundary: flush
    assert len(win) == 1 and win.regime == 1
    np.testing.assert_array_equal(win.tail(4)["x"], [99])
    win.push({"x": np.float32(100)}, regime=1)
    np.testing.assert_array_equal(win.tail(2)["x"], [99, 100])


# --------------------------------------------------------------------------
# fleet integration: drift + adaptation in the serving loop
# --------------------------------------------------------------------------

def _tiny_world():
    cfg, tables = make_paper_env(n_uavs=3, slot_seconds=10.0,
                                 peak_rps=20.0)
    return cfg, tables, PoissonTrace(rate_rps=6.0)


def test_drift_sim_bit_reproducible():
    cfg, tables, trace = _tiny_world()
    sched = get_schedule("link-brownout", onset=8, recover=20)
    pol = build_policy("greedy_oracle", cfg, tables)
    kw = dict(n_requests=5000, seed=3, fleet=FleetConfig(slo_s=2.0),
              schedule=sched)
    r1 = simulate(cfg, tables, pol, trace, **kw)
    r2 = simulate(cfg, tables, pol, trace, **kw)
    assert r1.summary == r2.summary
    assert r1.adaptation == r2.adaptation
    np.testing.assert_array_equal(r1.metrics.latencies_s,
                                  r2.metrics.latencies_s)


def test_drift_stream_policy_independent_paired():
    """Trace scaling and regime switches fire on the epoch clock, so two
    policies under one seed still face identical arrivals."""
    cfg, tables, trace = _tiny_world()
    sched = get_schedule("flash-crowd", onset=5, relax=0, scale=2.5)
    kw = dict(n_requests=6000, seed=9, fleet=FleetConfig(slo_s=2.0),
              schedule=sched)
    r1 = simulate(cfg, tables, build_policy("device_only", cfg, tables),
                  trace, **kw)
    r2 = simulate(cfg, tables, build_policy("full_offload", cfg, tables),
                  trace, **kw)
    assert [e["arrivals"] for e in r1.epoch_log] \
        == [e["arrivals"] for e in r2.epoch_log]
    # the crowd really scales the offered rate
    base = np.mean([e["arrivals"] for e in r1.epoch_log[:5]])
    crowd = np.mean([e["arrivals"] for e in r1.epoch_log[8:]])
    assert crowd > 1.5 * base


def test_regime_side_effects_kill_and_revive_devices():
    cfg, tables, trace = _tiny_world()
    sched = get_schedule("device-churn", leave_at=4, rejoin_at=10,
                         leave=(0, 1))
    pol = build_policy("device_only", cfg, tables)
    res = simulate(cfg, tables, pol, trace, n_requests=8000, seed=0,
                   fleet=FleetConfig(slo_s=2.0), schedule=sched)
    alive = {e["epoch"]: e["alive"] for e in res.epoch_log}
    assert alive[3] == 3 and alive[4] == 1 and alive[10] == 3
    assert res.summary["dropped"] > 0        # churned-out devices drop
    regs = {r["name"]: r for r in res.adaptation["regimes"]}
    assert set(regs) == {"base", "churn-out", "churn-in"}


def test_online_adaptation_bit_reproducible_and_hot_swaps():
    """The full drift+adapt loop — capture, jitted incremental updates,
    Policy.jitted param hot-swap, exploration — is bit-reproducible
    under a fixed seed, and actually updates the policy."""
    cfg, tables, trace = _tiny_world()
    a2c = build_policy("a2c", cfg, tables, episodes=2)
    a2c.train(seed=0)
    snap = a2c.params
    sched = get_schedule("link-brownout", onset=5, recover=0)
    oc = OnlineConfig(algo="a2c", gate="always", window=16, min_window=4,
                      update_every=1)
    kw = dict(n_requests=6000, seed=4, fleet=FleetConfig(slo_s=2.0),
              schedule=sched, online=oc)
    r1 = simulate(cfg, tables, a2c, trace, **kw)
    p1 = jax.tree.map(np.asarray, a2c.params)
    a2c.set_params(snap)
    r2 = simulate(cfg, tables, a2c, trace, **kw)
    p2 = jax.tree.map(np.asarray, a2c.params)
    a2c.set_params(snap)
    assert r1.summary == r2.summary
    assert r1.adaptation == r2.adaptation
    assert r1.adaptation["online"]["updates"] > 0
    # bit-identical adapted parameters, and different from pre-drift
    flat1, flat2 = jax.tree.leaves(p1), jax.tree.leaves(p2)
    assert all(np.array_equal(a, b) for a, b in zip(flat1, flat2))
    assert any(not np.array_equal(a, np.asarray(b))
               for a, b in zip(flat1, jax.tree.leaves(snap)))
    # the run leaves the policy serving greedily
    assert a2c.explore == 0.0


def test_online_ppo_objective_runs_and_is_deterministic():
    """The PPO variant of the incremental update (per-device GAE +
    clipped surrogate on the capture-time behavior log-probs) drives
    the same loop: scenario.build_online picks it up from the spec."""
    cfg, tables, trace = _tiny_world()
    ppo = build_policy("ppo", cfg, tables, episodes=2)
    ppo.train(seed=0)
    snap = ppo.params
    assert ppo.algo == "ppo"
    oc = OnlineConfig(algo=ppo.algo, gate="always", window=16,
                      min_window=4, update_every=1)
    kw = dict(n_requests=4000, seed=2, fleet=FleetConfig(slo_s=2.0),
              online=oc)
    r1 = simulate(cfg, tables, ppo, trace, **kw)
    ppo.set_params(snap)
    r2 = simulate(cfg, tables, ppo, trace, **kw)
    ppo.set_params(snap)
    assert r1.adaptation["online"]["updates"] > 0
    assert r1.adaptation["online"]["algo"] == "ppo"
    assert r1.summary == r2.summary


def test_online_requires_trainable_policy():
    cfg, tables, trace = _tiny_world()
    pol = build_policy("device_only", cfg, tables)
    with pytest.raises(ValueError, match="trainable"):
        simulate(cfg, tables, pol, trace, n_requests=500,
                 online=OnlineConfig())


# --------------------------------------------------------------------------
# scenario surface
# --------------------------------------------------------------------------

def test_nonstationary_presets_registered():
    from repro.scenarios import scenario_names
    for name in ("link-brownout", "flash-crowd", "battery-cliff",
                 "device-churn"):
        assert name in scenario_names()
        sc = get_scenario(name)
        assert sc.drift is not None
        assert any(n.endswith("+online") for n in sc.policies)


def test_run_scenario_rejects_bad_online_roster():
    sc = get_scenario("paper-mmpp-burst")
    with pytest.raises(KeyError, match="not trainable"):
        run_scenario(sc, ("device_only+online",))
    with pytest.raises(KeyError, match="modifier"):
        run_scenario(sc, ("a2c+turbo",))


# --------------------------------------------------------------------------
# acceptance: online-adapted A2C vs the same controller frozen at its
# pre-drift parameters (the PR's headline claim)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("preset", ["link-brownout", "flash-crowd"])
def test_online_adapted_a2c_beats_frozen_under_drift(preset):
    """On both nonstationary acceptance presets, the online-adapted A2C
    must achieve strictly higher SLO attainment and strictly higher mean
    reward than the identical controller frozen at its pre-drift
    parameters, with per-regime recovery time reported."""
    rep = run_scenario(get_scenario(preset), ("a2c+online", "a2c"))
    adapted, frozen = rep.results["a2c+online"], rep.results["a2c"]
    assert adapted.mean["slo_attainment"] > frozen.mean["slo_attainment"], \
        (preset, adapted.mean["slo_attainment"],
         frozen.mean["slo_attainment"])
    assert adapted.adaptation["mean_reward"] \
        > frozen.adaptation["mean_reward"], preset
    # recovery time to within 10% of the per-regime oracle is reported
    # for every regime, and the drift regime both degraded and recovered
    drift_reg = adapted.adaptation["regimes"][1]
    assert "recovery_epochs" in drift_reg
    assert drift_reg["recovery_epochs"] is not None
    assert drift_reg["recovery_epochs"] > 0
    assert adapted.adaptation["online"]["updates"] > 0
    # the frozen sibling shares the pre-drift training run
    assert frozen.loaded_from == "(shared: a2c)" or frozen.trained

"""Attention blocks: GQA self-attention (bias/qk_norm/RoPE/SWA), MLA
(DeepSeek-V2 compressed KV), and cross-attention (Whisper decoder / VLM).

Cache layout (self-attn): {"k","v"}: (B, C, HK, Dh) ring buffers indexed by
``pos % C`` so sliding-window decode works with C == window. Slot validity is
recovered positionally: slot s holds absolute position
``pos - ((pos - s) mod C)`` (negative => empty).

MLA cache stores the *compressed* latent: {"ckv": (B,C,R), "krope": (B,C,Dr)}.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models import attention_core as ac
from repro.models.layers import apply_rope, dense, rms_norm_headwise


# --------------------------------------------------------------------------
# plans
# --------------------------------------------------------------------------

def plan_self_attn(cfg: ModelConfig):
    d, Dh = cfg.d_model, cfg.resolved_head_dim
    H, HK = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        vd, R = cfg.v_head_dim, cfg.kv_lora_rank
        return {
            "wq": P((d, H * (nope + rope)), ("embed", "heads")),
            "w_dkv": P((d, R + rope), ("embed", None)),
            "kv_norm": P((R,), (None,), "ones"),
            "w_uk": P((R, H * nope), (None, "heads")),
            "w_uv": P((R, H * vd), (None, "heads")),
            "wo": P((H * vd, d), ("heads", "embed")),
        }
    plan = {
        "wq": P((d, H * Dh), ("embed", "heads")),
        "wk": P((d, HK * Dh), ("embed", "kv_heads")),
        "wv": P((d, HK * Dh), ("embed", "kv_heads")),
        "wo": P((H * Dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        plan["bq"] = P((H * Dh,), ("heads",), "zeros")
        plan["bk"] = P((HK * Dh,), ("kv_heads",), "zeros")
        plan["bv"] = P((HK * Dh,), ("kv_heads",), "zeros")
    if cfg.attn_bias:
        plan["bo"] = P((d,), (None,), "zeros")
    if cfg.qk_norm:
        plan["q_norm"] = P((Dh,), (None,), "ones")
        plan["k_norm"] = P((Dh,), (None,), "ones")
    return plan


def plan_cross_attn(cfg: ModelConfig):
    d, Dh = cfg.d_model, cfg.resolved_head_dim
    H, HK = cfg.n_heads, cfg.n_kv_heads
    plan = {
        "wq": P((d, H * Dh), ("embed", "heads")),
        "wk": P((d, HK * Dh), ("embed", "kv_heads")),
        "wv": P((d, HK * Dh), ("embed", "kv_heads")),
        "wo": P((H * Dh, d), ("heads", "embed")),
    }
    if cfg.attn_bias:
        plan["bq"] = P((H * Dh,), ("heads",), "zeros")
        plan["bv"] = P((HK * Dh,), ("kv_heads",), "zeros")
        plan["bo"] = P((d,), (None,), "zeros")
    return plan


# --------------------------------------------------------------------------
# ring-buffer cache helpers
# --------------------------------------------------------------------------

def slot_positions(pos, cache_len: int):
    """Absolute position held by each ring slot after ``pos+1`` tokens
    (current token at ``pos`` already written). Negative => empty slot."""
    s = jnp.arange(cache_len, dtype=jnp.int32)
    return pos - jnp.mod(pos - s, cache_len)


def ring_write_step(buf, val, pos):
    """Write one timestep val (B, ...) at slot pos % C. buf: (B, C, ...)."""
    C = buf.shape[1]
    return jax.lax.dynamic_update_slice_in_dim(
        buf, val[:, None], jnp.mod(pos, C), axis=1)


def ring_from_prefill(seq_vals, cache_len: int):
    """Build a ring buffer from prefill values (B, S, ...): keep the last
    ``cache_len`` positions, placed at their ``p % cache_len`` slots."""
    B, S = seq_vals.shape[:2]
    if S <= cache_len:
        pad = [(0, 0)] * seq_vals.ndim
        pad[1] = (0, cache_len - S)
        return jnp.pad(seq_vals, pad)
    last = seq_vals[:, S - cache_len:]            # positions S-C .. S-1
    # position p sits at slot p % C; last[0] is position S-C
    shift = (S - cache_len) % cache_len
    return jnp.roll(last, shift, axis=1)


# --------------------------------------------------------------------------
# applies
# --------------------------------------------------------------------------

def _heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


def apply_self_attn(cfg: ModelConfig, p, x, *, pos0, mode: str,
                    cache=None, window: Optional[int] = None,
                    causal: bool = True, cache_len: Optional[int] = None):
    """Returns (out, new_cache). mode in {train, prefill, decode}."""
    B, S, _ = x.shape
    if cfg.use_mla:
        return _apply_mla(cfg, p, x, pos0=pos0, mode=mode, cache=cache,
                          window=window, cache_len=cache_len)
    Dh, H, HK = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads

    q = dense(x, p["wq"])
    k = dense(x, p["wk"])
    v = dense(x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q, k, v = _heads(q, H, Dh), _heads(k, HK, Dh), _heads(v, HK, Dh)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
        k = rms_norm_headwise(k, p["k_norm"])
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "decode":
        from repro.kernels import ops as kops
        kc = ring_write_step(cache["k"], k[:, 0], pos0)
        vc = ring_write_step(cache["v"], v[:, 0], pos0)
        new_cache = {"k": kc, "v": vc}
        if kops.use_pallas():
            out = kops.decode_attention(
                q[:, 0], kc.transpose(0, 2, 1, 3),
                vc.transpose(0, 2, 1, 3), pos0, window=window)[:, None]
        else:
            kv_pos = slot_positions(pos0, kc.shape[1])
            out = ac.plain_attention(q, kc, vc, q_positions=positions,
                                     kv_positions=kv_pos, causal=True,
                                     window=window)
    else:
        out = ac.attention(q, k, v, q_positions=positions,
                           kv_positions=positions, causal=causal,
                           window=window, q_chunk=cfg.attn_q_chunk,
                           kv_chunk=cfg.attn_kv_chunk,
                           causal_skip=cfg.attn_causal_skip)
        if mode == "prefill":
            C = cache_len if cache_len is not None else S
            new_cache = {"k": ring_from_prefill(k, C),
                         "v": ring_from_prefill(v, C)}
    out = dense(out.reshape(B, S, H * Dh), p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    return out, new_cache


def _apply_mla(cfg: ModelConfig, p, x, *, pos0, mode, cache, window,
               cache_len=None):
    from repro.models.layers import apply_norm  # local import (cycle-free)

    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    vd, R = cfg.v_head_dim, cfg.kv_lora_rank
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)

    q = _heads(dense(x, p["wq"]), H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    dkv = x @ p["w_dkv"]                           # (B,S,R+rope)
    ckv = apply_norm(cfg, {"scale": p["kv_norm"]}, dkv[..., :R])
    krope = apply_rope(dkv[..., R:][:, :, None, :], positions,
                       cfg.rope_theta)             # (B,S,1,rope)

    def expand(ckv_seq, krope_seq):
        k_nope = _heads(ckv_seq @ p["w_uk"], H, nope)
        vv = _heads(ckv_seq @ p["w_uv"], H, vd)
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_seq,
                                      k_nope.shape[:-1] + (rope,))], axis=-1)
        return kk, vv

    scale = (nope + rope) ** -0.5
    new_cache = None
    if mode == "decode":
        ckv_c = ring_write_step(cache["ckv"], ckv[:, 0], pos0)
        kr_c = ring_write_step(cache["krope"], krope[:, 0, 0], pos0)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        kv_pos = slot_positions(pos0, ckv_c.shape[1])
        if cfg.mla_absorb:
            # Weight absorption: attend in the compressed latent space.
            # q_lat = q_nope @ W_uk  (per head), score against cached ckv
            # directly; out = (probs @ ckv) @ W_uv. Avoids re-expanding the
            # whole cache to per-head K/V every decode step.
            w_uk = p["w_uk"].reshape(R, H, nope)
            w_uv = p["w_uv"].reshape(R, H, vd)
            q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
            q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,1,H,R+rope)
            k_cat = jnp.concatenate(
                [ckv_c, kr_c], axis=-1)[:, :, None, :]         # (B,C,1,R+rope)
            out_lat = ac.plain_attention(
                q_cat, k_cat, ckv_c[:, :, None, :],
                q_positions=positions, kv_positions=kv_pos, causal=True,
                window=window, logit_scale=scale)              # (B,1,H,R)
            out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv)
        else:
            k, v = expand(ckv_c, kr_c[:, :, None, :])
            out = ac.plain_attention(q, k, v, q_positions=positions,
                                     kv_positions=kv_pos, causal=True,
                                     window=window, logit_scale=scale)
    else:
        k, v = expand(ckv, krope)
        out = ac.attention(q, k, v, q_positions=positions,
                           kv_positions=positions, causal=True,
                           window=window, logit_scale=scale,
                           q_chunk=cfg.attn_q_chunk,
                           kv_chunk=cfg.attn_kv_chunk)
        if mode == "prefill":
            C = cache_len if cache_len is not None else S
            new_cache = {"ckv": ring_from_prefill(ckv, C),
                         "krope": ring_from_prefill(krope[:, :, 0, :], C)}
    out = dense(out.reshape(B, S, H * vd), p["wo"])
    return out, new_cache


def apply_cross_attn(cfg: ModelConfig, p, x, *, kv_src=None, cache=None):
    """Cross-attention. kv_src: (B, S_enc, d) encoder/media states, or None
    when a precomputed {"xk","xv"} cache is supplied. Returns (out, cache)."""
    B, S, _ = x.shape
    Dh, H, HK = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense(x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = _heads(q, H, Dh)
    if cache is not None and kv_src is None:
        k, v = cache["xk"], cache["xv"]
    else:
        k = _heads(dense(kv_src, p["wk"]), HK, Dh)
        v = dense(kv_src, p["wv"])
        if "bv" in p:
            v = v + p["bv"]
        v = _heads(v, HK, Dh)
        cache = {"xk": k, "xv": v}
    Skv = k.shape[1]
    zero = jnp.zeros((S,), jnp.int32)
    kv_pos = jnp.zeros((Skv,), jnp.int32)
    out = ac.attention(q, k, v, q_positions=zero, kv_positions=kv_pos,
                       causal=False, window=None)
    out = dense(out.reshape(B, S, H * Dh), p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    return out, cache

"""Dev loop: reduced-config forward/prefill/decode for every arch."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.models import (init, forward_train, prefill, decode_step,
                          init_cache, n_params)


def batch_for(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    b["targets"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.cross_attn_every:
        b["media"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_media_tokens, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        b["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return b


def main():
    archs = sys.argv[1:] or ALL_ARCHS
    for name in archs:
        cfg = get_config(name).reduced()
        params = init(cfg, jax.random.key(0))
        b = batch_for(cfg)
        loss, metrics = jax.jit(
            lambda p, bb: forward_train(cfg, p, bb, remat=False))(params, b)
        assert jnp.isfinite(loss), (name, loss)
        logits, cache = jax.jit(lambda p, bb: prefill(cfg, p, bb))(params, b)
        assert np.isfinite(np.asarray(logits)).all(), name
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))(
                params, cache, tok, jnp.int32(16))
        assert np.isfinite(np.asarray(logits2)).all(), name
        # cache built from scratch must match prefill cache structure
        c0 = init_cache(cfg, 2, 16)
        s1 = jax.tree.structure(cache)
        s2 = jax.tree.structure(c0)
        assert s1 == s2, (name, s1, s2)
        for a, b2 in zip(jax.tree.leaves(cache), jax.tree.leaves(c0)):
            assert a.shape == b2.shape, (name, a.shape, b2.shape)
        print(f"OK {name:24s} params={n_params(cfg):,} loss={float(loss):.3f}")


if __name__ == "__main__":
    main()

"""Transformer blocks: one plan/apply pair per block kind.

Kinds:
  attn  — pre-norm self-attention + pre-norm MLP (or MoE) [dense/moe/griffin-local]
  enc   — bidirectional self-attention + MLP (whisper encoder)
  dec   — self-attention + cross-attention + MLP (whisper decoder)
  xattn — gated cross-attention + gated MLP (llama-3.2-vision image layers)
  ssm   — mamba mixer (norm + mixer only)
  rec   — RG-LRU recurrent mixer + MLP (griffin)

Each apply returns (x, new_cache, aux_loss).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models.layers import plan_norm, apply_norm, plan_mlp, apply_mlp
from repro.models.attention import (plan_self_attn, apply_self_attn,
                                    plan_cross_attn, apply_cross_attn)
from repro.models.moe import plan_moe, apply_moe
from repro.models.ssm import plan_ssm, apply_ssm
from repro.models.rglru import plan_rec, apply_rec

ZERO = jnp.float32(0.0)


def plan_block(cfg: ModelConfig, kind: str, moe: bool = False):
    bias = cfg.attn_bias  # whisper-style mlp biases ride along with attn bias
    if kind == "ssm":
        return {"norm": plan_norm(cfg), "ssm": plan_ssm(cfg)}
    if kind == "rec":
        return {"norm1": plan_norm(cfg), "rec": plan_rec(cfg),
                "norm2": plan_norm(cfg), "mlp": plan_mlp(cfg)}
    if kind in ("attn", "enc"):
        plan = {"norm1": plan_norm(cfg), "attn": plan_self_attn(cfg),
                "norm2": plan_norm(cfg)}
        if moe:
            plan["moe"] = plan_moe(cfg)
        else:
            plan["mlp"] = plan_mlp(cfg, bias=bias)
        return plan
    if kind == "dec":
        return {"norm1": plan_norm(cfg), "attn": plan_self_attn(cfg),
                "norm2": plan_norm(cfg), "xattn": plan_cross_attn(cfg),
                "norm3": plan_norm(cfg), "mlp": plan_mlp(cfg, bias=bias)}
    if kind == "xattn":
        return {"norm1": plan_norm(cfg), "xattn": plan_cross_attn(cfg),
                "gate_attn": P((1,), (None,), "zeros", dtype="float32"),
                "norm2": plan_norm(cfg), "mlp": plan_mlp(cfg),
                "gate_mlp": P((1,), (None,), "zeros", dtype="float32")}
    raise ValueError(f"unknown block kind {kind!r}")


def apply_block(cfg: ModelConfig, kind: str, p, x, *, mode: str, pos0,
                cache=None, kv_src=None, window: Optional[int] = None,
                cache_len: Optional[int] = None):
    if kind == "ssm":
        h, nc = apply_ssm(cfg, p["ssm"], apply_norm(cfg, p["norm"], x),
                          mode=mode, cache=cache)
        return x + h, nc, ZERO

    if kind == "rec":
        h, nc = apply_rec(cfg, p["rec"], apply_norm(cfg, p["norm1"], x),
                          mode=mode, cache=cache)
        x = x + h
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        return x, nc, ZERO

    if kind in ("attn", "enc"):
        causal = kind == "attn"
        h, nc = apply_self_attn(cfg, p["attn"], apply_norm(cfg, p["norm1"], x),
                                pos0=pos0, mode=mode, cache=cache,
                                window=window, causal=causal,
                                cache_len=cache_len)
        x = x + h
        y = apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            m, aux = apply_moe(cfg, p["moe"], y)
            return x + m, nc, aux
        return x + apply_mlp(cfg, p["mlp"], y), nc, ZERO

    if kind == "dec":
        self_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
        h, nc_self = apply_self_attn(cfg, p["attn"],
                                     apply_norm(cfg, p["norm1"], x),
                                     pos0=pos0, mode=mode, cache=self_cache,
                                     window=window, causal=True,
                                     cache_len=cache_len)
        x = x + h
        cross_cache = None
        if cache is not None and "xk" in cache:
            cross_cache = {"xk": cache["xk"], "xv": cache["xv"]}
        h, nc_cross = apply_cross_attn(cfg, p["xattn"],
                                       apply_norm(cfg, p["norm2"], x),
                                       kv_src=kv_src, cache=cross_cache)
        x = x + h
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm3"], x))
        nc = None
        if mode in ("prefill", "decode"):
            nc = dict(nc_self or {})
            nc.update(nc_cross or {})
        return x, nc, ZERO

    if kind == "xattn":
        cross_cache = cache if (cache is not None and "xk" in cache) else None
        h, nc = apply_cross_attn(cfg, p["xattn"],
                                 apply_norm(cfg, p["norm1"], x),
                                 kv_src=kv_src, cache=cross_cache)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h = apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h
        new_cache = nc if mode in ("prefill", "decode") else None
        return x, new_cache, ZERO

    raise ValueError(f"unknown block kind {kind!r}")

"""Parameter *plans*: declarative shapes + logical axes + initializers.

Model code declares a nested dict of ``P`` descriptors. From one plan we
derive (a) materialized parameters (``init``), (b) the logical-axes tree used
by launch/shardings.py to build NamedShardings, and (c) eval_shape structs
for allocation-free dry-runs.

Logical axis vocabulary (resolved to mesh axes in launch/shardings.py):
  "vocab", "embed", "ff", "heads", "kv_heads", "experts", "inner" (mamba),
  "lru", "layers" (stacking dim), None (replicated dim).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: Union[str, Callable] = "fan_in"   # fan_in | zeros | ones | normal | callable
    scale: Optional[float] = None           # stddev override for normal inits
    dtype: Optional[str] = None             # override model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_p(x) -> bool:
    return isinstance(x, P)


def _leaf_key(rng: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "big")
    return jax.random.fold_in(rng, h)


def _init_leaf(p: P, key: jax.Array, default_dtype) -> jax.Array:
    dtype = jnp.dtype(p.dtype) if p.dtype else default_dtype
    if callable(p.init):
        out = p.init(key, p.shape, dtype)
        assert out.shape == p.shape, (out.shape, p.shape)
        return out
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)
    if p.init == "fan_in":
        # fan-in = second-to-last dim for matrices (stacking dims excluded)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else max(p.shape[-1], 1)
        std = p.scale if p.scale is not None else fan_in ** -0.5
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def _iter_with_path(plan, prefix=""):
    if is_p(plan):
        yield prefix, plan
        return
    if isinstance(plan, dict):
        for k in sorted(plan):
            yield from _iter_with_path(plan[k], f"{prefix}/{k}")
        return
    raise TypeError(f"plan node must be dict or P, got {type(plan)} at {prefix}")


def _map_plan(fn, plan, prefix=""):
    if is_p(plan):
        return fn(prefix, plan)
    return {k: _map_plan(fn, v, f"{prefix}/{k}") for k, v in plan.items()}


def materialize(plan, rng: jax.Array, default_dtype) -> Any:
    """Plan -> pytree of initialized arrays (rng folded per leaf path)."""
    return _map_plan(
        lambda path, p: _init_leaf(p, _leaf_key(rng, path), default_dtype), plan)


def abstract(plan, default_dtype) -> Any:
    """Plan -> pytree of ShapeDtypeStruct (no allocation; for dry-runs)."""
    return _map_plan(
        lambda path, p: jax.ShapeDtypeStruct(
            p.shape, jnp.dtype(p.dtype) if p.dtype else default_dtype),
        plan)


def axes_tree(plan) -> Any:
    """Plan -> pytree of logical-axes tuples (same structure as params)."""
    return _map_plan(lambda path, p: p.axes, plan)


def stack(plan, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacking dim of size n to every leaf (for scan-over-layers)."""
    return _map_plan(
        lambda path, p: dataclasses.replace(
            p, shape=(n,) + p.shape, axes=(axis_name,) + p.axes), plan)


def count_params(plan) -> int:
    total = 0
    for _, p in _iter_with_path(plan):
        n = 1
        for d in p.shape:
            n *= d
        total += n
    return total

"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrent block: two branches from the input — (i) linear -> GeLU gate,
(ii) linear -> causal conv1d -> RG-LRU — merged multiplicatively and
projected back. RG-LRU recurrence (Griffin eqs. 1-4):

    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c * softplus(Lambda) * r_t  (a = diag, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``lax.associative_scan`` over the diagonal linear
recurrence; decode is one step. kernels/rglru_scan.py is the Pallas twin.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models.layers import causal_conv1d, causal_conv1d_step

LRU_C = 8.0


def plan_rec(cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.resolved_lru_width
    k = cfg.ssm_conv

    def lam_init(key, shape, dtype):
        # a ~ U[0.9, 0.999]: Lambda = softplus^-1(-log a / c)
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        t = -jnp.log(u) / LRU_C
        return jnp.log(jnp.expm1(jnp.maximum(t, 1e-8))).astype(dtype)

    return {
        "w_gate_branch": P((d, w), ("embed", "lru")),
        "w_rec_branch": P((d, w), ("embed", "lru")),
        "conv_w": P((k, w), (None, "lru"), "normal", scale=0.1),
        "conv_b": P((w,), ("lru",), "zeros"),
        "w_a": P((w, w), ("lru", None), scale=w ** -0.5),
        "b_a": P((w,), (None,), "zeros"),
        "w_x": P((w, w), ("lru", None), scale=w ** -0.5),
        "b_x": P((w,), (None,), "zeros"),
        "lam": P((w,), (None,), lam_init, dtype="float32"),
        "w_out": P((w, d), ("lru", "embed")),
    }


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["w_x"] + p["b_x"]).astype(jnp.float32)
    log_a = -LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gx


def rglru_scan(p, u, h0: Optional[jax.Array] = None):
    """u: (B, S, w). Diagonal linear recurrence via associative_scan."""
    B, S, w = u.shape
    a, gx = _gates(p, u)                                    # (B,S,w) each
    if h0 is not None:
        # fold initial state into the first element
        gx = gx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(u.dtype), h[:, -1]


def apply_rec(cfg: ModelConfig, p, x, *, mode: str, cache=None):
    """Griffin recurrent mixer. Returns (out, new_cache).

    cache = {"conv": (B, K-1, w), "lru": (B, w)}.
    """
    B, S, _ = x.shape
    w = cfg.resolved_lru_width
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    u = x @ p["w_rec_branch"]

    new_cache = None
    if mode == "decode":
        u_t, conv_state = causal_conv1d_step(
            u[:, 0], cache["conv"], p["conv_w"], p["conv_b"])
        a, gx = _gates(p, u_t[:, None])
        h = a[:, 0] * cache["lru"].astype(jnp.float32) + gx[:, 0]
        y = h[:, None].astype(x.dtype)
        new_cache = {"conv": conv_state, "lru": h.astype(cache["lru"].dtype)}
    else:
        from repro.kernels import ops as kops
        uc = causal_conv1d(u, p["conv_w"], p["conv_b"])
        if kops.use_pallas() and S % 128 == 0 and w % 128 == 0:
            a, gx = _gates(p, uc)
            y32, h_last = kops.rglru_scan_full(a, gx)
            y = y32.astype(x.dtype)
        else:
            y, h_last = rglru_scan(p, uc)
        if mode == "prefill":
            K = cfg.ssm_conv
            tail = u[:, -(K - 1):]
            pad = jnp.zeros((B, max(0, (K - 1) - S), w), u.dtype)
            new_cache = {"conv": jnp.concatenate([pad, tail], axis=1),
                         "lru": h_last.astype(x.dtype)}
    return (y * gate) @ p["w_out"], new_cache

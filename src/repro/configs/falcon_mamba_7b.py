"""falcon-mamba-7b [ssm] — attention-free Mamba-1. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355 (Falcon Mamba)",
    n_layers=64,
    d_model=4096,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,              # mamba block replaces the MLP
    vocab_size=65_024,
    ssm=True,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    use_rope=False,
    norm="rmsnorm",
))

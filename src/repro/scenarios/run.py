"""``run_scenario``: the single experiment entry point.

Builds the scenario's world once, resolves every requested policy
through the canonical registry (training — or loading a saved artifact —
where the spec is trainable), and simulates each policy over the *same*
seeds, so comparisons are paired by construction: two policies under one
seed face the identical request stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.policies import get_policy_spec
from repro.scenarios.base import Scenario
from repro.sim import FleetConfig, simulate

_TABLE_HEADER = (f"{'policy':14s} {'requests':>9s} {'p50_s':>8s} "
                 f"{'p95_s':>8s} {'p99_s':>8s} {'slo_att':>8s} "
                 f"{'goodput':>8s} {'E/req_J':>8s} {'drop':>6s}")


@dataclasses.dataclass
class PolicyResult:
    """One policy's paired-seed outcome inside a ComparisonReport."""
    name: str
    mean: Dict[str, float]
    per_seed: List[Dict]
    trained: bool = False
    loaded_from: Optional[str] = None
    saved_to: Optional[str] = None
    cross_check: Optional[Dict] = None

    def row(self) -> str:
        m = self.mean
        return (f"{self.name:14s} {m['count']:9.0f} {m['p50']:8.3f} "
                f"{m['p95']:8.2f} {m['p99']:8.2f} "
                f"{m['slo_attainment']:8.3f} {m['goodput']:8.1f} "
                f"{m['energy_per_request_j']:8.3f} {m['dropped']:6.0f}")


@dataclasses.dataclass
class ComparisonReport:
    """Paired-seed comparison of N policies under one scenario."""
    scenario: str
    seeds: Tuple[int, ...]
    n_requests: int
    trace: str
    results: Dict[str, PolicyResult]     # insertion-ordered

    def table(self) -> str:
        return "\n".join([_TABLE_HEADER]
                         + [r.row() for r in self.results.values()])

    def to_json(self) -> Dict:
        out = {"scenario": self.scenario, "seeds": list(self.seeds),
               "n_requests": self.n_requests, "trace": self.trace,
               "policies": {}}
        for name, r in self.results.items():
            entry = {"mean": r.mean, "per_seed": r.per_seed,
                     "trained": r.trained}
            if r.loaded_from:
                entry["loaded_from"] = r.loaded_from
            if r.saved_to:
                entry["saved_to"] = r.saved_to
            if r.cross_check:
                entry["cross_check"] = {k: v for k, v in
                                        r.cross_check.items()
                                        if k != "records"}
            out["policies"][name] = entry
        return out


def run_scenario(scenario: Scenario,
                 policies: Optional[Sequence[str]] = None, *,
                 n_requests: Optional[int] = None,
                 seeds: Optional[Sequence[int]] = None,
                 episodes: Optional[int] = None,
                 load_policies: Optional[Mapping[str, str]] = None,
                 save_policies: Optional[Mapping[str, str]] = None,
                 verbose: bool = False) -> ComparisonReport:
    """Run ``policies`` (default: the scenario's own roster) through the
    scenario; returns a paired-seed ComparisonReport.

    ``load_policies``/``save_policies`` map policy name -> artifact path:
    a mapped trainable policy loads instead of training (identical
    paired-seed metrics to the run that saved it, no retraining), and
    saves right after training. ``n_requests``/``seeds``/``episodes``
    override the scenario without mutating it.
    """
    names = tuple(policies) if policies else scenario.policies
    specs = [get_policy_spec(n) for n in names]   # fail fast on bad names
    seeds = tuple(seeds) if seeds is not None else scenario.seeds
    n_req = int(n_requests) if n_requests is not None \
        else scenario.n_requests
    eps = int(episodes) if episodes is not None else scenario.episodes

    env_cfg, tables, model_ids, backend_factory = scenario.build_env()
    trace = scenario.build_trace()
    fleet = FleetConfig(slo_s=scenario.slo_s)

    if verbose:
        print(f"scenario {scenario.name}: {scenario.devices} devices "
              f"({scenario.env} env), trace={trace.name} "
              f"(mean {trace.mean_rps:.1f} rps/device), "
              f"slo={scenario.slo_s}s, requests={n_req} x seeds "
              f"{list(seeds)}")

    results: Dict[str, PolicyResult] = {}
    header_printed = False
    for spec in specs:
        kw = {}
        if spec.trainable:
            kw = dict(episodes=eps, entropy_coef=scenario.entropy_coef,
                      batch_envs=scenario.batch_envs)
        policy = spec.build(env_cfg, tables, **kw)
        trained, loaded_from, saved_to = False, None, None
        if spec.trainable:
            loaded_from = (load_policies or {}).get(spec.name)
            if loaded_from:
                policy.load(loaded_from)
                if verbose:
                    print(f"{spec.name}: loaded artifact {loaded_from}")
            else:
                if verbose:
                    print(f"{spec.name}: training ({eps} episodes) ...",
                          flush=True)
                hist = policy.train(seed=scenario.train_seed,
                                    trace=scenario.build_train_trace())
                trained = True
                if verbose:
                    last = np.mean([h["mean_reward"] for h in hist[-15:]])
                    print(f"  trained: mean reward (last 15 episodes) = "
                          f"{last:+.3f}")
            saved_to = (save_policies or {}).get(spec.name)
            if saved_to:
                policy.save(saved_to)
                if verbose:
                    print(f"{spec.name}: saved artifact {saved_to}")

        per_seed, cross = [], None
        for seed in seeds:
            res = simulate(env_cfg, tables, policy, trace,
                           n_requests=n_req, seed=seed, fleet=fleet,
                           backend=backend_factory(), model_ids=model_ids)
            per_seed.append(res.summary)
            cross = res.cross_check or cross
        mean = {k: float(np.mean([s[k] for s in per_seed]))
                for k in per_seed[0] if k != "unit"}
        results[spec.name] = PolicyResult(
            name=spec.name, mean=mean, per_seed=per_seed, trained=trained,
            loaded_from=loaded_from, saved_to=saved_to, cross_check=cross)
        if verbose:
            if not header_printed:
                print("\n" + _TABLE_HEADER)
                header_printed = True
            print(results[spec.name].row())

    return ComparisonReport(scenario=scenario.name, seeds=seeds,
                            n_requests=n_req, trace=trace.name,
                            results=results)

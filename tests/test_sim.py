"""repro.sim: trace generators, fleet-loop reproducibility, backend
parity against the executable engine, and the controller-beats-statics
acceptance run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (A2CConfig, RewardWeights,
                        make_paper_env, make_tpu_env, make_train_episode,
                        init_agent, transformer_profile,
                        env_reset, env_step)
from repro.policies import build_policy
from repro.core.latency import LatencyParams
from repro.models import init
from repro.optim import adamw_init
from repro.sim import (AnalyticalBackend, ExecuteBackend, FleetConfig,
                       LATENCY_SCHEMA, MMPPTrace, PoissonTrace, ReplayTrace,
                       simulate, summarize_latencies)
from repro.sim.traces import TRACES, RandomRateTrace


# --------------------------------------------------------------------------
# traces
# --------------------------------------------------------------------------

def test_traces_deterministic_and_nonnegative():
    for name, cls in TRACES.items():
        trace = ReplayTrace(counts=np.arange(5)) if name == "replay" \
            else cls()
        a = trace.stream(np.random.default_rng(0), 3, 10.0)
        b = trace.stream(np.random.default_rng(0), 3, 10.0)
        rows_a = np.stack([next(a) for _ in range(20)])
        rows_b = np.stack([next(b) for _ in range(20)])
        np.testing.assert_array_equal(rows_a, rows_b)
        assert rows_a.shape == (20, 3) and (rows_a >= 0).all(), name
        assert trace.mean_rps > 0


def test_get_trace_miss_lists_valid_names():
    """Registry-convention miss: a KeyError naming every valid trace
    (same as the policy/scenario/schedule registries)."""
    from repro.sim import get_trace, trace_names

    with pytest.raises(KeyError) as e:
        get_trace("no-such-trace")
    msg = str(e.value)
    assert "valid names" in msg
    for name in trace_names():
        assert name in msg
    assert tuple(sorted(TRACES)) == trace_names()


def test_replay_trace_cycles_and_broadcasts():
    trace = ReplayTrace(counts=np.asarray([1, 2, 3]))
    gen = trace.stream(np.random.default_rng(0), 4, 30.0)
    rows = [next(gen) for _ in range(5)]
    np.testing.assert_array_equal(rows[0], np.full(4, 1))
    np.testing.assert_array_equal(rows[3], np.full(4, 1))   # cycled
    assert trace.mean_rps == pytest.approx(2.0 / 30.0)


def test_mmpp_is_actually_bursty():
    trace = MMPPTrace(rate_low_rps=1.0, rate_high_rps=50.0)
    gen = trace.stream(np.random.default_rng(1), 1, 10.0)
    counts = np.array([next(gen)[0] for _ in range(300)])
    assert counts.max() > 300      # burst epochs
    assert np.percentile(counts, 20) < 30   # calm epochs


# --------------------------------------------------------------------------
# env trace injection + deterministic rollouts
# --------------------------------------------------------------------------

def test_env_step_arrival_and_task_injection():
    cfg, tables = make_paper_env()
    state = env_reset(cfg, tables, jax.random.key(0))
    actions = jnp.zeros((cfg.n_uavs, 2), jnp.int32)
    s1, _, _ = env_step(cfg, tables, state, actions, jax.random.key(1),
                        arrivals=7.0)
    # queue = max(0 + 7 - service_per_slot, 0), no Poisson draw involved
    assert float(s1["queue"]) == pytest.approx(
        max(7.0 - cfg.queue_service_per_slot, 0.0))
    load = jnp.full((cfg.n_uavs,), 0.37)
    s2, _, _ = env_step(cfg, tables, state, actions, jax.random.key(1),
                        next_task=load)
    np.testing.assert_allclose(np.asarray(s2["task"]), 0.37, rtol=1e-6)


def test_env_rollout_bit_reproducible_with_task_seq():
    cfg, tables = make_paper_env(peak_rps=20.0)
    ac = A2CConfig(episodes=2)
    params = init_agent(cfg, tables, ac, jax.random.key(0))
    opt = adamw_init(params)
    step = make_train_episode(cfg, tables, ac)
    seq = jnp.asarray(np.random.default_rng(3).uniform(
        0, 1, (cfg.episode_len, cfg.n_uavs)), jnp.float32)
    _, _, s1 = step(params, opt, jax.random.key(7), seq)
    _, _, s2 = step(params, opt, jax.random.key(7), seq)
    assert float(s1["loss"]) == float(s2["loss"])


def test_fleet_simulate_bit_reproducible():
    cfg, tables = make_paper_env(slot_seconds=10.0)
    trace = PoissonTrace(rate_rps=8.0)
    kw = dict(n_requests=3000, seed=11, fleet=FleetConfig(slo_s=1.0))
    oracle = build_policy("greedy_oracle", cfg, tables)
    r1 = simulate(cfg, tables, oracle, trace, **kw)
    r2 = simulate(cfg, tables, oracle, trace, **kw)
    np.testing.assert_array_equal(r1.metrics.latencies_s,
                                  r2.metrics.latencies_s)
    np.testing.assert_array_equal(r1.metrics.energies_j,
                                  r2.metrics.energies_j)
    assert r1.summary == r2.summary
    np.testing.assert_array_equal(r1.selection_hist, r2.selection_hist)


def test_fleet_request_stream_is_policy_independent():
    """Same seed => identical arrivals regardless of policy, so policy
    comparisons are paired."""
    cfg, tables = make_paper_env(slot_seconds=10.0)
    trace = PoissonTrace(rate_rps=8.0)
    kw = dict(n_requests=2000, seed=5, fleet=FleetConfig(slo_s=1.0))
    r1 = simulate(cfg, tables, build_policy("device_only", cfg, tables),
                  trace, **kw)
    r2 = simulate(cfg, tables, build_policy("full_offload", cfg, tables),
                  trace, **kw)
    assert [e["arrivals"] for e in r1.epoch_log] == \
        [e["arrivals"] for e in r2.epoch_log]


# --------------------------------------------------------------------------
# metrics schema (shared with serving.ServerStats)
# --------------------------------------------------------------------------

def test_latency_schema_shared_with_scheduler_stats():
    from repro.serving.scheduler import ServerStats

    stats = ServerStats(wall_steps=10, ttft_steps=[1, 2], e2e_steps=[3, 8])
    sched = stats.latency_summary(slo_steps=5.0)
    sim = summarize_latencies([0.1, 0.2, 0.9], slo=0.5, duration=10.0)
    for k in LATENCY_SCHEMA:
        assert k in sched and k in sim, k
    assert sched["unit"] == "steps" and sim["unit"] == "s"
    assert sched["slo_attainment"] == pytest.approx(0.5)
    assert sim["slo_attainment"] == pytest.approx(2 / 3)
    # empty-safe
    empty = summarize_latencies([], slo=1.0)
    assert empty["count"] == 0 and np.isnan(empty["slo_attainment"])


def test_fleet_metrics_account_drops():
    from repro.sim.metrics import FleetMetrics

    m = FleetMetrics(slo_s=1.0)
    m.record([0.5, 0.6], [0.1, 0.1], device=0)
    m.drop(2)
    s = m.summary(duration_s=10.0)
    assert s["count"] == 2 and s["dropped"] == 2
    assert s["slo_attainment"] == pytest.approx(0.5)   # 2 met of 4 offered


# --------------------------------------------------------------------------
# backend parity: analytical tables vs executed SplitServingEngine
# --------------------------------------------------------------------------

def test_execute_backend_act_bytes_parity():
    """The analytical backend's cut-activation bytes must match the
    engine's measured act_bytes exactly for every (version, cut) that
    ships an activation (terminal cuts are env-only semantics)."""
    arch, S = "qwen2-0.5b", 8
    env_cfg, tables = make_tpu_env([arch], reduced=True, seq_len=S)
    cfg = get_config(arch).reduced()
    prof = transformer_profile(cfg, seq_len=S)
    params = init(cfg, jax.random.key(0))
    be = ExecuteBackend(env_cfg, tables, [cfg], [prof], [params],
                        seq_len=S, sample=64)
    for j in range(tables.n_versions):
        for k in range(tables.n_cuts):
            be.maybe_execute(0, j, k)
    cc = be.cross_check()
    assert cc["samples"] > 0
    assert cc["bytes_exact"], cc["records"]
    assert cc["bytes_mismatches"] == 0
    assert np.isfinite(cc["latency_ratio_median"])


def test_analytical_backend_matches_action_costs():
    """Backend pricing must reproduce env.action_costs' t_total for
    offloaded actions (same tables, same formulas)."""
    from repro.core.env import action_costs

    cfg, tables = make_paper_env()
    state = env_reset(cfg, tables, jax.random.key(0))
    be = AnalyticalBackend(cfg, tables)
    actions = np.tile(np.asarray([[1, 1]], np.int32), (cfg.n_uavs, 1))
    pr = be.price(np.asarray(state["model_id"]), actions,
                  np.asarray(state["bandwidth"]), np.asarray(state["p_tx"]))
    costs = action_costs(cfg, tables, state, jnp.asarray(actions))
    t_total = np.asarray(costs[3])
    queue_wait = float(state["queue"]) * cfg.latency.job_service_s
    np.testing.assert_allclose(pr.head_s + pr.tx_s + pr.tail_s + queue_wait,
                               t_total, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(costs[4]), pr.energy_j, rtol=1e-6)


# --------------------------------------------------------------------------
# acceptance: trained controller vs static baselines under bursty load
# --------------------------------------------------------------------------

def test_a2c_beats_static_baselines_on_mmpp():
    """The trained (stability-aware, domain-randomized) A2C controller
    must beat all-local and always-max-offload on SLO attainment under
    the bursty MMPP trace, averaged over paired request streams."""
    n, burst = 4, 30.0
    lat = LatencyParams(server_flops=0.55e12 * n, bw_max_bps=1e9)
    w = RewardWeights(w_acc=0.05, w_lat=0.1, w_energy=0.15, w_stab=0.7)
    cfg, tables = make_paper_env(n_uavs=n, latency=lat, weights=w,
                                 peak_rps=burst, slot_seconds=10.0,
                                 frames_per_slot=10.0 * burst)
    mids = np.zeros(n, np.int32)   # homogeneous vgg fleet
    a2c = build_policy("a2c", cfg, tables, episodes=500, entropy_coef=0.03)
    a2c.train(seed=0, trace=RandomRateTrace(max_rps=burst))
    trace = MMPPTrace(rate_low_rps=2.0, rate_high_rps=burst)

    def mean_slo(policy):
        vals = []
        for seed in (0, 2, 4):
            res = simulate(cfg, tables, policy, trace, n_requests=20_000,
                           seed=seed, fleet=FleetConfig(slo_s=2.0),
                           model_ids=mids)
            vals.append(res.summary["slo_attainment"])
        return float(np.mean(vals))

    a2c_slo = mean_slo(a2c)
    local = mean_slo(build_policy("device_only", cfg, tables))
    offload = mean_slo(build_policy("full_offload", cfg, tables))
    assert a2c_slo > local, (a2c_slo, local)
    assert a2c_slo > offload, (a2c_slo, offload)

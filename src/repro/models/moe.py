"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch
(GShard-style one-hot einsum), shared experts, load-balance aux loss.

The dispatch/combine einsums are the SPMD-friendly baseline: with the
expert dim sharded over "model" they lower to all-to-all style collectives
under GSPMD. The sequence is processed in chunks (``moe_chunk``) so the
dispatch tensor (B, chunk, E, C) stays bounded for 32k-token prefill.
(EXPERIMENTS.md §Perf iterates on exactly this dispatch overhead.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models.layers import plan_mlp, apply_mlp

MOE_CHUNK = 1024   # tokens per dispatch chunk (baseline; perf knob)


def plan_moe(cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    plan = {
        "router": P((d, E), ("embed", "experts"), scale=d ** -0.5),
        "w_gate": P((E, d, f), ("experts", "embed", "ff")),
        "w_up": P((E, d, f), ("experts", "embed", "ff")),
        "w_down": P((E, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared_experts:
        plan["shared"] = plan_mlp(cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return plan


def _capacity(chunk: int, cfg: ModelConfig) -> int:
    c = int(chunk * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cfg.top_k, min(chunk, -(-c // 8) * 8))   # round up to 8


def _route(cfg: ModelConfig, p, x):
    """Shared top-k routing. Returns (top_p, top_e, pos, keep, aux)."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (B,T,E)
    top_p, top_e = jax.lax.top_k(probs, K)                    # (B,T,K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    sel = jax.nn.one_hot(top_e, E, dtype=jnp.float32)         # (B,T,K,E)
    # position of each (token, slot) within its expert buffer
    flat = sel.reshape(B, T * K, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(B, T, K, E)
    pos = jnp.sum(pos_in_e * sel, axis=-1)                    # (B,T,K)
    keep = (pos < C) & (jnp.sum(sel, -1) > 0)
    # load-balance loss terms (Switch-style): mean prob * mean assignment
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    ce = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))          # (E,)
    aux = E * jnp.sum(me * ce) / K
    return top_p, top_e, pos, keep, sel, aux


def _experts(cfg, p, xe):
    h_g = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    h_u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = jax.nn.silu(h_g) * h_u
    return jnp.einsum("becf,efd->becd", h, p["w_down"])       # (B,E,C,d)


def _dispatch_chunk(cfg: ModelConfig, p, x):
    """GShard-style one-hot einsum dispatch (baseline). x: (B,T,d)."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    top_p, top_e, pos, keep, sel, aux = _route(cfg, p, x)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    # combine weights (B,T,K,E,C) folded over K into dispatch/combine tensors
    combine = jnp.einsum("btke,btkc,btk->btec", sel, pos_oh, top_p)
    dispatch = jnp.einsum("btke,btkc->btec", sel, pos_oh)

    xe = jnp.einsum("btec,btd->becd", dispatch.astype(x.dtype), x)  # (B,E,C,d)
    ye = _experts(cfg, p, xe)
    y = jnp.einsum("btec,becd->btd", combine.astype(x.dtype), ye)
    return y, aux


def _dispatch_chunk_gather(cfg: ModelConfig, p, x):
    """Scatter/gather dispatch (optimized): no O(T*E*C*d) dispatch matmuls —
    dispatch is a scatter-add into the expert buffer, combine is a gather.
    Same capacity semantics as the einsum path (EXPERIMENTS.md §Perf)."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    top_p, top_e, pos, keep, sel, aux = _route(cfg, p, x)

    slot = (top_e * C + pos.astype(jnp.int32)).astype(jnp.int32)  # (B,T,K)
    slot = jnp.where(keep, slot, E * C)                       # overflow slot
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None, None]
    b_idx = jnp.broadcast_to(b_idx, slot.shape)               # (B,T,K)
    vals = jnp.broadcast_to(x[:, :, None, :], (B, T, K, d))
    xe_flat = jnp.zeros((B, E * C + 1, d), x.dtype).at[
        b_idx, slot].add(vals)
    xe = xe_flat[:, :E * C].reshape(B, E, C, d)
    ye = _experts(cfg, p, xe)
    ye_flat = jnp.concatenate(
        [ye.reshape(B, E * C, d), jnp.zeros((B, 1, d), ye.dtype)], axis=1)
    y_tk = ye_flat[b_idx, slot]                               # (B,T,K,d)
    w = (top_p * keep).astype(x.dtype)
    y = jnp.sum(y_tk * w[..., None], axis=2)
    return y, aux


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (y, aux_loss). Chunks the sequence for dispatch."""
    B, S, d = x.shape
    dispatch_fn = (_dispatch_chunk_gather if cfg.moe_impl == "gather"
                   else _dispatch_chunk)
    chunk = min(cfg.moe_chunk or MOE_CHUNK, S)
    if S % chunk != 0:
        chunk = S  # fallback: single chunk (small/odd sequences)
    n = S // chunk
    if n == 1:
        y, aux = dispatch_fn(cfg, p, x)
    else:
        xs = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)

        def body(_, xc):
            yc, aux_c = dispatch_fn(cfg, p, xc)
            return None, (yc, aux_c)

        _, (ys, auxs) = jax.lax.scan(body, None, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
        aux = jnp.mean(auxs)
    if cfg.n_shared_experts:
        y = y + apply_mlp(cfg, p["shared"], x)
    return y, aux * cfg.router_aux_weight

"""Cut-point partitioning of the transformer models (head / tail).

The paper splits a CNN at layer l: the device runs M^l (head), ships the
activation, the server runs the tail. For the assigned transformers the cut
sits on a *superblock boundary* (scan granularity), so head/tail execution
slices the stacked layer parameters — jax.tree slicing, no recompilation of
per-layer code.

``split_forward`` == head ∘ tail and must equal the full forward (tested in
tests/test_partition.py). ``cut_points`` enumerates the legal boundaries.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def cut_for_layer(cfg: ModelConfig, layer_idx: int) -> Tuple[str, int]:
    """Map a global block index (profile layer numbering) to the nearest
    legal scan-boundary cut (stack_name, scan index).

    Profiles count decoder blocks; stacks scan superblocks that may cover
    several blocks per step (e.g. recurrentgemma's (rec,rec,attn) period),
    so the cut rounds to the closest superblock boundary."""
    remaining = int(layer_idx)
    defs = M.stack_defs(cfg)
    for si, s in enumerate(defs):
        per = sum(sub.repeat for sub in s.subs)
        total = s.length * per
        if remaining <= total or si == len(defs) - 1:
            step = int(round(remaining / per))
            if si == 0:
                step = max(step, 1)   # cut 0 == full offload (caller-level)
            return (s.name, min(step, s.length))
        remaining -= total
    raise AssertionError("unreachable")


def cut_points(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Legal cut boundaries: (stack_name, index within stack scan)."""
    out = []
    for s in M.stack_defs(cfg):
        for i in range(s.length + 1):
            if (s.name, i) == (M.stack_defs(cfg)[0].name, 0):
                continue  # cut 0 == full offload, handled by caller
            out.append((s.name, i))
    return out


def _slice_stack(p_stack, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], p_stack)


def _run_stacks(cfg, params, x, segments, kv_src):
    aux = jnp.float32(0.0)
    for (sdef, lo, hi) in segments:
        if hi <= lo:
            continue
        sliced = _slice_stack(params["stacks"][sdef.name], lo, hi)
        import dataclasses
        sub_def = dataclasses.replace(sdef, length=hi - lo)
        x, _, a = M._apply_stack(cfg, sub_def, sliced, x, mode="train",
                                 pos0=jnp.int32(0), kv_src=kv_src)
        aux = aux + a
    return x, aux


def _segments(cfg, cut: Tuple[str, int]):
    """Split stack defs into head segments and tail segments at cut."""
    heads, tails = [], []
    passed = False
    for s in M.stack_defs(cfg):
        if s.name == cut[0]:
            heads.append((s, 0, cut[1]))
            tails.append((s, cut[1], s.length))
            passed = True
        elif not passed:
            heads.append((s, 0, s.length))
        else:
            tails.append((s, 0, s.length))
    return heads, tails


def run_head(cfg: ModelConfig, params, batch, cut: Tuple[str, int]):
    """Device-side: embed + head layers. Returns the cut activation."""
    x = M._embed(cfg, params, batch["tokens"])
    kv = M._kv_src(cfg, params, batch)
    heads, _ = _segments(cfg, cut)
    x, _ = _run_stacks(cfg, params, x, heads, kv)
    return x


def run_tail(cfg: ModelConfig, params, x, batch, cut: Tuple[str, int]):
    """Server-side: tail layers + final norm + logits."""
    kv = M._kv_src(cfg, params, batch)
    _, tails = _segments(cfg, cut)
    x, _ = _run_stacks(cfg, params, x, tails, kv)
    x = M.apply_norm(cfg, params["final_norm"], x)
    return M._head(cfg, params, x)


def split_forward(cfg: ModelConfig, params, batch, cut: Tuple[str, int]):
    """Full split execution; must equal forward_logits(cfg, params, batch)."""
    act = run_head(cfg, params, batch, cut)
    return run_tail(cfg, params, act, batch, cut)


def cut_activation_bytes(cfg: ModelConfig, batch_shape) -> int:
    B, S = batch_shape
    return B * S * cfg.d_model * jnp.dtype(cfg.cdtype).itemsize

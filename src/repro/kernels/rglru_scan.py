"""RG-LRU diagonal linear recurrence as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + gx_t, with a/gx precomputed by cheap jnp projections
(the gates are matmuls XLA already fuses well); the kernel owns the
memory-bound sequential hot loop, keeping the (bw,) state in VMEM scratch
across the sequential chunk grid dim.

Layout: a, gx: (B, S, W). grid = (B, W/bw, S/bc).
Oracle: kernels/ref.py rglru_scan_ref (associative_scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, gx_ref, y_ref, hout_ref, h_scr, *, bc: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        h = (a_ref[0, t].astype(jnp.float32) * h
             + gx_ref[0, t].astype(jnp.float32))
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bc, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ic == nc - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bw", "bc", "interpret"))
def rglru_scan(a, gx, *, bw: int = 256, bc: int = 128,
               interpret: bool = True):
    """a, gx: (B, S, W) -> (h_seq (B,S,W), h_last (B,W))."""
    B, S, W = a.shape
    bw = min(bw, W)
    bc = min(bc, S)
    assert W % bw == 0 and S % bc == 0, (W, bw, S, bc)
    nw, nc = W // bw, S // bc

    kernel = functools.partial(_rglru_kernel, bc=bc, nc=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, bc, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, bc, bw), lambda b, w, c: (b, c, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, bc, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, bw), lambda b, w, c: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, gx)
    return y, h

"""Vectorized mega-fleet engines: the whole epoch as (devices,)-array ops.

``fleet.simulate`` walks a per-device Python loop over Lindley FIFOs —
correct, observable, and capped at a few hundred devices per wall-clock
second. This module turns the epoch into fused array programs over a
*padded ragged layout*: each epoch's per-device arrivals (counts c_d,
max C = counts.max()) become an (n, C) matrix of sorted arrival
offsets, padded past each device's count with a sentinel that sorts
last; the Lindley recursion C_k = max(A_k, C_{k-1}) + s then runs as a
row-wise running max (``lindley_core``), identical elementwise to the
loop's 1-D recursion, so the valid prefix of every row is *bit-equal*
to what the loop computes.

Three engines share that core (``FleetConfig.engine``):

- ``"loop"``   — the original per-device loop (kept in ``fleet.py`` as
  the parity oracle).
- ``"vectorized"`` — pure numpy, one ``lindley_core`` call per epoch.
  Bit-identical to the loop: a single ``uniform(size=counts.sum())``
  draw consumes the world-rng stream exactly like the loop's
  per-device draws (PCG64 doubles are consumed sequentially), the
  padded sort reproduces each device's sorted offsets, and the
  row-major flatten reproduces the loop's device-order metric
  recording. Same seed ⇒ identical latencies, histogram, counters.
- ``"scan"``   — a jitted ``jax.lax.scan`` over epochs
  (``simulate_scan``), float32, with an opt-in ``shard_map`` device
  axis (``FleetConfig.shard``). The trace counts come from the *same*
  trace-rng stream as the host engines (presampled in epoch order) and
  the initial world state from the same world-rng draws, but per-epoch
  world dynamics and arrival offsets draw from a jax PRNG — so
  cross-engine parity is statistical (same physics, same workload,
  different noise realization), not bitwise. Latency percentiles come
  from a fixed log-spaced histogram (512 bins over 1e-4..1e4 s: ~3.7%
  relative resolution); count/SLO/energy accumulators are exact.

f32 time safety: the scan carries ``free_rel`` — each device's FIFO
drain time *relative to the epoch start* — instead of absolute time, so
a 100k-epoch run never hits float32's ~0.06 s resolution at t ~ 1e6 s.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.core.env import EnvConfig, ProfileTables
from repro.sim.traces import Trace, presample_counts

# latency-histogram shape shared by the scan engine and its summary:
# log-spaced edges, geometric-midpoint percentile readout
_NBINS = 512
_LAT_LO, _LAT_HI = 1e-4, 1e4


def lindley_core(xp, offs, free_at, head_tx_s, tail_s, offloaded,
                 srv_wait):
    """Row-wise Lindley recursion over the padded (n, C) layout.

    ``offs``: per-device sorted arrival times (absolute or
    epoch-relative — the recursion is shift-invariant), padded past each
    device's count with values that sorted last. ``free_at``: (n,) time
    each device's FIFO drains. Returns ``(lat, done)`` both (n, C);
    entries past a device's count are garbage the caller masks out.

    Elementwise identical to the loop engine's 1-D recursion: the
    running max only ever looks left within a row, and padding sits at
    the row's end, so the valid prefix never sees it.
    """
    n, C = offs.shape
    idx = xp.arange(C)
    s = head_tx_s[:, None]
    if xp is np:
        # in-place variant: the identical operations in the identical
        # order (so results stay bit-equal to the loop oracle), buffers
        # reused — at 100k devices the (n, C) temporaries are the
        # epoch's dominant cost
        done = np.maximum(offs, free_at[:, None])
        done -= s * idx[None, :]
        np.maximum.accumulate(done, axis=1, out=done)      # start
        done += s * (idx[None, :] + 1)
        lat = done - offs
        lat += tail_s[:, None]
        np.add(lat, srv_wait, out=lat, where=offloaded[:, None])
        return lat, done
    import jax
    shifted = xp.maximum(offs, free_at[:, None]) - s * idx[None, :]
    start = jax.lax.cummax(shifted, axis=1)
    done = start + s * (idx[None, :] + 1)
    lat = done - offs + tail_s[:, None]
    lat = xp.where(offloaded[:, None], lat + srv_wait, lat)
    return lat, done


def padded_offsets(counts, u, slot_seconds):
    """Pack a flat draw of ``counts.sum()`` uniforms into the padded
    (n, C) layout and sort each row: row d's first ``counts[d]`` entries
    are device d's sorted offsets (boolean-mask assignment fills in
    row-major order, i.e. device order — the same draws the loop engine
    would have pulled per device). Padding is ``2 * slot`` — finite (no
    inf-inf NaN warnings downstream) and past every valid draw, so it
    sorts last. Returns ``(offsets, valid)``."""
    n = counts.shape[0]
    C = max(int(counts.max()), 1)
    col = np.arange(C)
    valid = col[None, :] < counts[:, None]
    pad = np.full((n, C), 2.0 * slot_seconds)
    pad[valid] = u
    pad.sort(axis=1)
    return pad, valid


def numpy_queues(counts, alive, free_at, pr, srv_wait, t_now,
                 slot_seconds, w_rng, metrics, slo_s):
    """One epoch of request flow, vectorized (engine="vectorized").

    Draws the epoch's arrival offsets in ONE ``uniform`` call — PCG64
    consumes doubles sequentially, so this is bitwise the same stream
    state as the loop's per-device draws — then runs ``lindley_core``
    over the padded layout and records metrics in the loop's
    device-major order. Mutates ``free_at`` in place; returns slo_hits.
    """
    total = int(counts.sum())
    if total == 0:
        return 0
    u = w_rng.uniform(0.0, slot_seconds, total)
    pad, valid = padded_offsets(counts, u, slot_seconds)
    pad += t_now          # == t_now + sort(u): the loop's exact values
    offs = pad
    # scalar (classic) or (n,) per-device routed-server wait (cluster):
    # the latter broadcasts as a column over the (n, C) layout
    sw = srv_wait[:, None] if np.ndim(srv_wait) else srv_wait
    lat, done = lindley_core(np, offs, free_at, pr.head_s + pr.tx_s,
                             pr.tail_s, pr.offloaded, sw)
    upd = alive & (counts > 0)
    last = np.take_along_axis(done, np.maximum(counts - 1, 0)[:, None],
                              axis=1)[:, 0]
    free_at[upd] = last[upd]
    sel = valid & alive[:, None]
    lats = lat[sel]
    if lats.size == 0:
        return 0
    n = counts.shape[0]
    energies = np.broadcast_to(pr.energy_j[:, None], lat.shape)[sel]
    devs = np.broadcast_to(np.arange(n)[:, None], lat.shape)[sel]
    metrics.record(lats, energies, device=devs)
    return int(np.sum(lats <= slo_s))


# --------------------------------------------------------------------------
# scan engine
# --------------------------------------------------------------------------

def _hist_percentile(hist, edges, count, q):
    """Latency quantile from the log-binned histogram: the geometric
    midpoint of the first bin whose cumulative count reaches q."""
    if count <= 0:
        return 0.0
    cum = np.cumsum(hist)
    i = int(np.searchsorted(cum, q * count))
    i = min(i, hist.size - 1)
    lo = edges[i - 1] if i > 0 else _LAT_LO / 2
    hi = edges[i] if i < edges.size else _LAT_HI
    return float(np.sqrt(lo * hi))


def simulate_scan(env_cfg: EnvConfig, tables: ProfileTables, policy,
                  trace: Trace, *, n_requests: int = 100_000,
                  seed: int = 0, fleet=None,
                  backend=None,
                  model_ids: Optional[Sequence[int]] = None):
    """The fully-jitted engine: one ``lax.scan`` over epochs, every
    epoch a fused (devices,)-array step (decide → price → padded
    Lindley → accumulate → world dynamics), float32 throughout.

    Workload parity with the host engines: the per-epoch arrival counts
    are presampled from the identical trace-rng stream, and the initial
    world state (bandwidth, transmit power) from the identical
    world-rng draws; only per-epoch dynamics noise and intra-slot
    arrival offsets come from a jax PRNG. Stationary worlds only — a
    drift ``schedule``, ``online`` adaptation, and the ExecuteBackend
    need host round-trips and raise upstream in ``fleet.simulate``.

    ``fleet.shard=True`` runs the scan under ``shard_map`` over every
    visible jax device (fleet axis sharded, scalar reductions psum'd).
    Per-device noise keys fold in the shard index, and the unsharded
    path folds index 0, so a 1-device mesh is bit-identical to
    ``shard=False``. Requires a per-device-decomposable policy (any
    static registry policy); trainable nets read the whole fleet's
    observation and are rejected.

    Returns a ``fleet.SimResult`` whose ``metrics`` holds only the drop
    counter — per-request arrays never leave the device; ``summary``
    is built from in-scan accumulators (percentiles from the log-binned
    histogram, everything else exact).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import energy as en
    from repro.core import pricing
    from repro.core.controller import measured_state
    from repro.sim.fleet import FleetConfig, SimResult
    from repro.sim.metrics import EpochLog, FleetMetrics

    fleet = fleet if fleet is not None else FleetConfig()
    cfg = env_cfg
    n = cfg.n_uavs
    lp, pw = cfg.latency, cfg.power
    slot = float(cfg.slot_seconds)
    if getattr(policy, "trainable", False) and fleet.shard:
        raise ValueError(
            "engine='scan' with shard=True needs a per-device-"
            "decomposable policy; trainable nets read the whole fleet's "
            "observation and cannot act on a device shard")

    if model_ids is None:
        model_ids = np.arange(n, dtype=np.int32) % tables.n_models
    model_ids = np.asarray(model_ids, dtype=np.int32)

    # identical seeding scheme to the host engines
    ss = np.random.SeedSequence(seed)
    s_trace, s_world = ss.spawn(2)
    t_rng = np.random.default_rng(s_trace)
    w_rng = np.random.default_rng(s_world)
    bw0 = w_rng.uniform(lp.bw_min_bps, lp.bw_max_bps, n)
    ptx0 = w_rng.uniform(pw.p_tx_min, pw.p_tx_max, n)

    with obs.span("fleet.scan.presample"):
        counts = presample_counts(trace, t_rng, n, slot, n_requests,
                                  fleet.max_epochs)
    T = counts.shape[0]
    if T == 0:
        raise ValueError("engine='scan' presampled zero epochs; "
                         "n_requests and max_epochs must both be > 0")
    C = max(int(counts.max()), 1)
    served = int(counts.sum())

    norm_rps = fleet.load_norm_rps or (
        cfg.peak_rps if cfg.peak_rps > 0 else max(2.0 * trace.mean_rps,
                                                  1e-9))
    M, V, K = tables.n_models, tables.n_versions, tables.n_cuts
    edges = np.geomspace(_LAT_LO, _LAT_HI, _NBINS - 1)
    edges_j = jnp.asarray(edges, jnp.float32)

    # sharding: pad the fleet axis to a multiple of the mesh size with
    # dead devices (battery 0, zero arrivals — they price, but serve,
    # drop, and drain nothing)
    ndev = len(jax.devices()) if fleet.shard else 1
    pad_n = (-n) % ndev
    npad = n + pad_n
    if pad_n:
        counts = np.pad(counts, ((0, 0), (0, pad_n)))
        model_ids = np.pad(model_ids, (0, pad_n))
        bw0 = np.pad(bw0, (0, pad_n), constant_values=lp.bw_min_bps)
        ptx0 = np.pad(ptx0, (0, pad_n), constant_values=pw.p_tx_min)
    battery0 = np.where(np.arange(npad) < n, pw.battery_j, 0.0)

    def epoch_step(mids, shard_idx, carry, inp):
        (battery, bw, p_tx, activity, side_q, backlog_s, free_rel,
         obs_rate, key, acc) = carry
        counts_t, epoch = inp
        cf = counts_t.astype(jnp.float32)
        key, k_epoch = jax.random.split(key)
        k_loc = jax.random.fold_in(k_epoch, shard_idx)
        k_pol, k_arr, k_bw, k_ptx, k_act = jax.random.split(k_loc, 5)
        k_q = jax.random.fold_in(k_epoch, _NBINS)  # replicated scalar draw

        def g(x):                      # global reduction across the mesh
            return jax.lax.psum(x, "d") if fleet.shard else x

        alive = battery > 0.0
        queue_jobs = side_q + backlog_s / lp.job_service_s
        srv_wait = queue_jobs * lp.job_service_s
        obs_queue = jnp.minimum(queue_jobs, fleet.queue_obs_clip)
        load = jnp.clip(obs_rate / norm_rps, 0.0, 1.0)

        # 1) decide from measured state (same sensors as the host loop)
        state = measured_state(cfg, tables, battery_j=battery,
                               bandwidth=bw, p_tx=p_tx,
                               queue_jobs=obs_queue, load=load,
                               model_id=mids, activity=activity, t=epoch)
        actions = policy.act(state, k_pol)

        # 2) price under the same view the AnalyticalBackend builds
        view = pricing.StateView(model_id=mids, bandwidth=bw, p_tx=p_tx,
                                 queue=0.0, load=0.0)
        pr = pricing.price_actions(cfg, tables, view, actions, xp=jnp)

        # 3) padded-ragged Lindley in epoch-relative time
        u = jax.random.uniform(k_arr, (mids.shape[0], C), maxval=slot)
        col = jnp.arange(C)
        validm = col[None, :] < counts_t[:, None]
        offs = jnp.sort(jnp.where(validm, u, 2.0 * slot), axis=1)
        lat, done = lindley_core(jnp, offs, free_rel,
                                 pr.head_s + pr.tx_s, pr.tail_s,
                                 pr.offloaded, srv_wait)
        upd = alive & (counts_t > 0)
        last = jnp.take_along_axis(
            done, jnp.maximum(counts_t - 1, 0)[:, None], axis=1)[:, 0]
        free_rel = jnp.where(upd, last, free_rel)
        # shift the time origin to the next epoch; anything already
        # drained clamps to "free now" (f32-safe over any horizon)
        free_rel = jnp.maximum(free_rel - slot, 0.0)

        sel = validm & alive[:, None]
        slo_hits = g(jnp.sum(sel & (lat <= fleet.slo_s)))
        dropped_t = g(jnp.sum(jnp.where(alive, 0, counts_t)))
        count_t = g(jnp.sum(jnp.where(alive, counts_t, 0)))
        lat_sum = g(jnp.sum(jnp.where(sel, lat, 0.0)))
        lat_max = g(jnp.max(jnp.where(sel, lat, -jnp.inf)))
        e_sum = g(jnp.sum(jnp.where(alive, cf * pr.energy_j, 0.0)))
        bins = jnp.clip(jnp.searchsorted(edges_j, lat), 0, _NBINS - 1)
        hist_lat_t = g(jnp.zeros(_NBINS, jnp.int32)
                       .at[bins.ravel()].add(sel.ravel()
                                             .astype(jnp.int32)))
        flat = (mids * V + actions[:, 0]) * K + actions[:, 1]
        hist_sel_t = g(jnp.zeros(M * V * K, jnp.int32)
                       .at[flat].add(jnp.where(alive, counts_t, 0)
                                     .astype(jnp.int32)))
        tail_in = g(jnp.sum(jnp.where(upd & pr.offloaded,
                                      cf * pr.tail_s, 0.0)))

        # 4) world dynamics (mirrors the host loop, jax noise)
        kin = en.kinetic_power(pw, activity[:, 0], activity[:, 1],
                               activity[:, 2])
        drain = jnp.where(alive, kin * slot + cf * pr.energy_j, 0.0)
        battery = jnp.maximum(battery - drain, 0.0)
        nloc = bw.shape[0]
        bw = jnp.clip(bw * jnp.exp(jax.random.normal(k_bw, (nloc,))
                                   * 0.15), lp.bw_min_bps, lp.bw_max_bps)
        p_tx = jnp.clip(p_tx + jax.random.normal(k_ptx, (nloc,)) * 0.05,
                        pw.p_tx_min, pw.p_tx_max)
        activity = jnp.clip(activity
                            + jax.random.normal(k_act, (nloc, 3))
                            * cfg.activity_jitter, 0.0, 1.0)
        activity = activity / jnp.maximum(
            activity.sum(-1, keepdims=True), 1.0)
        side_q = jnp.maximum(
            side_q + jax.random.poisson(k_q, cfg.queue_arrival_rate)
            .astype(jnp.float32) - cfg.queue_service_per_slot, 0.0)
        backlog_s = jnp.maximum(backlog_s + tail_in - slot, 0.0)
        obs_rate = (1.0 - fleet.ewma) * obs_rate + fleet.ewma * cf / slot

        acc = {"count": acc["count"] + count_t - dropped_t,
               "dropped": acc["dropped"] + dropped_t,
               "slo_hits": acc["slo_hits"] + slo_hits,
               "lat_sum": acc["lat_sum"] + lat_sum,
               "lat_max": jnp.maximum(acc["lat_max"], lat_max),
               "e_sum": acc["e_sum"] + e_sum,
               "hist_lat": acc["hist_lat"] + hist_lat_t,
               "hist_sel": acc["hist_sel"] + hist_sel_t}
        carry = (battery, bw, p_tx, activity, side_q, backlog_s,
                 free_rel, obs_rate, key, acc)
        # per-epoch stacked outputs: O(1) scalars only (the scan-carry
        # rule — DESIGN §13). Always emitted, timeline on or off, so the
        # compiled graph is identical either way; the timeline is pure
        # host-side extraction below.
        ys = (queue_jobs, backlog_s, dropped_t, slo_hits,
              g(jnp.sum(alive.astype(jnp.int32))),
              count_t, lat_sum, lat_max, e_sum)
        return carry, ys

    def run(counts_all, epochs_all, mids, bat0, bwi, pti, shard_idx):
        nloc = mids.shape[0]
        acc0 = {"count": jnp.int32(0), "dropped": jnp.int32(0),
                "slo_hits": jnp.int32(0), "lat_sum": jnp.float32(0.0),
                "lat_max": jnp.float32(-jnp.inf),
                "e_sum": jnp.float32(0.0),
                "hist_lat": jnp.zeros(_NBINS, jnp.int32),
                "hist_sel": jnp.zeros(M * V * K, jnp.int32)}
        carry0 = (bat0.astype(jnp.float32), bwi.astype(jnp.float32),
                  pti.astype(jnp.float32),
                  jnp.tile(jnp.asarray(cfg.activity, jnp.float32)[None],
                           (nloc, 1)),
                  jnp.float32(0.0), jnp.float32(0.0),
                  jnp.zeros(nloc, jnp.float32),
                  jnp.full(nloc, trace.mean_rps, jnp.float32),
                  jax.random.key(seed), acc0)
        carry, ys = jax.lax.scan(
            lambda c, x: epoch_step(mids, shard_idx, c, x),
            carry0, (counts_all, epochs_all))
        return carry[-1], ys

    xs = (jnp.asarray(counts.T, jnp.int32).T,  # (T, npad) int32
          jnp.arange(T, dtype=jnp.int32))
    mids_j = jnp.asarray(model_ids)
    args = (xs[0], xs[1], mids_j, jnp.asarray(battery0, jnp.float32),
            jnp.asarray(bw0, jnp.float32), jnp.asarray(ptx0, jnp.float32))

    with obs.span("fleet.scan", epochs=T, devices=n, shard=fleet.shard):
        if fleet.shard:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh
            from jax.sharding import PartitionSpec as P
            mesh = Mesh(np.asarray(jax.devices()), ("d",))
            sharded = shard_map(
                lambda c, e, m, b, w, p: run(
                    c, e, m, b, w, p, jax.lax.axis_index("d")),
                mesh=mesh,
                in_specs=(P(None, "d"), P(), P("d"), P("d"), P("d"),
                          P("d")),
                out_specs=(P(), (P(),) * 9),
                # accumulators are psum'd every epoch (replicated by
                # construction); skip the conservative rep checker
                check_rep=False)
            acc, ys = jax.jit(sharded)(*args)
        else:
            acc, ys = jax.jit(run, static_argnums=(6,))(*args, 0)
        acc = jax.tree.map(np.asarray, acc)
        ys = jax.tree.map(np.asarray, ys)

    count = int(acc["count"])
    dropped = int(acc["dropped"])
    slo_hits = int(acc["slo_hits"])
    duration = T * slot
    hist = acc["hist_lat"]
    total = count + dropped
    summary = {
        "count": float(count), "unit": "s",
        "mean": float(acc["lat_sum"]) / count if count else 0.0,
        "p50": _hist_percentile(hist, edges, count, 0.50),
        "p95": _hist_percentile(hist, edges, count, 0.95),
        "p99": _hist_percentile(hist, edges, count, 0.99),
        "max": float(acc["lat_max"]) if count else 0.0,
        "slo": float(fleet.slo_s),
        "slo_attainment": slo_hits / total if total else float("nan"),
        "goodput": slo_hits / duration if duration else 0.0,
        "dropped": float(dropped),
        "energy_j": float(acc["e_sum"]),
        "energy_per_request_j": float(acc["e_sum"]) / count if count
        else 0.0,
        "duration_s": duration,
        "epochs": T, "requests": served,
    }

    metrics = FleetMetrics(slo_s=fleet.slo_s)
    metrics.dropped = dropped
    epoch_log = EpochLog(stride=fleet.log_stride, cap=fleet.log_cap)
    (q_jobs, backlog, drop_t, slo_t, alive_t,
     srv_t, lsum_t, lmax_t, e_t) = ys
    if fleet.record_epochs:
        epoch_log.extend_columns(
            epoch=np.arange(T), arrivals=counts[:, :n].sum(axis=1),
            queue_jobs=q_jobs, backlog_s=backlog, dropped=drop_t,
            slo_hits=slo_t, alive=alive_t, regime=np.zeros(T, np.int64))
    tl = None
    if fleet.timeline:
        from repro.obs.slo import SLOConfig
        from repro.obs.timeline import Timeline
        tl = Timeline(slo_s=fleet.slo_s, slot_seconds=slot,
                      stride=fleet.log_stride, engine="scan")
        with obs.span("fleet.timeline"):
            tl.extend_epochs(
                epoch=np.arange(T), arrivals=counts[:, :n].sum(axis=1),
                served=srv_t, dropped=drop_t, slo_hits=slo_t,
                alive=alive_t, queue_jobs=q_jobs, backlog_s=backlog,
                lat_sum=lsum_t, lat_max=lmax_t, energy_j=e_t)
            tl.finalize(SLOConfig(target=fleet.slo_target))
    sel_hist = acc["hist_sel"].astype(np.int64).reshape(M, V, K)
    return SimResult(summary=summary, metrics=metrics,
                     selection_hist=sel_hist, epochs=T, served=served,
                     duration_s=duration, cross_check=None,
                     epoch_log=epoch_log, adaptation=None, timeline=tl)

"""Integration: the multi-pod dry-run lowers+compiles in a fresh process
(512 virtual devices are process-global, so this must be a subprocess)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape,mesh", [
    ("qwen2-0.5b", "decode_32k", "single"),
    ("qwen2-0.5b", "long_500k", "multi"),
    ("falcon-mamba-7b", "decode_32k", "multi"),
])
def test_dryrun_combo(tmp_path, arch, shape, mesh):
    out = tmp_path / "dryrun.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["devices"] == (512 if mesh == "multi" else 256)
    assert rec["jaxpr_flops"] > 0
    assert "collectives" in rec

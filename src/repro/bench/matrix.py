"""repro.bench.matrix — declarative benchmark case matrix.

A benchmark is a plain function that emits rows through
``repro.bench.runner.emit``; the matrix is the registry that turns
those functions into an expanded list of ``Case``s — optionally
cartesian-expanded over parameter axes (fleet sizes, scenarios,
backends) the way antmicro/benchalot expands config matrices — that
the runner executes and the gate keys history on.

    m = Matrix()
    m.add(quant_matmul, tags=("system", "smoke"))
    m.add(fleet_sim, tags=("system", "smoke"),
          axes={"n_uavs": (8, 64, 256)})
    m.select(only=["fleet_sim"])          # all three expanded cases
    m.select(only=["fleet_sim[n_uavs=64]"])  # exactly one

Axis values may be a callable (resolved lazily at expansion) so a
registry-backed axis — scenario names, policy names — doesn't force
the registry import at matrix-definition time.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from itertools import product
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Case:
    """One executable benchmark case: ``fn(**params)``."""
    name: str                       # expanded, unique: fleet_sim[n_uavs=64]
    group: str                      # the registered function's name
    fn: Callable
    params: Dict = field(default_factory=dict)
    tags: Tuple[str, ...] = ()

    def run(self, **overrides):
        return self.fn(**{**self.params, **overrides})

    def with_params(self, **overrides) -> "Case":
        return replace(self, params={**self.params, **overrides})


def _axis_values(v):
    return tuple(v() if callable(v) else v)


class Matrix:
    """Ordered registry of benchmark functions with optional axes."""

    def __init__(self):
        self._specs: List[Dict] = []

    def add(self, fn: Callable, *, name: Optional[str] = None,
            tags: Sequence[str] = (),
            axes: Optional[Dict[str, object]] = None, **fixed) -> None:
        """Register ``fn``. ``axes`` maps kwarg name -> values (or a
        zero-arg callable yielding them); the case list is the
        cartesian product. ``fixed`` kwargs apply to every case."""
        self._specs.append({"fn": fn, "name": name or fn.__name__,
                            "tags": tuple(tags), "axes": dict(axes or {}),
                            "fixed": dict(fixed)})

    def groups(self) -> List[str]:
        return [s["name"] for s in self._specs]

    def cases(self) -> List[Case]:
        out: List[Case] = []
        for s in self._specs:
            if not s["axes"]:
                out.append(Case(name=s["name"], group=s["name"],
                                fn=s["fn"], params=dict(s["fixed"]),
                                tags=s["tags"]))
                continue
            keys = list(s["axes"])
            for combo in product(*(_axis_values(s["axes"][k])
                                   for k in keys)):
                params = {**s["fixed"], **dict(zip(keys, combo))}
                label = ",".join(f"{k}={v}" for k, v in zip(keys, combo))
                out.append(Case(name=f"{s['name']}[{label}]",
                                group=s["name"], fn=s["fn"],
                                params=params, tags=s["tags"]))
        return out

    def select(self, only: Optional[Iterable[str]] = None,
               tags: Optional[Iterable[str]] = None) -> List[Case]:
        """Filter cases by group/case name and/or tags. Unknown names
        raise a KeyError listing the valid ones (registry convention)."""
        cases = self.cases()
        if tags:
            want = set(tags)
            cases = [c for c in cases if want & set(c.tags)]
        if only is None:
            return cases
        only = list(only)
        known = {c.name for c in cases} | {c.group for c in cases}
        unknown = sorted(set(only) - known)
        if unknown:
            raise KeyError(
                f"unknown benchmark(s) {unknown}; valid groups: "
                f"{sorted({c.group for c in cases})}, valid cases: "
                f"{sorted(c.name for c in cases)}")
        sel = set(only)
        return [c for c in cases if c.name in sel or c.group in sel]

from repro.checkpointing.npz import (latest_step, load_tree,
                                     restore_checkpoint, save_checkpoint,
                                     save_tree)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_tree", "load_tree"]

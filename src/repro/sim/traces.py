"""Pluggable workload generators: per-device request arrival streams.

A trace yields, per decision epoch, the number of requests arriving at
each device during that epoch (``stream``). The fleet loop spreads each
epoch's arrivals uniformly over the slot (exact for a Poisson process
whose rate is constant within the slot, which every generator here is
conditionally on its modulating state).

All randomness flows through the ``numpy.random.Generator`` the caller
passes, so a fixed seed makes the whole simulation bit-reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np


class Trace:
    """Base class: a per-device arrival-count process."""

    name = "trace"

    @property
    def mean_rps(self) -> float:
        """Long-run mean arrival rate per device (requests/second);
        used to size epochs and normalize the measured-load feature."""
        raise NotImplementedError

    def stream(self, rng: np.random.Generator, n_devices: int,
               slot_seconds: float) -> Iterator[np.ndarray]:
        """Infinite iterator of per-epoch arrival counts, shape
        (n_devices,), dtype int64."""
        raise NotImplementedError


def presample_counts(trace: Trace, rng: np.random.Generator,
                     n_devices: int, slot_seconds: float,
                     n_requests: int, max_epochs: int) -> np.ndarray:
    """Materialize the epoch stream up front: counts for every epoch
    until cumulative arrivals reach ``n_requests`` (or ``max_epochs``),
    as a (T, n_devices) int64 array.

    Consumes ``rng`` exactly as ``fleet.simulate``'s incremental
    ``next(stream)`` calls would, and applies the identical termination
    rule (stop *after* the epoch that crosses ``n_requests``) — so the
    scan engine sees the same workload, epoch for epoch, as the host
    engines under the same trace seed.
    """
    stream = trace.stream(rng, n_devices, slot_seconds)
    out = []
    served = 0
    while served < n_requests and len(out) < max_epochs:
        counts = np.asarray(next(stream), dtype=np.int64)
        out.append(counts)
        served += int(counts.sum())
    return np.stack(out) if out else np.zeros((0, n_devices), np.int64)


@dataclasses.dataclass
class PoissonTrace(Trace):
    """Homogeneous Poisson arrivals at ``rate_rps`` per device."""
    rate_rps: float = 10.0
    name = "poisson"

    @property
    def mean_rps(self) -> float:
        return self.rate_rps

    def stream(self, rng, n_devices, slot_seconds):
        lam = self.rate_rps * slot_seconds
        while True:
            yield rng.poisson(lam, n_devices)


@dataclasses.dataclass
class MMPPTrace(Trace):
    """2-state Markov-modulated Poisson process (bursty traffic).

    A fleet-wide modulating chain switches between a calm rate and a
    burst rate with per-epoch transition probabilities — the shared
    burst state is what stresses a controller fleet-wide (AutoScale's
    observation: stochastic workload variance is where energy-aware
    controllers win or lose).
    """
    rate_low_rps: float = 2.0
    rate_high_rps: float = 25.0
    p_up: float = 0.15      # calm -> burst per epoch
    p_down: float = 0.35    # burst -> calm per epoch
    name = "mmpp"

    @property
    def mean_rps(self) -> float:
        # stationary distribution of the 2-state chain
        pi_high = self.p_up / max(self.p_up + self.p_down, 1e-12)
        return (1 - pi_high) * self.rate_low_rps + pi_high * self.rate_high_rps

    def stream(self, rng, n_devices, slot_seconds):
        high = False
        while True:
            rate = self.rate_high_rps if high else self.rate_low_rps
            yield rng.poisson(rate * slot_seconds, n_devices)
            p = self.p_down if high else self.p_up
            if rng.random() < p:
                high = not high


@dataclasses.dataclass
class DiurnalTrace(Trace):
    """Sinusoidal day/night rate: base + amplitude * (1 + sin) / 2.

    ``period_epochs`` epochs per simulated day; ``phase`` in [0, 1)
    shifts the peak. Arrivals are Poisson at the instantaneous rate.
    """
    base_rps: float = 4.0
    peak_rps: float = 20.0
    period_epochs: float = 48.0
    phase: float = 0.0
    name = "diurnal"

    @property
    def mean_rps(self) -> float:
        return self.base_rps + (self.peak_rps - self.base_rps) / 2.0

    def rate_rps(self, epoch: int) -> float:
        x = 2.0 * np.pi * (epoch / self.period_epochs + self.phase)
        return self.base_rps + (self.peak_rps - self.base_rps) \
            * (1.0 + np.sin(x)) / 2.0

    def stream(self, rng, n_devices, slot_seconds):
        t = 0
        while True:
            yield rng.poisson(self.rate_rps(t) * slot_seconds, n_devices)
            t += 1


@dataclasses.dataclass
class ReplayTrace(Trace):
    """Replay measured per-epoch arrival counts from an array.

    ``counts`` has shape (epochs,) — broadcast across devices — or
    (epochs, n_devices). The trace cycles when the simulation outruns
    the recording. ``slot_seconds_recorded`` lets ``mean_rps`` report
    the recording's own timescale.
    """
    counts: np.ndarray = None
    slot_seconds_recorded: float = 30.0
    name = "replay"

    def __post_init__(self):
        self.counts = np.atleast_1d(np.asarray(self.counts))
        if self.counts.ndim > 2 or self.counts.size == 0:
            raise ValueError("ReplayTrace needs a non-empty (epochs,) or "
                             "(epochs, n_devices) array")

    @property
    def mean_rps(self) -> float:
        return float(np.mean(self.counts)) / self.slot_seconds_recorded

    def stream(self, rng, n_devices, slot_seconds):
        t = 0
        while True:
            row = self.counts[t % self.counts.shape[0]]
            yield np.broadcast_to(np.atleast_1d(row), (n_devices,)).astype(
                np.int64).copy()
            t += 1


@dataclasses.dataclass
class RandomRateTrace(Trace):
    """Doubly-stochastic Poisson: each epoch and device draws an iid
    rate ~ U(0, max_rps), then Poisson arrivals at that rate.

    Not a realistic workload — it is the *domain randomization* trace:
    training the controller on it covers the whole (load, state) surface
    uniformly, so per-device load sensitivity is learned everywhere
    instead of only at a bursty trace's two modes.
    """
    max_rps: float = 30.0
    name = "uniform"

    @property
    def mean_rps(self) -> float:
        return self.max_rps / 2.0

    def stream(self, rng, n_devices, slot_seconds):
        while True:
            rates = rng.uniform(0.0, self.max_rps, n_devices)
            yield rng.poisson(rates * slot_seconds)


TRACES = {
    "poisson": PoissonTrace,
    "mmpp": MMPPTrace,
    "diurnal": DiurnalTrace,
    "replay": ReplayTrace,
    "uniform": RandomRateTrace,
}


def trace_names() -> tuple:
    return tuple(sorted(TRACES))


def get_trace(name: str, **kw) -> Trace:
    """Canonical-name lookup; a miss names every valid trace (the same
    convention as the policy/scenario/schedule registries)."""
    if name not in TRACES:
        raise KeyError(f"unknown trace {name!r}; valid names: "
                       f"{', '.join(trace_names())}")
    return TRACES[name](**kw)

"""Continuous-batching scheduler: slot-based request admission over a fixed
decode batch, the serving pattern real inference frameworks (vLLM/JetStream)
use — requests arrive asynchronously, prefill on admission, decode in
lockstep, retire on EOS/max-tokens, refill the freed slot.

Single-program JAX realization:
  - a fixed pool of B slots, each with its own ring KV cache region
    (slot dim = batch dim of one shared cache tree),
  - per-slot position counters (positions differ per slot — the models'
    positional masking is per-slot via the `pos` argument vectorization),
  - prefill runs per admitted request (B=1) and its cache is scattered
    into the pool slot.

Because model decode_step takes one shared scalar `pos`, slots decode in
*cohorts* that share a position (cohort = requests admitted together);
this keeps the jitted step identical to the production serve_step while
still giving continuous admission. Requests retire *individually*: a
finished request is compacted out of its cohort (batch-axis gather on
the cache tree), the freed slot re-admits queued work on the next loop
turn, and a cohort whose ring cache is exhausted retires truncated
instead of silently wrapping `pos`.

Per-request accounting matches the repro.sim.metrics schema: submit ->
first-token (TTFT) and submit -> done wall steps, summarized by
``ServerStats.latency_summary``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (S,)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    truncated: bool = False       # ring cache ran out before EOS/max
    submit_step: int = -1         # wall step at submit()
    first_token_step: int = -1    # wall step of prefill (first token)
    done_step: int = -1           # wall step at retirement

    @property
    def done(self) -> bool:
        if self.truncated:
            return True
        if self.eos_id is not None and self.out and self.out[-1] == self.eos_id:
            return True
        return len(self.out) >= self.max_new_tokens


@dataclasses.dataclass
class ServerStats:
    admitted: int = 0
    completed: int = 0
    decode_steps: int = 0
    prefills: int = 0
    truncated: int = 0
    wall_steps: int = 0           # scheduler loop turns
    slot_reclaims: int = 0        # slots freed by individual retirement
    ttft_steps: List[int] = dataclasses.field(default_factory=list)
    e2e_steps: List[int] = dataclasses.field(default_factory=list)

    def latency_summary(self, slo_steps: Optional[float] = None) -> Dict:
        """Same schema as the fleet simulator's latency reports
        (repro.sim.metrics.summarize_latencies), in wall-step units."""
        from repro.sim.metrics import summarize_latencies

        out = summarize_latencies(self.e2e_steps, slo=slo_steps,
                                  duration=float(self.wall_steps) or None,
                                  unit="steps")
        ttft = summarize_latencies(self.ttft_steps, unit="steps")
        out["ttft_p50"] = ttft["p50"]
        out["ttft_p95"] = ttft["p95"]
        out["ttft_mean"] = ttft["mean"]
        return out


class ContinuousBatchingServer:
    """Cohort-based continuous batching over the functional model API."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.queue: Deque[Request] = deque()
        self.stats = ServerStats()
        self._cache_axes = M.cache_axes(cfg)

        def _prefill(params, batch):
            return M.prefill(cfg, params, batch, total_len=cache_len)

        def _decode(params, cache, tok, pos):
            return M.decode_step(cfg, params, cache, tok, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        # cohorts: list of dicts {requests, cache, tok, pos}
        self._cohorts: List[Dict] = []

    # -- client API ---------------------------------------------------------

    def submit(self, req: Request):
        if len(req.tokens) + 1 > self.cache_len:
            raise ValueError(
                f"prompt of {len(req.tokens)} tokens cannot fit a "
                f"cache_len={self.cache_len} ring with one generated token")
        req.submit_step = self.stats.wall_steps
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive admission + decode until queue and cohorts drain."""
        finished: List[Request] = []
        steps = 0
        while (self.queue or self._cohorts) and steps < max_steps:
            self.stats.wall_steps += 1
            self._admit()
            finished.extend(self._step_all())
            steps += 1
        return finished

    # -- internals ----------------------------------------------------------

    def _slots_in_use(self) -> int:
        return sum(len(c["requests"]) for c in self._cohorts)

    def _extra_batch(self, n: int) -> Dict:
        b = {}
        if self.cfg.cross_attn_every:
            b["media"] = jnp.zeros((n, self.cfg.n_media_tokens,
                                    self.cfg.d_model), self.cfg.cdtype)
        if self.cfg.enc_dec:
            b["enc_frames"] = jnp.zeros((n, self.cfg.encoder_seq,
                                         self.cfg.d_model), self.cfg.cdtype)
        return b

    def _admit(self):
        free = self.max_batch - self._slots_in_use()
        admit: List[Request] = []
        # cohort = requests admitted together (left-pad to max prompt len)
        while self.queue and len(admit) < free:
            admit.append(self.queue.popleft())
        if not admit:
            return
        S = max(len(r.tokens) for r in admit)
        toks = np.zeros((len(admit), S), np.int32)
        for i, r in enumerate(admit):
            toks[i, S - len(r.tokens):] = r.tokens   # left-pad
        batch = {"tokens": jnp.asarray(toks), **self._extra_batch(len(admit))}
        logits, cache = self._prefill(self.params, batch)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i, r in enumerate(admit):
            r.out.append(int(first[i]))
            r.first_token_step = self.stats.wall_steps
        self._cohorts.append({"requests": admit, "cache": cache,
                              "tok": first, "pos": S})
        self.stats.admitted += len(admit)
        self.stats.prefills += 1

    def _take_slots(self, cache, idx):
        """Gather cohort cache slots along each leaf's batch axis (leaves
        carry leading layer-stacking dims, so the axis is per-leaf)."""
        sel = jnp.asarray(idx, jnp.int32)
        return jax.tree.map(
            lambda a, ax: jnp.take(a, sel, axis=ax.index("batch")),
            cache, self._cache_axes)

    def _retire(self, c, finished: List[Request]) -> bool:
        """Retire finished requests individually, compacting the cohort
        so their slots free up for re-admission. Returns True while the
        cohort still has live requests."""
        live = [i for i, r in enumerate(c["requests"]) if not r.done]
        if len(live) == len(c["requests"]):
            return True
        for r in c["requests"]:
            if r.done:
                r.done_step = self.stats.wall_steps
                self.stats.completed += 1
                self.stats.truncated += int(r.truncated)
                self.stats.ttft_steps.append(
                    r.first_token_step - r.submit_step)
                self.stats.e2e_steps.append(r.done_step - r.submit_step)
                finished.append(r)
        if not live:
            return False
        self.stats.slot_reclaims += len(c["requests"]) - len(live)
        c["requests"] = [c["requests"][i] for i in live]
        c["cache"] = self._take_slots(c["cache"], live)
        c["tok"] = c["tok"][jnp.asarray(live, jnp.int32)]
        return True

    def _step_all(self) -> List[Request]:
        finished: List[Request] = []
        keep = []
        for c in self._cohorts:
            if not self._retire(c, finished):
                continue
            if c["pos"] >= self.cache_len:
                # ring cache exhausted: retire truncated rather than let
                # decode positions wrap over live history
                for r in c["requests"]:
                    r.truncated = True
                self._retire(c, finished)
                continue
            logits, cache = self._decode(self.params, c["cache"], c["tok"],
                                         jnp.int32(c["pos"]))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for i, r in enumerate(c["requests"]):
                r.out.append(int(nxt[i]))
            c.update(cache=cache, tok=nxt, pos=c["pos"] + 1)
            self.stats.decode_steps += 1
            keep.append(c)
        self._cohorts = keep
        return finished

"""Shared actor-critic networks + batched rollout machinery.

Both on-policy agents (``a2c``, the paper's algorithm, and ``ppo``, the
beyond-paper ablation) train the same networks over the same rollouts;
this module holds that shared layer once:

- the paper's networks (critic 512/256, actor with a shared 128-wide
  per-UAV head feeding the (version, cut) logit pairs) and their
  sampling / log-prob / entropy math;
- ``make_rollout``: one lax.scan episode of the env, optionally
  recording the behavior policy's logp/value (PPO's surrogate needs
  them, A2C recomputes);
- ``run_batched_episodes``: vmap over ``batch_envs`` parallel env
  instances *inside* one jit — per-env reset keys, per-env
  domain-randomized task traces, one mean-gradient update downstream.
  Training E envs per update costs far less than E sequential episodes
  (the per-step nets are tiny; batching amortizes scan and dispatch),
  and the gradient sees E independent worlds per step;
- ``discounted_returns`` / ``gae``: the two return estimators.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.env import env_reset, env_step, observe
from repro.models import params as pp
from repro.models.params import P


# --------------------------------------------------------------------------
# networks (paper Sec. II-C)
# --------------------------------------------------------------------------

def plan_agent(cfg, tables, ac):
    """Parameter plan; ``ac`` supplies hidden1/hidden2/uav_head widths."""
    n = cfg.n_uavs
    obs = n * cfg.obs_dim_per_uav
    V, K = tables.n_versions, tables.n_cuts
    h1, h2, hu = ac.hidden1, ac.hidden2, ac.uav_head
    dense = lambda i, o: {"w": P((i, o), (None, None)),
                          "b": P((o,), (None,), "zeros")}
    per_uav = lambda i, o: {"w": P((n, i, o), (None, None, None)),
                            "b": P((n, o), (None, None), "zeros")}
    plan = {
        "actor": {"l1": dense(obs, h1), "l2": dense(h1, h2),
                  "uav": per_uav(h2, hu),
                  "ver": per_uav(hu, V), "cut": per_uav(hu, K)},
        "critic": {"l1": dense(obs, h1), "l2": dense(h1, h2),
                   "out": dense(h2, 1)},
    }
    if cfg.cluster is not None:
        # cluster mode: a third per-UAV head routes requests — the
        # (version, cut, server) factored policy the paper's pair lacks
        plan["actor"]["srv"] = per_uav(hu, cfg.cluster.n_servers)
    return plan


def init_agent(cfg, tables, ac, rng):
    return pp.materialize(plan_agent(cfg, tables, ac), rng,
                          jnp.dtype("float32"))


def _dense(p, x):
    return x @ p["w"] + p["b"]


def actor_apply(params, obs_flat):
    """obs_flat: (obs_total,) -> (logits_v (n, V), logits_c (n, K),
    logits_s (n, S) or None). The server head exists only in
    cluster-mode params — a *static* pytree-structure test, so jit
    traces each param family once, never a runtime branch."""
    a = params["actor"]
    h = jax.nn.relu(_dense(a["l1"], obs_flat))
    h = jax.nn.relu(_dense(a["l2"], h))
    hu = jax.nn.relu(jnp.einsum("i,nio->no", h, a["uav"]["w"])
                     + a["uav"]["b"])                       # (n, hu)
    lv = jnp.einsum("no,nov->nv", hu, a["ver"]["w"]) + a["ver"]["b"]
    lc = jnp.einsum("no,nok->nk", hu, a["cut"]["w"]) + a["cut"]["b"]
    ls = None
    if "srv" in a:
        ls = jnp.einsum("no,nos->ns", hu, a["srv"]["w"]) + a["srv"]["b"]
    return lv, lc, ls


def critic_apply(params, obs_flat):
    c = params["critic"]
    h = jax.nn.relu(_dense(c["l1"], obs_flat))
    h = jax.nn.relu(_dense(c["l2"], h))
    return _dense(c["out"], h)[0]


def _mask_logits(logits, valid):
    return jnp.where(valid > 0, logits, -1e9)


def sample_actions(params, obs_flat, valid_v, rng):
    lv, lc, ls = actor_apply(params, obs_flat)
    lv = _mask_logits(lv, valid_v)
    if ls is None:
        k1, k2 = jax.random.split(rng)
    else:
        k1, k2, k3 = jax.random.split(rng, 3)
    av = jax.random.categorical(k1, lv, axis=-1)
    ac_ = jax.random.categorical(k2, lc, axis=-1)
    cols = [av, ac_]
    if ls is not None:
        cols.append(jax.random.categorical(k3, ls, axis=-1))
    return jnp.stack(cols, axis=-1).astype(jnp.int32)


def greedy_actions(params, obs_flat, valid_v):
    lv, lc, ls = actor_apply(params, obs_flat)
    lv = _mask_logits(lv, valid_v)
    cols = [jnp.argmax(lv, -1), jnp.argmax(lc, -1)]
    if ls is not None:
        cols.append(jnp.argmax(ls, -1))
    return jnp.stack(cols, axis=-1).astype(jnp.int32)


def device_logp_entropy(params, obs_flat, actions, valid_v):
    """Per-device (log-prob, entropy) of the taken actions, shape (n,)
    each — the per-UAV terms ``logp_entropy`` sums; the online learner
    (repro.online.adapt) weights them by per-device advantages. In
    cluster mode the factored policy adds the server head's terms."""
    lv, lc, ls = actor_apply(params, obs_flat)
    lv = _mask_logits(lv, valid_v)
    logp_v = jax.nn.log_softmax(lv, -1)
    logp_c = jax.nn.log_softmax(lc, -1)
    lp = (jnp.take_along_axis(logp_v, actions[:, :1], -1)[:, 0]
          + jnp.take_along_axis(logp_c, actions[:, 1:2], -1)[:, 0])
    ent = (-jnp.sum(jnp.exp(logp_v) * logp_v, -1)
           - jnp.sum(jnp.exp(logp_c) * logp_c, -1))
    if ls is not None:
        logp_s = jax.nn.log_softmax(ls, -1)
        lp = lp + jnp.take_along_axis(logp_s, actions[:, 2:3], -1)[:, 0]
        ent = ent - jnp.sum(jnp.exp(logp_s) * logp_s, -1)
    return lp, ent


def logp_entropy(params, obs_flat, actions, valid_v):
    lp, ent = device_logp_entropy(params, obs_flat, actions, valid_v)
    return jnp.sum(lp), jnp.sum(ent)


def valid_versions(tables, state):
    return tables.version_valid[state["model_id"]]   # (n, V)


# --------------------------------------------------------------------------
# rollouts
# --------------------------------------------------------------------------

def make_rollout(env_cfg, tables, *, record_policy=False):
    """Returns ``rollout(params, state0, rng, task_seq=None) ->
    (state_T, traj)``: one episode scanned over ``episode_len`` slots.
    ``traj`` leaves have a leading time axis; with ``record_policy`` the
    behavior policy's per-step logp and value are recorded too (PPO's
    clipped surrogate needs them fixed at sampling time).

    ``task_seq``, when given, is an (episode_len, n) array of per-slot
    offered load in [0, 1] fed through env_step's ``next_task`` hook
    (trace-driven training; see controller.train_agent)."""

    def rollout(params, state0, rng, task_seq=None):
        def step(state, xs):
            k, nxt = xs
            obs = observe(env_cfg, tables, state).reshape(-1)
            valid = valid_versions(tables, state)
            actions = sample_actions(params, obs, valid, k)
            out = {"obs": obs, "actions": actions, "valid": valid}
            if record_policy:
                lp, _ = logp_entropy(params, obs, actions, valid)
                out["logp"] = lp
                out["value"] = critic_apply(params, obs)
            k_env = jax.random.fold_in(k, 1)
            state2, r, info = env_step(env_cfg, tables, state, actions,
                                       k_env, next_task=nxt)
            out.update(reward=r, alive=info["alive"],
                       battery=info["battery"])
            return state2, out

        keys = jax.random.split(rng, env_cfg.episode_len)
        return jax.lax.scan(step, state0, (keys, task_seq))

    return rollout


def run_batched_episodes(env_cfg, tables, rollout, params, rng,
                         batch_envs, model_ids=None, task_seq=None):
    """Reset and roll ``batch_envs`` independent env instances under one
    jit (vmapped over per-env reset/rollout keys and per-env task
    traces). Returns ``(state_T, traj, bootstrap)`` with a leading env
    axis on every leaf; ``bootstrap`` is the critic's value at the final
    state of each env (for return bootstrapping)."""
    k0, k1 = jax.random.split(rng)
    state0 = jax.vmap(
        lambda k: env_reset(env_cfg, tables, k, model_ids=model_ids)
    )(jax.random.split(k0, batch_envs))
    if task_seq is not None:
        # slot t's load is task_seq[:, t]: seed state0 with row 0 and
        # let env_step's next_task install rows 1..T-1 (last repeats)
        state0 = dict(state0, task=task_seq[:, 0])
        task_seq = jnp.concatenate([task_seq[:, 1:], task_seq[:, -1:]],
                                   axis=1)
        state_T, traj = jax.vmap(
            lambda s0, k, ts: rollout(params, s0, k, ts)
        )(state0, jax.random.split(k1, batch_envs), task_seq)
    else:
        state_T, traj = jax.vmap(
            lambda s0, k: rollout(params, s0, k)
        )(state0, jax.random.split(k1, batch_envs))
    obs_T = jax.vmap(
        lambda s: observe(env_cfg, tables, s).reshape(-1))(state_T)
    bootstrap = jax.vmap(lambda o: critic_apply(params, o))(obs_T)
    return state_T, traj, bootstrap


def stack_task_seqs(task_sampler, episode, batch_envs):
    """Sample one update's offered-load sequences from a task_sampler:
    episode indices ``episode*E .. episode*E+E-1`` (per-env domain
    randomization), stacked to (E, T, n) — or (T, n) when E == 1, which
    keeps the unbatched jit signature stable. Shared by the A2C and PPO
    training loops so the indexing convention cannot diverge."""
    import numpy as np

    seq = np.stack([np.asarray(task_sampler(episode * batch_envs + e),
                               dtype=np.float32)
                    for e in range(batch_envs)])
    if batch_envs == 1:
        seq = seq[0]
    return jnp.asarray(seq)


def prepare_task_seq(task_seq, batch_envs):
    """Normalize a task sequence to the batched (E, T, n) layout: a 2-D
    (T, n) sequence (the unbatched API) is shared across all envs."""
    if task_seq is None:
        return None
    task_seq = jnp.asarray(task_seq, jnp.float32)
    if task_seq.ndim == 2:
        task_seq = jnp.broadcast_to(
            task_seq[None], (batch_envs,) + task_seq.shape)
    return task_seq


# --------------------------------------------------------------------------
# return estimators
# --------------------------------------------------------------------------

def discounted_returns(rewards, bootstrap, gamma):
    """n-step discounted returns along the leading time axis."""
    def back(carry, r):
        g = r + gamma * carry
        return g, g
    _, rets = jax.lax.scan(back, bootstrap, rewards, reverse=True)
    return rets


def gae(rewards, values, bootstrap, gamma, lam):
    """Generalized advantage estimation; returns (advantages, returns)."""
    def back(carry, xs):
        adv_next, v_next = carry
        r, v = xs
        delta = r + gamma * v_next - v
        adv = delta + gamma * lam * adv_next
        return (adv, v), adv
    (_, _), advs = jax.lax.scan(back, (jnp.float32(0.0), bootstrap),
                                (rewards, values), reverse=True)
    return advs, advs + values

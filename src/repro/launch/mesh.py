"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline targets; the container runs CPU)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((1, min(n, 1)), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a == "model")


def axis_size(mesh, names) -> int:
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n

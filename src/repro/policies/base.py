"""The Policy protocol + canonical name registry.

One lifecycle for every controller the repo can run, learned or static:

    spec = get_policy_spec("a2c")          # canonical names only
    policy = spec.build(env_cfg, tables)   # bound to one env
    policy.train(seed=0, trace=...)        # trainable specs only
    policy.save("controller.npz")          # reusable artifact
    actions = policy.act(state, rng)       # uniform (n, 2) int32 decide

``act`` must be jit-traceable (pure jnp on the env-state dict): the
fleet simulator compiles it once per policy via ``Policy.jitted`` and
``evaluate_policy`` scans it inside one jitted episode. Every consumer —
``scripts/simulate.py``, ``examples/``, ``benchmarks/run.py``,
``repro.scenarios.run_scenario`` — resolves policies through this
registry, so adding a controller is one ``register`` call, not five
call-site edits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


class Policy:
    """A controller bound to one (env_cfg, tables) world.

    Subclasses implement ``act``; trainable ones additionally implement
    ``train``/``save``/``load`` (see ``repro.policies.trainable``).
    """

    name: str = "policy"
    trainable: bool = False

    def __init__(self, env_cfg, tables):
        self.env_cfg = env_cfg
        self.tables = tables
        self._jit_fn = None
        self._jit_token = None

    def act(self, state, rng):
        """(env-state dict, PRNG key) -> (n_uavs, 2) int32 (version, cut)."""
        raise NotImplementedError

    def jitted(self):
        """Jitted ``act``, cached on the instance and re-traced whenever
        the trainable state changes (params swapped by train/load) — the
        fleet loop's per-epoch decide must not re-trace per call, and
        must not serve stale baked-in params either.

        The traced body counts itself at ``decide.<name>`` in
        ``repro.obs.jaxmon`` — the counter moves only when jit actually
        (re-)traces, so retrace regressions at the fleet's hottest jit
        site are measurable (tests/test_obs.py)."""
        import jax

        from repro.obs import jaxmon

        token = self._cache_token()
        # identity comparison, and the token object itself is pinned on
        # the instance: an id()-style integer could be recycled by a
        # later allocation and silently serve stale compiled params
        if self._jit_fn is None or self._jit_token is not token:
            def _act(state, rng):
                jaxmon.count_trace(f"decide.{self.name}")
                return self.act(state, rng)

            self._jit_fn = jax.jit(_act)
            self._jit_token = token
        return self._jit_fn

    def _cache_token(self):
        return None

    # artifact lifecycle: only trainable policies have state to persist
    def train(self, seed: int = 0, trace=None, log_every: int = 0):
        raise NotImplementedError(f"policy {self.name!r} is not trainable")

    def save(self, path: str) -> str:
        raise NotImplementedError(
            f"policy {self.name!r} has no trainable state to save")

    def load(self, path: str) -> "Policy":
        raise NotImplementedError(
            f"policy {self.name!r} has no trainable state to load")


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Registry entry: how to build one named policy for a given env."""
    name: str
    factory: Callable[..., Policy]
    trainable: bool = False
    description: str = ""
    needs_cluster: bool = False  # only buildable when EnvConfig.cluster set

    def build(self, env_cfg, tables, **kw) -> Policy:
        policy = self.factory(env_cfg, tables, **kw)
        policy.name = self.name
        return policy


_REGISTRY: Dict[str, PolicySpec] = {}


def register(spec: PolicySpec) -> PolicySpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"policy {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def policy_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_policy_spec(name: str) -> PolicySpec:
    """Canonical-name lookup; a miss names every valid policy (there are
    no aliases — 'oracle' was historical drift for 'greedy_oracle')."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; valid names: "
                       f"{', '.join(policy_names())}")
    return _REGISTRY[name]


def build_policy(name: str, env_cfg, tables, **kw) -> Policy:
    return get_policy_spec(name).build(env_cfg, tables, **kw)

"""repro.bench.gate — statistically gated perf regression detection.

``gate_records(current, history, fp)`` compares every timed record of
the current run against its pooled matching-fingerprint baseline
(``history.baseline_for``) with the ``stats.compare`` rule — minimum
effect threshold AND nonparametric significance — and returns a
``GateReport`` of per-case verdicts:

    regression            significantly slower beyond min_effect  (FAILS)
    improved              significantly faster beyond min_effect
    ok                    within noise or below min_effect
    insufficient          too few samples on either side (reported only)
    new                   no history for this case+fingerprint
    fingerprint_mismatch  history exists but only under other
                          environments — the gate REFUSES to compare
    error                 the case crashed this run (bench exit already
                          nonzero; never compared)

For every regression the gate folds the per-phase obs breakdown the
runner stored (current vs the baseline rows' average) and names the
*dominant regressed phase* — the span contributing the largest
absolute slowdown — so a failed ``fleet_sim`` says
``pricing.analytical +120%`` instead of making you rerun under a
profiler. ``render`` prints the verdict table plus the devices/sec
scaling curves (records carrying ``extra.devices_per_s``) the
mega-fleet work tracks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.bench import history as hist
from repro.bench.stats import compare, format_sig

# a phase only counts as regressed if its own slowdown clears this
# fraction AND it explains a visible share of the case's added time
PHASE_MIN_EFFECT = 0.10
PHASE_MIN_TOTAL_S = 1e-5


@dataclass
class CaseVerdict:
    name: str
    status: str                         # see module docstring
    effect: float = 0.0                 # median ratio - 1 (+ = slower)
    p: float = 1.0                      # one-sided MWU p (direction of effect)
    base_median: float = float("nan")
    cur_median: float = float("nan")
    n_base: int = 0
    n_cur: int = 0
    cur_ci: Tuple[float, float] = (float("nan"), float("nan"))
    base_shas: List[str] = field(default_factory=list)
    phase: Optional[str] = None         # dominant regressed span name
    phase_detail: str = ""
    note: str = ""

    def to_json(self) -> Dict:
        d = {"name": self.name, "status": self.status,
             "effect": format_sig(self.effect),
             "p": format_sig(self.p),
             "base_median": format_sig(self.base_median),
             "cur_median": format_sig(self.cur_median),
             "n_base": self.n_base, "n_cur": self.n_cur,
             "cur_ci": [format_sig(x) for x in self.cur_ci],
             "base_shas": self.base_shas}
        if self.phase:
            d["phase"] = self.phase
            d["phase_detail"] = self.phase_detail
        if self.note:
            d["note"] = self.note
        return d


@dataclass
class GateReport:
    verdicts: List[CaseVerdict]
    fingerprint: Dict
    refused: bool = False               # nothing at all was comparable
    reason: str = ""

    @property
    def regressions(self) -> List[CaseVerdict]:
        return [v for v in self.verdicts if v.status == "regression"]

    @property
    def failed(self) -> bool:
        return bool(self.regressions)

    def counts(self) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for v in self.verdicts:
            c[v.status] = c.get(v.status, 0) + 1
        return c

    def to_json(self) -> Dict:
        return {"failed": self.failed, "refused": self.refused,
                "reason": self.reason, "counts": self.counts(),
                "fingerprint": self.fingerprint,
                "verdicts": [v.to_json() for v in self.verdicts]}


# --------------------------------------------------------------------------
# phase attribution
# --------------------------------------------------------------------------

def _mean_phases(rows: Sequence[Dict]) -> Dict[str, float]:
    """Average per-phase total_s across the baseline rows that carry a
    breakdown (older-history rows without one contribute nothing)."""
    acc: Dict[str, List[float]] = {}
    for r in rows:
        for name, p in (r.get("phases") or {}).items():
            acc.setdefault(name, []).append(float(p["total_s"]))
    return {name: sum(v) / len(v) for name, v in acc.items()}


def attribute_phase(base_rows: Sequence[Dict], cur_record: Dict,
                    min_effect: float = PHASE_MIN_EFFECT
                    ) -> Tuple[Optional[str], str]:
    """Name the span whose slowdown dominates the case's added time.

    Ranked by absolute added seconds (a phase that doubled but costs
    2us never outranks one that grew 30% on the critical path); a
    phase must itself be slower than baseline by ``min_effect``. Spans
    new in the current run (absent from every baseline row) qualify
    with their full cost."""
    base = _mean_phases(base_rows)
    cur = cur_record.get("phases") or {}
    if not cur:
        return None, ""
    best: Optional[Tuple[float, str, str]] = None
    for name, p in cur.items():
        ct = float(p["total_s"])
        if ct < PHASE_MIN_TOTAL_S:
            continue
        bt = base.get(name)
        if bt is None:
            if base:        # genuinely new span this run
                cand = (ct, name, f"new span, {ct*1e3:.2f}ms")
            else:           # baseline has no breakdown at all
                continue
        else:
            if bt <= 0 or ct / bt - 1.0 <= min_effect:
                continue
            cand = (ct - bt, name,
                    f"+{(ct/bt - 1.0)*100:.0f}% "
                    f"({bt*1e3:.2f}ms -> {ct*1e3:.2f}ms)")
        if best is None or cand[0] > best[0]:
            best = cand
    if best is None:
        return None, ""
    return best[1], best[2]


# --------------------------------------------------------------------------
# gating
# --------------------------------------------------------------------------

def gate_records(records: Sequence[Dict], history_rows: Sequence[Dict],
                 fp: Optional[Dict] = None, *, min_effect: float = 0.10,
                 alpha: float = 0.05, pool: int = hist.DEFAULT_POOL,
                 min_samples: int = 3) -> GateReport:
    fp = fp or hist.fingerprint()
    verdicts: List[CaseVerdict] = []
    comparable = 0
    mismatched = 0
    for rec in records:
        name = rec.get("name", "?")
        if "error" in rec:
            verdicts.append(CaseVerdict(name=name, status="error",
                                        note=rec["error"]))
            continue
        base = hist.baseline_for(name, fp, history_rows, pool=pool)
        if base is None:
            if hist.has_foreign_fingerprint(name, fp, history_rows):
                mismatched += 1
                verdicts.append(CaseVerdict(
                    name=name, status="fingerprint_mismatch",
                    note="history rows exist only under other "
                         "environment fingerprints"))
            else:
                verdicts.append(CaseVerdict(name=name, status="new"))
            continue
        comparable += 1
        cur_samples = [float(s) for s in rec.get("samples", [])]
        c = compare(base.samples, cur_samples, min_effect=min_effect,
                    alpha=alpha, min_samples=min_samples)
        v = CaseVerdict(name=name, status=c.verdict, effect=c.effect,
                        p=c.p_slower if c.effect >= 0 else c.p_faster,
                        base_median=c.base_median,
                        cur_median=c.cur_median, n_base=c.n_base,
                        n_cur=c.n_cur, cur_ci=c.cur_ci,
                        base_shas=base.shas)
        if c.verdict == "regression":
            v.phase, v.phase_detail = attribute_phase(base.rows, rec)
        verdicts.append(v)
    refused = (comparable == 0 and mismatched > 0)
    reason = ""
    if refused:
        reason = (f"refusing to gate: history matches no case under "
                  f"fingerprint {hist.fp_key(fp)} "
                  f"({mismatched} case(s) recorded under other "
                  f"environments)")
    return GateReport(verdicts=verdicts, fingerprint=fp,
                      refused=refused, reason=reason)


# --------------------------------------------------------------------------
# rendering
# --------------------------------------------------------------------------

_STATUS_ORDER = ["regression", "error", "improved", "ok", "insufficient",
                 "new", "fingerprint_mismatch"]


def _fmt_us(x: float) -> str:
    return "-" if x != x else f"{x:.4g}"


def scaling_curves(records: Sequence[Dict]) -> str:
    """devices/sec scaling curves from records carrying
    ``extra.devices_per_s`` (the mega-fleet trajectory)."""
    rows = [(r["name"], r["extra"]) for r in records
            if "extra" in r and "devices_per_s" in r["extra"]]
    if not rows:
        return ""
    lines = ["scaling (devices/sec):"]
    for name, ex in rows:
        dev = ex.get("devices", "?")
        lines.append(f"  {name:32s} devices={dev:>8} "
                     f"devices_per_s={ex['devices_per_s']:.4g}")
    return "\n".join(lines)


def render(report: GateReport,
           records: Sequence[Dict] = ()) -> str:
    c = report.counts()
    head = "bench gate: " + ("REFUSED" if report.refused else
                             "FAIL" if report.failed else "PASS")
    head += "   " + "  ".join(f"{k}={c[k]}" for k in _STATUS_ORDER
                              if k in c)
    lines = [head, f"fingerprint: {hist.fp_key(report.fingerprint)}"]
    if report.reason:
        lines.append(report.reason)
    lines += ["", f"{'case':36s} {'verdict':>20s} {'base_med':>10s} "
                  f"{'cur_med':>10s} {'effect':>8s} {'p':>7s}  n"]
    order = {s: i for i, s in enumerate(_STATUS_ORDER)}
    for v in sorted(report.verdicts,
                    key=lambda v: (order.get(v.status, 99), v.name)):
        eff = f"{v.effect*100:+.1f}%" if v.n_base else "-"
        p = f"{v.p:.3f}" if v.n_base else "-"
        lines.append(f"{v.name:36s} {v.status:>20s} "
                     f"{_fmt_us(v.base_median):>10s} "
                     f"{_fmt_us(v.cur_median):>10s} {eff:>8s} {p:>7s}  "
                     f"{v.n_base}v{v.n_cur}")
        if v.status == "regression":
            if v.phase:
                lines.append(f"{'':36s}   ^ dominant regressed phase: "
                             f"{v.phase} {v.phase_detail}")
            else:
                lines.append(f"{'':36s}   ^ no phase breakdown available "
                             f"for attribution")
        if v.note:
            lines.append(f"{'':36s}   ^ {v.note}")
    curves = scaling_curves(records)
    if curves:
        lines += ["", curves]
    return "\n".join(lines)

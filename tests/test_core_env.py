"""EdgeRL core: env invariants (hypothesis property tests), reward math,
profiles, and A2C learning."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import (A2CConfig, EnvConfig, RewardWeights, env_reset,
                        env_step, make_paper_env, make_tpu_env, observe,
                        paper_profiles)
from repro.core import reward as rw
from repro.policies import build_policy
from repro.core.env import action_costs, build_tables
from repro.core.profiles import transformer_profile
from repro.configs import get_config


@pytest.fixture(scope="module")
def paper_env():
    return make_paper_env()


# --------------------------------------------------------------------------
# profiles
# --------------------------------------------------------------------------

def test_paper_profile_flops_match_literature():
    """Analytic GFLOPs must land near the published numbers."""
    profs = paper_profiles()
    expect = {("vgg", "11"): 15.2, ("vgg", "19"): 39.0,
              ("resnet", "18"): 3.6, ("resnet", "50"): 8.2,
              ("densenet", "121"): 5.7, ("densenet", "161"): 15.6}
    for p in profs.values():
        for v in p.versions:
            want = expect[(v.model, v.version)]
            got = v.total_flops / 1e9
            assert abs(got - want) / want < 0.15, (v.model, v.version, got)


def test_profile_head_tail_partition():
    profs = paper_profiles()
    for p in profs.values():
        for v in p.versions:
            for cut in v.cut_points:
                np.testing.assert_allclose(
                    v.head_flops(cut) + v.tail_flops(cut), v.total_flops,
                    rtol=1e-9)
            assert v.head_flops(0) == 0
            assert v.tail_flops(v.n_layers) == 0


def test_transformer_profiles_cover_all_archs():
    from repro.configs import ALL_ARCHS
    for a in ALL_ARCHS:
        prof = transformer_profile(get_config(a))
        assert prof.versions
        for v in prof.versions:
            assert v.total_flops > 0
            assert all(0 < c <= v.n_layers for c in v.cut_points)


# --------------------------------------------------------------------------
# reward math (Eqs. 8-11)
# --------------------------------------------------------------------------

@given(acc=st.floats(0.0, 1.0))
@settings(max_examples=50, deadline=None)
def test_accuracy_score_bounds(acc):
    w = RewardWeights()
    s = float(rw.accuracy_score(w, jnp.float32(acc)))
    assert 0.0 <= s <= 1.0


@given(t=st.floats(0.0, 100.0), tfull=st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_latency_score_upper_bound(t, tfull):
    s = float(rw.latency_score(jnp.float32(t), jnp.float32(tfull)))
    assert s <= 1.0 + 1e-6
    if t <= tfull:
        assert s >= 0.0 - 1e-6


def test_weights_normalize():
    w = RewardWeights(w_acc=2.0, w_lat=1.0, w_energy=1.0).normalized()
    assert abs(w.w_acc + w.w_lat + w.w_energy - 1.0) < 1e-9


# --------------------------------------------------------------------------
# env invariants
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1), j=st.integers(0, 1),
       k=st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_env_step_invariants(seed, j, k, ):
    cfg, tables = make_paper_env()
    key = jax.random.key(seed)
    state = env_reset(cfg, tables, key)
    actions = jnp.tile(jnp.asarray([[j, k]], jnp.int32), (cfg.n_uavs, 1))
    state2, r, info = env_step(cfg, tables, state, actions, key)
    # battery is non-increasing and non-negative
    assert bool(jnp.all(state2["battery_j"] <= state["battery_j"]))
    assert bool(jnp.all(state2["battery_j"] >= 0.0))
    # bandwidth stays in range
    lp = cfg.latency
    assert bool(jnp.all(state2["bandwidth"] >= lp.bw_min_bps - 1))
    assert bool(jnp.all(state2["bandwidth"] <= lp.bw_max_bps + 1))
    # queue non-negative, reward finite
    assert float(state2["queue"]) >= 0.0
    assert np.isfinite(float(r))
    # latency decomposition positive
    assert bool(jnp.all(info["t_total"] > 0.0))
    assert bool(jnp.all(info["e_infer"] >= 0.0))


def test_observation_shape_and_range(paper_env):
    cfg, tables = paper_env
    state = env_reset(cfg, tables, jax.random.key(0))
    obs = observe(cfg, tables, state)
    assert obs.shape == (cfg.n_uavs, cfg.obs_dim_per_uav)
    assert bool(jnp.all(jnp.isfinite(obs)))


def test_cut_monotonicity(paper_env):
    """Later cuts -> more head FLOPs, i.e. more device time (Eq. 1)."""
    cfg, tables = paper_env
    state = env_reset(cfg, tables, jax.random.key(0))
    t_loc = []
    for k in range(tables.n_cuts):
        a = jnp.tile(jnp.asarray([[1, k]], jnp.int32), (cfg.n_uavs, 1))
        head = tables.head_flops[state["model_id"], a[:, 0], a[:, 1]]
        t_loc.append(np.asarray(head))
    t = np.stack(t_loc)
    assert (np.diff(t, axis=0) >= 0).all()


def test_greedy_beats_random(paper_env):
    from repro.core import evaluate_policy
    cfg, tables = paper_env
    g = evaluate_policy(cfg, tables,
                        build_policy("greedy_oracle", cfg, tables),
                        jax.random.key(3), episodes=1)
    r = evaluate_policy(cfg, tables, build_policy("random", cfg, tables),
                        jax.random.key(3), episodes=1)
    assert g["reward"] > r["reward"]


def test_tpu_env_builds_and_steps():
    cfg, tables = make_tpu_env(["qwen2-0.5b", "falcon-mamba-7b"])
    state = env_reset(cfg, tables, jax.random.key(0))
    actions = jnp.zeros((2, 2), jnp.int32)
    state2, r, info = env_step(cfg, tables, state, actions, jax.random.key(1))
    assert np.isfinite(float(r))


# --------------------------------------------------------------------------
# A2C learning
# --------------------------------------------------------------------------

def test_a2c_improves_over_training(paper_env):
    from repro.core import train_agent
    cfg, tables = paper_env
    _, hist = train_agent(cfg, tables, A2CConfig(episodes=80), seed=0)
    first = np.mean([h["mean_reward"] for h in hist[:15]])
    last = np.mean([h["mean_reward"] for h in hist[-15:]])
    assert last > first + 0.05, (first, last)


def test_a2c_episode_is_deterministic(paper_env):
    from repro.core import init_agent, make_train_episode
    from repro.optim import adamw_init
    cfg, tables = paper_env
    ac = A2CConfig(episodes=2)
    params = init_agent(cfg, tables, ac, jax.random.key(0))
    opt = adamw_init(params)
    step = make_train_episode(cfg, tables, ac)
    _, _, s1 = step(params, opt, jax.random.key(7))
    _, _, s2 = step(params, opt, jax.random.key(7))
    assert float(s1["loss"]) == float(s2["loss"])


def test_dryrun_calibrated_env(tmp_path):
    """Beyond-paper: profiles calibrated to measured dry-run FLOPs."""
    import json
    import os
    from repro.core.roofline_env import make_dryrun_tpu_env
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("no dry-run results in this checkout")
    cfg, tables = make_dryrun_tpu_env(["qwen2-0.5b", "falcon-mamba-7b"],
                                      results=path)
    state = env_reset(cfg, tables, jax.random.key(0))
    actions = jnp.zeros((2, 2), jnp.int32)
    _, r, info = env_step(cfg, tables, state, actions, jax.random.key(1))
    assert np.isfinite(float(r))
    # calibrated totals must exceed the naive analytic ones (remat etc.)
    assert float(tables.full_flops[0, 0]) > 0


def test_ppo_learns(paper_env):
    """Beyond-paper PPO agent also improves on the EdgeRL env."""
    from repro.core import ppo as PPO
    cfg, tables = paper_env
    _, hist = PPO.train(cfg, tables, PPO.PPOConfig(episodes=60),
                        jax.random.key(0))
    first = np.mean([h["mean_reward"] for h in hist[:10]])
    last = np.mean([h["mean_reward"] for h in hist[-10:]])
    assert last > first + 0.03, (first, last)

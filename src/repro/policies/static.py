"""Static (non-trainable) policies: the paper's implicit baselines,
wrapped from ``repro.core.baselines`` into the Policy protocol and
registered under their canonical names.
"""
from __future__ import annotations

from repro.core import baselines
from repro.policies.base import Policy, PolicySpec, register


class StaticPolicy(Policy):
    """Binds a pure baseline function ``fn(cfg, tables, state, rng)`` to
    one env; stateless, so ``build`` is the whole lifecycle."""

    def __init__(self, env_cfg, tables, fn):
        super().__init__(env_cfg, tables)
        self._fn = fn

    def act(self, state, rng):
        return self._fn(self.env_cfg, self.tables, state, rng)


def _static(name: str, fn, description: str) -> PolicySpec:
    return register(PolicySpec(
        name=name,
        factory=lambda env_cfg, tables, **kw: StaticPolicy(env_cfg, tables,
                                                           fn),
        trainable=False, description=description))


_static("device_only", baselines.device_only,
        "lightweight version, everything local (last cut)")
_static("full_offload", baselines.full_offload,
        "heaviest valid version, cut as early as possible")
_static("random", baselines.random_policy,
        "uniform over valid (version, cut) pairs")
_static("greedy_oracle", baselines.greedy_oracle,
        "per-step per-UAV reward argmax over the (V, K) grid")

"""The declarative Scenario: one dataclass describing an experiment's
whole operating regime — env kind + fleet shape, reward weighting,
workload trace, SLO, training budget and evaluation seeds — so every
consumer (CLI, examples, benchmarks, tests) enumerates requirements
instead of re-plumbing build_trace/build_env/build_policy by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import make_paper_env, make_tpu_env, transformer_profile
from repro.core.latency import LatencyParams
from repro.core.reward import RewardWeights
from repro.sim import AnalyticalBackend, ExecuteBackend, get_trace
from repro.sim.traces import Trace


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, fully-specified operating regime.

    ``build_env()``/``build_trace()``/``build_train_trace()`` turn the
    declaration into live objects; ``run_scenario`` (repro.scenarios.run)
    is the single entry point that consumes them. ``replace(**kw)``
    derives variants (CLI flags override preset fields through it).
    """
    name: str
    description: str = ""

    # --- world -----------------------------------------------------------
    env: str = "paper"                   # "paper" | "tpu"
    devices: int = 4
    arch: str = "qwen2-0.5b"             # tpu env: assigned transformer
    models: str = "cycle"                # paper env fleet composition
    weights: RewardWeights = dataclasses.field(
        default_factory=lambda: RewardWeights(w_acc=0.05, w_lat=0.10,
                                              w_energy=0.15, w_stab=0.70))
    slot_seconds: float = 10.0
    peak_rps: float = 30.0               # 0 -> paper-faithful reward
    # paper-env fleet provisioning; None keeps LatencyParams defaults
    # (the paper's 3-UAV testbed numbers)
    server_flops_per_device: Optional[float] = 0.55e12
    bw_max_bps: Optional[float] = 1e9
    bw_min_bps: Optional[float] = None

    # --- server cluster (repro.cluster; paper env only) --------------------
    # named pool preset (cluster.get_pool) -> heterogeneous server pool;
    # None keeps the classic single-server world with (version, cut)
    # actions. With a pool, actions widen to (version, cut, server) and
    # the topology preset prices each device->server link.
    pool: Optional[str] = None
    pool_kw: Dict = dataclasses.field(default_factory=dict)
    topology: str = "uniform"
    topology_kw: Dict = dataclasses.field(default_factory=dict)
    # named autoscaler policy over the pool ("threshold"|"hysteresis");
    # None pins replicas/DVFS at the nominal operating point
    autoscale: Optional[str] = None
    autoscale_kw: Dict = dataclasses.field(default_factory=dict)

    # --- workload ---------------------------------------------------------
    trace: str = "mmpp"
    trace_kw: Dict = dataclasses.field(default_factory=dict)

    # --- nonstationarity / online adaptation (repro.online) ---------------
    # named WorldSchedule factory (drift.get_schedule) + kwargs; None
    # keeps the world stationary
    drift: Optional[str] = None
    drift_kw: Dict = dataclasses.field(default_factory=dict)
    # OnlineConfig overrides for "+online" roster entries (the algo is
    # taken from the policy spec: a2c -> a2c objective, ppo -> ppo)
    online_kw: Dict = dataclasses.field(default_factory=dict)
    # device battery override (Wh); nonstationary runs need the fleet
    # to outlive the drift-recover cycle (paper env only)
    battery_wh: Optional[float] = None

    # --- evaluation -------------------------------------------------------
    slo_s: float = 2.0
    # SLO attainment objective for the error-budget report
    # (repro.obs.slo): at most (1 - slo_target) of offered requests may
    # miss the slo_s deadline or drop before the budget is spent
    slo_target: float = 0.95
    seeds: Tuple[int, ...] = (0, 1, 2)   # paired across policies
    n_requests: int = 20_000
    policies: Tuple[str, ...] = ("a2c", "device_only", "full_offload")
    # fleet epoch-flow engine (FleetConfig.engine / sim.megafleet):
    # "loop" per-device oracle, "vectorized" fused-numpy (bit-identical),
    # "scan" jitted lax.scan (stationary worlds, static policies)
    engine: str = "loop"

    # --- training budget (trainable policies) -----------------------------
    episodes: int = 300
    entropy_coef: float = 0.03
    batch_envs: int = 1
    train_seed: int = 0
    train_trace: Optional[str] = "uniform"   # domain randomization
    train_trace_kw: Dict = dataclasses.field(default_factory=dict)

    # --- execute cross-check (tpu env) -------------------------------------
    execute: bool = False
    sample: int = 16
    exec_seq: int = 32

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    # -- builders ----------------------------------------------------------
    def build_trace(self) -> Trace:
        return get_trace(self.trace, **self.trace_kw)

    def build_schedule(self):
        """The scenario's WorldSchedule, or None when stationary."""
        if self.drift is None:
            return None
        from repro.online import get_schedule
        return get_schedule(self.drift, **self.drift_kw)

    def build_online(self, algo: str = "a2c"):
        """OnlineConfig for a '+online' roster entry; ``algo`` comes
        from the policy spec so A2C and PPO adapt with their own
        objective on the shared incremental-update machinery."""
        from repro.online import OnlineConfig
        return OnlineConfig(algo=algo, **self.online_kw)

    def build_cluster(self):
        """ClusterParams from the pool/topology presets, or None."""
        if self.pool is None:
            return None
        from repro.cluster import build_cluster, get_pool, get_topology
        servers = get_pool(self.pool, **self.pool_kw)
        topo = get_topology(self.topology, self.devices, len(servers),
                            **self.topology_kw)
        return build_cluster(servers, topo)

    def build_autoscaler(self):
        """AutoscalerConfig for the fleet's ServerPool, or None."""
        if self.autoscale is None:
            return None
        if self.pool is None:
            raise ValueError(f"scenario {self.name!r} sets autoscale="
                             f"{self.autoscale!r} without a server pool")
        from repro.cluster import AutoscalerConfig
        return AutoscalerConfig(policy=self.autoscale,
                                **self.autoscale_kw)

    def build_train_trace(self) -> Optional[Trace]:
        """The load process trainable policies see; None under the
        paper-faithful reward (peak_rps == 0 -> Bernoulli task draws)."""
        if self.train_trace is None or self.peak_rps <= 0:
            return None
        kw = dict(self.train_trace_kw)
        if self.train_trace == "uniform" and not kw:
            kw = {"max_rps": self.peak_rps}   # cover the whole load range
        return get_trace(self.train_trace, **kw)

    def build_env(self):
        """Returns (env_cfg, tables, model_ids, backend_factory) — the
        same quadruple scripts/simulate.py historically hand-built."""
        if self.env == "tpu":
            return self._build_tpu_env()
        if self.execute:
            raise ValueError("execute=True needs env='tpu' (the "
                             "executable engine serves the transformer "
                             "stack)")
        lat_kw = {}
        if self.server_flops_per_device is not None:
            lat_kw["server_flops"] = self.server_flops_per_device \
                * self.devices
        if self.bw_max_bps is not None:
            lat_kw["bw_max_bps"] = self.bw_max_bps
        if self.bw_min_bps is not None:
            lat_kw["bw_min_bps"] = self.bw_min_bps
        env_kw = {}
        if self.battery_wh is not None:
            from repro.core.energy import DevicePower
            env_kw["power"] = DevicePower(battery_wh=self.battery_wh)
        cluster = self.build_cluster()
        if cluster is not None:
            env_kw["cluster"] = cluster
        env_cfg, tables = make_paper_env(
            weights=self.weights, n_uavs=self.devices,
            latency=LatencyParams(**lat_kw),
            slot_seconds=self.slot_seconds, peak_rps=self.peak_rps,
            # one frame per request at saturation: env battery drain per
            # slot equals the fleet's per-request metering
            frames_per_slot=self.slot_seconds * max(self.peak_rps, 1.0),
            **env_kw)
        if self.models == "cycle":
            model_ids = np.arange(self.devices,
                                  dtype=np.int32) % tables.n_models
        else:
            model_ids = np.full(self.devices,
                                tables.names.index(self.models), np.int32)
        return env_cfg, tables, model_ids, \
            lambda: AnalyticalBackend(env_cfg, tables)

    def _build_tpu_env(self):
        import jax

        from repro.configs import get_config

        if self.pool is not None:
            raise ValueError("server pools (Scenario.pool) model the "
                             "paper env's edge cluster; the tpu env's "
                             "tail submesh is a single shared server")
        archs = [self.arch] * self.devices
        env_cfg, tables = make_tpu_env(
            archs, weights=self.weights, reduced=True,
            seq_len=self.exec_seq, slot_seconds=self.slot_seconds,
            peak_rps=self.peak_rps)
        model_ids = np.zeros(self.devices, np.int32)

        def backend_factory():
            if not self.execute:
                return AnalyticalBackend(env_cfg, tables)
            from repro.models import init

            cfg = get_config(self.arch).reduced()
            prof = transformer_profile(cfg, seq_len=self.exec_seq)
            params = init(cfg, jax.random.key(0))
            return ExecuteBackend(env_cfg, tables, [cfg], [prof], [params],
                                  seq_len=self.exec_seq, sample=self.sample)
        return env_cfg, tables, model_ids, backend_factory

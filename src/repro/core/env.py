"""EdgeEnv: the paper's ad-hoc edge MDP as a pure-JAX environment.

State (Eq. 6) per UAV: battery level b in [0,10], task availability
alpha in {0,1} (generalized to measured offered load in [0,1] when a
workload trace drives the env — see env_step's next_task and
EnvConfig.peak_rps), transmit power P_tx, model id m, and the activity
mix (forward F, vertical V, rotation R) over the next slot. Shared
state: per-UAV link bandwidth and the edge-server queue length (Poisson
side workload by default, trace-injectable -> Eq. 4 queue term).

Action (Eq. 7) per UAV: (version j, cut-point index l) into the profile
tables. ``env_step`` is jit/scan-friendly: all dynamics are jnp ops on a
dict-of-arrays state, so whole A2C episodes run inside one jit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.pool import ClusterParams
from repro.core import energy as en
from repro.core import latency as lat
from repro.core import pricing
from repro.core import reward as rw
from repro.core.profiles import ModelProfile


# Per-UAV observation feature spec (Eq. 6 + bandwidth/queue, which the
# controller measures). ``observe`` emits exactly these features in this
# order, and the A2C input width is derived from it — adding a feature
# here resizes the agent instead of silently desyncing it.
OBS_FEATURES: Tuple[str, ...] = (
    "battery", "task", "p_tx", "model_id",
    "act_forward", "act_vertical", "act_rotate",
    "bandwidth", "queue",
)


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    n_uavs: int = 3
    slot_seconds: float = 30.0        # paper: delta = 30 s
    episode_len: int = 96             # slots per episode (battery-bounded)
    frames_per_slot: float = 30.0     # 1 fps reconnaissance video
    queue_arrival_rate: float = 4.0   # Poisson jobs/slot (server side work)
    queue_service_per_slot: float = 5.0
    task_prob: float = 0.9
    # High activity profile (paper Sec. III-A): 80% fwd, 10% vert, 10% rot
    activity: Tuple[float, float, float] = (0.8, 0.1, 0.1)
    activity_jitter: float = 0.05
    # Slots a (version, cut) choice persists for, amortizing the shipping
    # of the tail weights (tables.tail_weight_bytes) over the link.
    # 0 disables the term (the paper's CNNs are pre-staged on the server).
    weight_ship_slots: float = 0.0
    # Request rate (per device, requests/s) that saturates the task/load
    # feature. When > 0, action_costs adds a stability score
    # sigmoid(p_stab * (1 - u)) with u = task * peak_rps * service_s —
    # the request-level capacity signal the per-slot paper scores lack
    # (weighted by RewardWeights.w_stab; 0 keeps the paper's reward).
    peak_rps: float = 0.0
    # Heterogeneous server pool + device->server link matrix
    # (repro.cluster). None keeps the classic single-server MDP with
    # (version, cut) actions; set, it widens actions to (version, cut,
    # server), makes the queue state per-server, and reprices Eq. 2-4
    # per chosen target through the same pricing core.
    cluster: Optional[ClusterParams] = None
    power: en.DevicePower = dataclasses.field(default_factory=en.DevicePower)
    latency: lat.LatencyParams = dataclasses.field(
        default_factory=lat.LatencyParams)
    weights: rw.RewardWeights = dataclasses.field(
        default_factory=rw.RewardWeights)

    @property
    def n_servers(self) -> int:
        return 1 if self.cluster is None else self.cluster.n_servers

    @property
    def action_dim(self) -> int:
        return 2 if self.cluster is None else 3

    @property
    def obs_dim_per_uav(self) -> int:
        # cluster mode widens the single "queue" feature to one column
        # per server (the controller sees every server's depth)
        return len(OBS_FEATURES) + (self.n_servers - 1)


@dataclasses.dataclass(frozen=True)
class ProfileTables:
    """Dense (M, V, K) lookup tables built from ModelProfiles."""
    head_flops: jnp.ndarray      # (M, V, K)
    tail_flops: jnp.ndarray      # (M, V, K)
    cut_bytes: jnp.ndarray       # (M, V, K)
    tail_weight_bytes: jnp.ndarray  # (M, V, K) server-side weight shipping
    acc: jnp.ndarray             # (M, V)
    full_flops: jnp.ndarray      # (M, V)  all-local FLOPs
    version_valid: jnp.ndarray   # (M, V) 1.0 if version exists
    n_versions: int
    n_cuts: int
    names: Tuple[str, ...]

    @property
    def n_models(self) -> int:
        return self.head_flops.shape[0]


def build_tables(profiles: Sequence[ModelProfile]) -> ProfileTables:
    V = max(len(p.versions) for p in profiles)
    K = max(len(v.cut_points) for p in profiles for v in p.versions)
    M = len(profiles)
    head = np.zeros((M, V, K))
    tail = np.zeros((M, V, K))
    bts = np.zeros((M, V, K))
    wbts = np.zeros((M, V, K))
    acc = np.zeros((M, V))
    full = np.zeros((M, V))
    valid = np.zeros((M, V))
    for mi, p in enumerate(profiles):
        for vi in range(V):
            v = p.versions[min(vi, len(p.versions) - 1)]
            valid[mi, vi] = float(vi < len(p.versions))
            acc[mi, vi] = v.accuracy
            full[mi, vi] = v.total_flops
            cuts = list(v.cut_points) + [v.cut_points[-1]] * K
            for ki in range(K):
                c = cuts[ki]
                head[mi, vi, ki] = v.head_flops(c)
                tail[mi, vi, ki] = v.tail_flops(c)
                bts[mi, vi, ki] = v.cut_bytes(c)
                wbts[mi, vi, ki] = v.tail_weight_bytes(c)
    return ProfileTables(
        head_flops=jnp.asarray(head), tail_flops=jnp.asarray(tail),
        cut_bytes=jnp.asarray(bts), tail_weight_bytes=jnp.asarray(wbts),
        acc=jnp.asarray(acc),
        full_flops=jnp.asarray(full), version_valid=jnp.asarray(valid),
        n_versions=V, n_cuts=K, names=tuple(p.name for p in profiles))


def env_reset(cfg: EnvConfig, tables: ProfileTables, rng,
              model_ids=None) -> Dict:
    n = cfg.n_uavs
    k1, k2, k3 = jax.random.split(rng, 3)
    if model_ids is None:
        model_ids = jnp.arange(n, dtype=jnp.int32) % tables.n_models
    bw = jax.random.uniform(k1, (n,), minval=cfg.latency.bw_min_bps,
                            maxval=cfg.latency.bw_max_bps)
    ptx = jax.random.uniform(k2, (n,), minval=cfg.power.p_tx_min,
                             maxval=cfg.power.p_tx_max)
    return {
        "battery_j": jnp.full((n,), cfg.power.battery_j),
        "task": jnp.ones((n,), jnp.float32),
        "p_tx": ptx,
        "model_id": model_ids,
        "activity": jnp.tile(jnp.asarray(cfg.activity)[None], (n, 1)),
        "bandwidth": bw,
        "queue": (jnp.float32(0.0) if cfg.cluster is None
                  else jnp.zeros((cfg.cluster.n_servers,), jnp.float32)),
        "t": jnp.int32(0),
    }


def _obs_features(cfg: EnvConfig, tables: ProfileTables, state) -> Dict:
    """Normalized per-UAV features, keyed by OBS_FEATURES name."""
    p, l = cfg.power, cfg.latency
    b = state["battery_j"] / p.battery_j * 10.0
    return {
        "battery": b / 10.0,
        "task": state["task"],
        "p_tx": (state["p_tx"] - p.p_tx_min) / (p.p_tx_max - p.p_tx_min),
        "model_id": state["model_id"].astype(jnp.float32)
        / max(tables.n_models - 1, 1),
        "act_forward": state["activity"][:, 0],
        "act_vertical": state["activity"][:, 1],
        "act_rotate": state["activity"][:, 2],
        "bandwidth": (state["bandwidth"] - l.bw_min_bps)
        / (l.bw_max_bps - l.bw_min_bps),
        # cluster mode: one column per server ((n, S)); classic: (n,)
        "queue": jnp.broadcast_to(
            state["queue"] / 20.0,
            state["task"].shape if cfg.cluster is None
            else (state["task"].shape[0], cfg.cluster.n_servers)),
    }


def observe(cfg: EnvConfig, tables: ProfileTables, state) -> jnp.ndarray:
    """(n_uavs, obs_dim_per_uav) normalized observation (Eq. 6 +
    bandwidth/queue, which the controller measures). Feature order is
    OBS_FEATURES — the single source of truth for the A2C input width;
    in cluster mode the "queue" feature contributes one column per
    server (obs_dim_per_uav accounts for the widening)."""
    feats = _obs_features(cfg, tables, state)
    assert set(feats) == set(OBS_FEATURES), (
        sorted(feats), sorted(OBS_FEATURES))
    cols = [feats[k][:, None] if feats[k].ndim == 1 else feats[k]
            for k in OBS_FEATURES]
    return jnp.concatenate(cols, axis=-1)


def action_costs(cfg: EnvConfig, tables: ProfileTables, state, actions):
    """Per-UAV (acc_score, lat_score, energy_score, t_total, e_infer,
    stab_score) for actions (n, 2) = (version j, cut index l).

    stab_score is the beyond-paper stability term (pricing.py): it reads
    the task feature as offered load in [0, 1] of cfg.peak_rps and
    scores whether this action's per-request device+link service time
    can absorb it. It only enters the reward when RewardWeights.w_stab
    > 0; with cfg.peak_rps == 0 the utilization is 0 and the score is a
    constant sigmoid(p_stab) ~ 1 for every action — rankings and
    advantages are unchanged, but set peak_rps when weighting it.

    Thin wrapper over the single cost core: all Eq. 1-5/9-11 math lives
    in ``pricing.price_actions`` (shared with the fleet simulator's
    numpy backend); ``action_breakdown`` exposes the full breakdown."""
    br = action_breakdown(cfg, tables, state, actions)
    return (br.acc_score, br.lat_score, br.energy_score, br.t_total,
            br.energy_j, br.stab_score)


def action_breakdown(cfg: EnvConfig, tables: ProfileTables, state,
                     actions) -> pricing.PricingBreakdown:
    """Full per-UAV PricingBreakdown for actions (n, 2) under ``state``."""
    return pricing.price_actions(cfg, tables,
                                 pricing.view_from_state(state), actions)


def env_step(cfg: EnvConfig, tables: ProfileTables, state, actions, rng,
             arrivals=None, next_task=None):
    """One delta-slot. Returns (new_state, reward, info).

    ``arrivals`` injects this slot's server-side job arrivals (scalar,
    jit-traceable) from an external workload trace (repro.sim.traces);
    None keeps the homogeneous Poisson(queue_arrival_rate) draw. This is
    the hook that lets training/evaluation rollouts see bursty (MMPP),
    diurnal, or replayed traffic instead of a constant-rate stream:
    pre-sample the trace and pass ``arrivals=trace_t`` per step (scan
    over the trace array alongside the keys).

    ``next_task`` similarly injects the next slot's per-device task/load
    feature ((n,) in [0, 1], e.g. trace counts / (slot * peak_rps))
    replacing the Bernoulli(task_prob) draw — how a2c.train teaches the
    agent what bursty offered load looks like."""
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    acc_s, lat_s, en_s, t_total, e_infer, stab_s = action_costs(
        cfg, tables, state, actions)

    alive = (state["battery_j"] > 0).astype(jnp.float32)
    active = alive * jnp.sign(state["task"])
    r = rw.reward(cfg.weights, acc_s, lat_s, en_s, stab_s, mask=active)

    # energy drain: kinetics (always, while alive) + inference scaled by
    # the task/load level (identical to the paper's gate for {0,1} task)
    kin_p = en.kinetic_power(cfg.power, state["activity"][:, 0],
                             state["activity"][:, 1], state["activity"][:, 2])
    e_kin = kin_p * cfg.slot_seconds
    drain = alive * (e_kin + state["task"] * e_infer * cfg.frames_per_slot)
    battery = jnp.maximum(state["battery_j"] - drain, 0.0)

    # dynamics: bandwidth random walk, queue M/M/1-ish, task Bernoulli
    lpar = cfg.latency
    bw = jnp.clip(state["bandwidth"]
                  * jnp.exp(jax.random.normal(k1, state["bandwidth"].shape)
                            * 0.15),
                  lpar.bw_min_bps, lpar.bw_max_bps)
    if cfg.cluster is None:
        if arrivals is None:
            arrivals = jax.random.poisson(k2, cfg.queue_arrival_rate)
        arrivals = jnp.asarray(arrivals).astype(jnp.float32)
        queue = jnp.maximum(state["queue"] + arrivals
                            - cfg.queue_service_per_slot, 0.0)
    else:
        # per-server background dynamics at the nominal operating point
        # (initial replicas / top DVFS): traces inject a *total* arrival
        # count, split across servers by bg_arrival_scale
        c = cfg.cluster
        bg_a = jnp.asarray(c.bg_arrival_scale)
        if arrivals is None:
            arrivals = jax.random.poisson(k2, cfg.queue_arrival_rate * bg_a)
        else:
            arrivals = jnp.asarray(arrivals) * bg_a
        arrivals = jnp.asarray(arrivals).astype(jnp.float32)
        speed = jnp.asarray([r * d[-1]
                             for r, d in zip(c.replicas, c.dvfs)])
        drain = cfg.queue_service_per_slot \
            * jnp.asarray(c.bg_service_scale) * speed
        queue = jnp.maximum(state["queue"] + arrivals - drain, 0.0)
    if next_task is None:
        task = jax.random.bernoulli(k3, cfg.task_prob,
                                    state["task"].shape).astype(jnp.float32)
    else:
        task = jnp.clip(jnp.asarray(next_task, jnp.float32), 0.0, 1.0)
    ptx = jnp.clip(state["p_tx"]
                   + jax.random.normal(k4, state["p_tx"].shape) * 0.05,
                   cfg.power.p_tx_min, cfg.power.p_tx_max)
    act = jnp.clip(state["activity"]
                   + jax.random.normal(k5, state["activity"].shape)
                   * cfg.activity_jitter, 0.0, 1.0)
    act = act / jnp.maximum(jnp.sum(act, -1, keepdims=True), 1.0)

    new_state = dict(state, battery_j=battery, bandwidth=bw, queue=queue,
                     task=task, p_tx=ptx, activity=act, t=state["t"] + 1)
    done = jnp.all(battery <= 0.0)
    info = {"t_total": t_total, "e_infer": e_infer, "acc_s": acc_s,
            "lat_s": lat_s, "en_s": en_s, "stab_s": stab_s, "alive": alive,
            "done": done, "battery": battery}
    return new_state, r, info

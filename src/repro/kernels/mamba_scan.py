"""Mamba-1 selective-scan as a Pallas TPU kernel.

TPU adaptation of the paper's "hardware-aware scan": the GPU version keeps
state in SRAM/registers per thread-block; here the (bd, N) state tile lives
in VMEM scratch and persists across the sequential chunk grid dimension,
while (batch, channel-block) grid dims are parallel. The discretized
(S, d_inner, N) tensor is never materialized in HBM — only per-chunk tiles
stream through VMEM.

Layout: u, dt: (B, S, DI); Bm, Cm: (B, S, N); A: (DI, N).
grid = (B, DI/bd, S/bc); innermost chunk dim is sequential and carries h.
Oracle: models/ssm.py ssm_scan_chunked (minus the D-skip, composed in ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref,
                  h_scr, *, bc: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)            # (bd, N)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)   # (bd,)
        u_t = u_ref[0, t].astype(jnp.float32)     # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)     # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)     # (N,)
        dA = jnp.exp(dt_t[:, None] * a)           # (bd, N)
        h = dA * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=-1)  # (bd,)
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bc, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ic == nc - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bc", "interpret"))
def mamba_scan(u, dt, Bm, Cm, A, *, bd: int = 128, bc: int = 128,
               interpret: bool = True):
    """Selective scan. u, dt: (B,S,DI); Bm, Cm: (B,S,N); A: (DI,N).

    Returns (y (B,S,DI), h_final (B,DI,N)). No D-skip/gating (see ops.py).
    """
    B, S, DI = u.shape
    N = Bm.shape[-1]
    bd = min(bd, DI)
    bc = min(bc, S)
    assert DI % bd == 0, (DI, bd)
    assert S % bc == 0, (S, bc)
    nd, nc = DI // bd, S // bc

    kernel = functools.partial(_mamba_kernel, bc=bc, nc=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda b, d, c: (b, c, d)),   # u
            pl.BlockSpec((1, bc, bd), lambda b, d, c: (b, c, d)),   # dt
            pl.BlockSpec((1, bc, N), lambda b, d, c: (b, c, 0)),    # Bm
            pl.BlockSpec((1, bc, N), lambda b, d, c: (b, c, 0)),    # Cm
            pl.BlockSpec((bd, N), lambda b, d, c: (d, 0)),          # A
        ],
        out_specs=[
            pl.BlockSpec((1, bc, bd), lambda b, d, c: (b, c, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, c: (b, d, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, DI), u.dtype),
            jax.ShapeDtypeStruct((B, DI, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, Bm, Cm, A)
    return y, h

"""Beyond-paper integration: EdgeRL profiles from *measured* dry-run
artifacts.

The paper profiles its CNNs by running them on the testbed. Our TPU
analogue of "running on the testbed" is the dry-run: per (arch, shape)
we have scan-aware compiled FLOPs, fused HBM bytes and collective bytes
(results/dryrun.jsonl). ``dryrun_profiles`` converts those records into
EdgeRL ``ModelProfile``s — per-layer FLOPs scaled so the arch total
matches the MEASURED compiled FLOPs (not the analytic estimate), i.e.
the controller optimizes against what the compiler actually emitted,
including remat/dispatch overheads the analytic model misses.

    cfg, tables = make_dryrun_tpu_env(["qwen2-0.5b", ...],
                                      results="results/dryrun.jsonl")
"""
from __future__ import annotations

import json
from typing import Dict, Sequence, Tuple

from repro.configs import SHAPES, get_config
from repro.core.controller import _TPU_LATENCY, _TPU_POWER
from repro.core.env import EnvConfig, ProfileTables, build_tables
from repro.core.profiles import LayerProfile, ModelProfile, VersionProfile
from repro.core.reward import RewardWeights


def _load_records(path: str) -> Dict[Tuple[str, str], dict]:
    out = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if (r.get("status") == "ok" and r.get("mesh") == "single"
                    and r.get("variant", "baseline") == "baseline"):
                out[(r["arch"], r["shape"])] = r
    return out


def dryrun_profile(arch: str, records, *, shape: str = "prefill_32k",
                   n_cuts: int = 4) -> ModelProfile:
    """ModelProfile whose total FLOPs equal the measured compiled FLOPs."""
    from repro.core.transformer_cost import block_flops_per_token

    cfg = get_config(arch)
    rec = records.get((arch, shape))
    info = SHAPES[shape]
    tokens = info["global_batch"] * info["seq_len"]

    versions = []
    for vname in cfg.versions:
        vcfg = cfg if vname == "base" else cfg.with_overrides(
            sliding_window=8192)
        analytic = block_flops_per_token(vcfg, seq_ctx=info["seq_len"])
        total_analytic = sum(analytic)
        if rec and vname == "base":
            # calibrate to the measured compiled FLOPs per token
            measured_per_tok = rec["jaxpr_flops"] / tokens
            scale = measured_per_tok / max(total_analytic, 1.0)
        else:
            scale = 1.0
        per_tok_bytes = cfg.d_model * 2 * info["seq_len"]
        layers = tuple(
            LayerProfile(f"block{i}", f * scale * info["seq_len"],
                         per_tok_bytes, 0)
            for i, f in enumerate(analytic))
        L = len(layers)
        cuts = tuple(max(1, round(L * (i + 1) / (n_cuts + 1)))
                     for i in range(n_cuts))
        acc = 0.75 if vname == "base" else 0.71
        versions.append(VersionProfile(arch, vname, acc, layers, cuts))
    return ModelProfile(arch, tuple(versions))


def make_dryrun_tpu_env(arch_names: Sequence[str],
                        results: str = "results/dryrun.jsonl",
                        weights: RewardWeights = RewardWeights(),
                        **env_kw) -> Tuple[EnvConfig, ProfileTables]:
    records = _load_records(results)
    profs = [dryrun_profile(a, records) for a in arch_names]
    tables = build_tables(profs)
    cfg = EnvConfig(n_uavs=len(arch_names), latency=_TPU_LATENCY,
                    power=_TPU_POWER, weights=weights.normalized(),
                    frames_per_slot=1000.0, **env_kw)
    return cfg, tables

"""Pallas TPU kernels for the compute hot spots, each with a jit'd wrapper
(ops.py) and a pure-jnp oracle (ref.py):

  flash_attention — online-softmax attention, GQA + causal + sliding window
  flash_decode    — single-token ring-cache decode attention (positional mask)
  mamba_scan      — Mamba-1 selective scan, VMEM-resident state tiles
  rglru_scan      — RG-LRU diagonal linear recurrence
  quant_matmul    — int8 x int8 -> int32 matmul with f32 rescale (repro.quant)

Set REPRO_USE_PALLAS=interpret (CPU validation) or =tpu (hardware) to route
the models through the kernels; unset -> pure-jnp reference path.
"""
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.quant_matmul import quant_matmul, quant_matmul_ref
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels import ops, ref

__all__ = ["flash_attention", "flash_decode", "mamba_scan", "rglru_scan",
           "quant_matmul", "quant_matmul_ref", "ops", "ref"]

"""EdgeRL controller: the centralized decision-maker (paper Sec. II-D).

Wires profiles -> env -> A2C and exposes:
  - ``make_paper_env``: the faithful testbed (VGG/ResNet/DenseNet on
    Jetson-TX2-class UAVs + PowerEdge-class edge server).
  - ``make_tpu_env``: the TPU adaptation (assigned transformer archs;
    device/server = head/tail submesh with roofline-derived throughputs,
    ICI link as the uplink) — see DESIGN.md §2.
  - ``train_agent`` / ``evaluate_policy`` / ``decide``.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import a2c as A2C
from repro.core.env import (EnvConfig, ProfileTables, build_tables,
                            env_reset, env_step, observe)
from repro.core.latency import LatencyParams
from repro.core.energy import DevicePower
from repro.core.profiles import paper_profiles, transformer_profile
from repro.core.reward import RewardWeights


def make_paper_env(weights: RewardWeights = RewardWeights(),
                   n_uavs: int = 3,
                   **env_kw) -> Tuple[EnvConfig, ProfileTables]:
    """The paper's testbed (3 UAVs); ``n_uavs`` scales the fleet — model
    assignment cycles through {vgg, resnet, densenet} like env_reset."""
    profs = paper_profiles()
    tables = build_tables([profs["vgg"], profs["resnet"], profs["densenet"]])
    cfg = EnvConfig(n_uavs=n_uavs, weights=weights.normalized(), **env_kw)
    return cfg, tables


# TPU v5e submesh regime: "device" = small head submesh (8 chips),
# "server" = shared tail submesh (64 chips, queued), link = ICI.
_TPU_LATENCY = LatencyParams(
    device_flops=8 * 197e12 * 0.4,      # 8 chips at 40% MFU
    server_flops=64 * 197e12 * 0.4,
    job_service_s=0.01,
    bw_min_bps=8 * 50e9 * 8 * 0.25,     # congested ICI share
    bw_max_bps=8 * 50e9 * 8,            # 8 links x 50 GB/s
)
_TPU_POWER = DevicePower(
    p_forward=0.0, p_vertical=0.0, p_rotate=0.0, p_hover=0.0,   # no kinetics
    p_compute=8 * 200.0,                # ~200 W per v5e chip
    p_tx_min=5.0, p_tx_max=20.0,        # ICI/DCN interface power proxy
    battery_wh=1e9,                     # pods don't run on batteries
)


def make_tpu_env(arch_names: Sequence[str],
                 weights: RewardWeights = RewardWeights(),
                 seq_len: int = 2048,
                 reduced: bool = False,
                 **env_kw) -> Tuple[EnvConfig, ProfileTables]:
    """TPU-adapted env whose version axis is the repro.quant registry
    (bf16 / w8 / w4 — see DESIGN.md §3). ``reduced=True`` profiles the
    smoke-test variant of each arch so table indices line up with an
    executable SplitServingEngine model (used by tests/examples that run
    the controller's decisions end-to-end)."""
    from repro.configs import get_config

    cfgs = [get_config(a) for a in arch_names]
    if reduced:
        cfgs = [c.reduced() for c in cfgs]
    profs = [transformer_profile(c, seq_len=seq_len) for c in cfgs]
    tables = build_tables(profs)
    # weight shipping: a (version, cut) switch stages the tail weights on
    # the server submesh; amortize over ~1/3 episode of request slots.
    env_kw.setdefault("weight_ship_slots", 32.0)
    cfg = EnvConfig(n_uavs=len(arch_names), latency=_TPU_LATENCY,
                    power=_TPU_POWER, weights=weights.normalized(),
                    frames_per_slot=1000.0,   # request batches per slot
                    **env_kw)
    return cfg, tables


def resolve_selection(model_cfg, profile, j: int, k: int):
    """Map a table action (version j, cut index k) to something the
    SplitServingEngine can execute: (quant version name, partition cut).

    ``profile`` must be the ModelProfile the tables were built from (same
    cfg), so the cut index addresses the same candidate list. Indices
    beyond this model's version/cut count clamp to the last entry — the
    same padding rule build_tables applies when mixing models of
    different sizes, so the executed action is the one the tables
    scored."""
    from repro.core import partition

    v = profile.versions[min(j, len(profile.versions) - 1)]
    layer = v.cut_points[min(k, len(v.cut_points) - 1)]
    return v.version, partition.cut_for_layer(model_cfg, layer)


def make_task_sampler(cfg: EnvConfig, trace, seed: int):
    """Adapt a workload trace (repro.sim.traces.Trace) into the
    ``task_sampler(episode) -> (episode_len, n_uavs)`` hook the batched
    trainers consume: per-slot offered load counts / (slot * peak_rps),
    the same normalization the fleet simulator feeds ``measured_state``,
    so the agent learns what bursts look like before it meets them
    online. Shared by the A2C and PPO training paths; requires
    cfg.peak_rps > 0 to normalize counts into the load feature."""
    if trace is None:
        return None
    if cfg.peak_rps <= 0:
        raise ValueError("trace-driven training needs cfg.peak_rps > 0 "
                         "to normalize counts into the load feature")

    def task_sampler(episode):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, episode]))
        gen = trace.stream(rng, cfg.n_uavs, cfg.slot_seconds)
        rows = [next(gen) for _ in range(cfg.episode_len)]
        return np.clip(np.asarray(rows, dtype=np.float32)
                       / (cfg.slot_seconds * cfg.peak_rps), 0.0, 1.0)

    return task_sampler


def train_agent(cfg: EnvConfig, tables: ProfileTables,
                ac: A2C.A2CConfig = A2C.A2CConfig(), seed: int = 0,
                log_every: int = 0, trace=None):
    """Train the A2C controller. ``ac.batch_envs = E`` rolls E vmapped
    env instances per update inside one jit (each with its own reset
    draw and, under a trace, its own sampled load sequence) — the same
    wall-clock per update buys E× the episodes and scenario diversity.
    ``trace`` (a repro.sim.traces.Trace) switches the episode's task
    feature from the Bernoulli draw to trace-driven offered load — see
    ``make_task_sampler``. For battery-drain parity with the per-request
    fleet metering, set cfg.frames_per_slot = slot_seconds * peak_rps
    (one frame per request at saturation)."""
    return A2C.train(cfg, tables, ac, jax.random.key(seed),
                     log_every=log_every,
                     task_sampler=make_task_sampler(cfg, trace, seed))


def decide(params, cfg: EnvConfig, tables: ProfileTables, state):
    """Greedy execution-profile decision for the current state."""
    obs = observe(cfg, tables, state).reshape(-1)
    valid = tables.version_valid[state["model_id"]]
    return A2C.greedy_actions(params, obs, valid)


def measured_state(cfg: EnvConfig, tables: ProfileTables, *,
                   battery_j, bandwidth, p_tx, queue_jobs, load,
                   model_id=None, activity=None, t: int = 0) -> Dict:
    """Assemble the env-state dict ``observe``/``decide`` consume from
    quantities a fleet actually measures online: remaining battery (J),
    link bandwidth (bps), transmit power (W), server queue depth (jobs)
    and per-device offered load in [0, 1] (observed arrival rate over a
    nominal capacity — Eq. 6's task-availability alpha generalized to a
    measured utilization). This is how the trace-driven simulator
    (repro.sim.fleet) runs the trained controller online each decision
    epoch: no env rollout, just measurements in, (version, cut) out."""
    battery_j = jnp.asarray(battery_j, jnp.float32)
    n = battery_j.shape[0]
    if model_id is None:
        model_id = jnp.arange(n, dtype=jnp.int32) % tables.n_models
    if activity is None:
        activity = jnp.tile(jnp.asarray(cfg.activity, jnp.float32)[None],
                            (n, 1))
    return {
        "battery_j": battery_j,
        "task": jnp.clip(jnp.asarray(load, jnp.float32), 0.0, 1.0),
        "p_tx": jnp.asarray(p_tx, jnp.float32),
        "model_id": jnp.asarray(model_id, jnp.int32),
        "activity": jnp.asarray(activity, jnp.float32),
        "bandwidth": jnp.asarray(bandwidth, jnp.float32),
        # cluster mode measures one depth per server ((S,)); the classic
        # scalar path is kept exactly as-is for bit-stable decides
        "queue": (jnp.asarray(queue_jobs, jnp.float32)
                  if np.ndim(queue_jobs) else jnp.float32(queue_jobs)),
        "t": jnp.int32(t),
    }


def evaluate_policy(cfg: EnvConfig, tables: ProfileTables,
                    policy, rng, episodes: int = 5) -> Dict:
    """Roll a policy (a ``repro.policies.Policy`` — anything exposing
    ``act(state, rng) -> (n, 2) int32`` built against this env); aggregate
    the paper's reported metrics + the (version, cut) selection histogram
    (Table II reproduction).

    Each episode is one jitted lax.scan over the slots — no host
    round-trip per slot — with the selection histogram built by a
    scatter-add over the (model, version, cut) indices. The per-episode
    rng threading (split per episode, split per slot, policy/env
    fold-ins) matches the historical per-slot Python loop, so fixed-seed
    results are unchanged up to float summation order."""
    if policy.env_cfg is not cfg or policy.tables is not tables:
        raise ValueError(
            f"policy {policy.name!r} was built against a different "
            "(env_cfg, tables) world than the one being evaluated; "
            "build it from the same objects")
    M, V, K = tables.n_models, tables.n_versions, tables.n_cuts

    @jax.jit
    def one_episode(rng):
        rng, k0 = jax.random.split(rng)
        state0 = env_reset(cfg, tables, k0)

        def step(carry, _):
            state, rng = carry
            rng, k = jax.random.split(rng)
            actions = policy.act(state, jax.random.fold_in(k, 7))
            state2, r, info = env_step(cfg, tables, state, actions,
                                       jax.random.fold_in(k, 13))
            out = {
                "actions": actions, "model_id": state["model_id"],
                "alive": info["alive"], "reward": r,
                "latency": jnp.mean(info["t_total"]),
                "energy": jnp.mean(info["e_infer"]),
                "acc_score": jnp.mean(info["acc_s"]),
                "lat_score": jnp.mean(info["lat_s"]),
                "en_score": jnp.mean(info["en_s"]),
                "alive_slots": jnp.sum(info["alive"]),
            }
            return (state2, rng), out

        (_, rng), tr = jax.lax.scan(step, (state0, rng), None,
                                    length=cfg.episode_len)
        m = tr.pop("model_id").reshape(-1)
        a = tr.pop("actions").reshape(-1, cfg.action_dim)
        alive = tr.pop("alive").reshape(-1)
        hist = jnp.zeros((M, V, K)).at[m, a[:, 0], a[:, 1]].add(alive)
        return rng, hist, {k: jnp.sum(v) for k, v in tr.items()}

    hist = np.zeros((M, V, K))
    agg = {k: 0.0 for k in ("reward", "latency", "energy", "acc_score",
                            "lat_score", "en_score", "alive_slots")}
    for ep in range(episodes):
        rng, ep_hist, sums = one_episode(rng)
        hist += np.asarray(ep_hist)
        for k in agg:
            agg[k] += float(sums[k])
    steps = episodes * cfg.episode_len
    out = {k: v / steps for k, v in agg.items()}
    out["selection_hist"] = hist
    # modal (version, cut index) per model — Table II analogue
    modal = {}
    for mi, name in enumerate(tables.names):
        if hist[mi].sum() > 0:
            j, c = np.unravel_index(np.argmax(hist[mi]), hist[mi].shape)
            modal[name] = (int(j), int(c))
    out["modal_selection"] = modal
    return out

"""qwen3-0.6b [dense] — qk_norm, GQA (kv=8). [hf:Qwen/Qwen3-8B family card]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    source="hf:Qwen/Qwen3-0.6B (Qwen3 family)",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    mlp_act="swiglu",
    tie_embeddings=True,
))

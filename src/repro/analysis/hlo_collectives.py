"""Parse collective ops (+ bytes) out of lowered/compiled HLO text.

cost_analysis does not expose collective bytes, and collectives sit inside
while-loop bodies for scanned layers — so we (1) regex every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction with its result shape, (2) recover each while loop's trip count
from its condition computation (compare against a constant), and (3) multiply
body collectives by trip count.

Bytes convention (documented in EXPERIMENTS.md): per-op moved bytes =
result-buffer bytes (all-gather / all-to-all / permute) or operand bytes
(all-reduce: counted twice for the reduce+broadcast phases, reduce-scatter:
operand bytes), divided later by chip count for the per-link roofline term.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if ("{" in line and "->" in line
                and (line.startswith("%") or line.startswith("ENTRY")
                     or not line.startswith(" "))):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                current = m.group(1)
                comps[current] = []
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def _while_info(comps: Dict[str, List[str]]) -> List[Tuple[str, str, int]]:
    """List of (body_comp, cond_comp, trip_count or 1)."""
    out = []
    for name, lines in comps.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            cond = re.search(r"condition=%?([\w\.\-]+)", ln)
            body = re.search(r"body=%?([\w\.\-]+)", ln)
            if not (cond and body):
                continue
            trip = _trip_count(comps.get(cond.group(1), []))
            out.append((body.group(1), cond.group(1), trip))
    return out


def _trip_count(cond_lines: List[str]) -> int:
    # look for compare(..., constant) with the bound; constants look like
    #   %constant.5 = s32[] constant(26)
    consts = {}
    for ln in cond_lines:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\-?\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" not in ln:
            continue
        args = re.search(r"compare\(([^)]*)\)", ln)
        if not args:
            continue
        for a in args.group(1).split(","):
            a = a.strip().lstrip("%")
            a = a.split(" ")[-1].lstrip("%")
            if a in consts and consts[a] > 0:
                return consts[a]
    if len(consts) == 1:
        v = next(iter(consts.values()))
        if v > 0:
            return v
    return 1


def _op_bytes(kind: str, line: str) -> int:
    head = line.split("=", 1)
    if len(head) < 2:
        return 0
    rhs = head[1]
    result = rhs.split(kind)[0]
    b = _shape_bytes(result)
    if kind == "all-reduce":
        return 2 * b
    return b


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Total collective bytes (loop-trip-count aware) per collective kind."""
    comps = _split_computations(hlo)
    whiles = _while_info(comps)
    mult: Dict[str, int] = defaultdict(lambda: 1)
    # nested whiles: propagate multipliers breadth-first (bodies may contain
    # further whiles; iterate to fixpoint over a few rounds)
    for _ in range(4):
        for body, cond, trip in whiles:
            parent = 1
            for name, lines in comps.items():
                for ln in lines:
                    if f"body=%{body}" in ln or f"body={body}" in ln:
                        parent = mult[name]
                        break
            mult[body] = parent * trip

    totals: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for name, lines in comps.items():
        m = mult[name]
        for ln in lines:
            for kind in COLLECTIVES:
                if f" {kind}(" in ln or f"= {kind}" in ln or f"{kind}(" in ln.split("=")[-1][:40]:
                    totals[kind] += m * _op_bytes(kind, ln)
                    counts[f"n_{kind}"] += m
                    break
    out = dict(totals)
    out.update(counts)
    out["total_bytes"] = float(sum(totals.values()))
    return out

"""Benchmark harness — one function per paper table/figure (+ system
benches), declared as a ``repro.bench`` case matrix. Prints
``name,us_per_call,derived`` CSV rows and writes the same rows as
machine-readable JSON (``--json``, default BENCH_results.json) with
repeated samples, bootstrap CI bounds, per-case obs phase breakdowns,
the git sha and an environment fingerprint — the record
``scripts/benchgate.py`` gates against ``BENCH_history.jsonl``
(DESIGN.md §10).

Paper artifacts:
  table1_profiles       — Table I: candidate cut points + activation bytes
  fig2_accuracy_sweep   — Fig. 2: performance vs accuracy weight w1
  fig3_latency_sweep    — Fig. 3: performance vs latency weight w2
  fig4_energy_sweep     — Fig. 4: performance vs energy weight w3
  table2_cut_selection  — Table II: (version, cut) selection at weight extremes
  a2c_convergence       — Sec. III-B: A2C learning curve vs greedy oracle
  baseline_policies     — device-only / full-offload / random / oracle

The sweeps use the per-step greedy oracle as the converged-policy proxy
(fast, deterministic); ``a2c_convergence`` demonstrates the A2C agent
approaching it. Pass --agent to run the sweeps with freshly trained agents
instead (slower; matches the paper's methodology exactly).

System benches:
  roofline_suite        — dominant roofline terms from results/dryrun.jsonl
  serving_decode        — us/token through the serving engine (reduced model)
  split_inference       — EdgeRL split execution vs monolithic forward
  megafleet_scaling     — vectorized fleet engine devices/sec scaling
                          curve (n_uavs 256 / 4k / 32k / 100k)
  megafleet_speedup     — loop-vs-vectorized per-epoch cost ratio at 32k
                          devices (gated) + speedup and scaling exponent
  scenario_sweep        — every registered scenario preset via run_scenario
  train_throughput      — A2C episodes/s, batched (vmap) vs looped
  pricing_numpy_throughput — numpy pricing-core actions/s (fleet hot path)
  online_adaptation     — repro.online incremental-update steps/s +
                          link-brownout drift recovery time
  timeline_overhead     — flight-recorder capture cost: fleet_sim wall
                          with FleetConfig.timeline on vs off (gated)
  kernels_interpret     — Pallas flash-attention kernel (interpret mode)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.bench import Matrix, Timing, history, runner, timeit

# rows flow through the active repro.bench.runner sink (CSV echo + the
# structured record benchgate consumes); Timing/_timeit live in
# repro.bench.stats now — same semantics, 5 samples by default
row = runner.emit
_timeit = timeit


# --------------------------------------------------------------------------
# paper benches
# --------------------------------------------------------------------------

def table1_profiles():
    """Table I rows, each timing its *own* model-version profile build
    (the historical harness timed one shared paper_profiles() call, so
    every row reported the identical us_per_call)."""
    from repro.core.profiles import PAPER_VERSIONS, paper_version_profile
    for model, version in PAPER_VERSIONS:
        t0 = time.perf_counter()
        v = paper_version_profile(model, version)
        us = (time.perf_counter() - t0) * 1e6
        cuts = ";".join(str(c) for c in v.cut_points)
        mb = ";".join(f"{v.cut_bytes(c)/1e6:.2f}" for c in v.cut_points)
        row(f"table1_{v.model}{v.version}", us,
            f"cuts={cuts} act_MB={mb} GF={v.total_flops/1e9:.1f} "
            f"acc={v.accuracy:.3f}")


def _sweep(weight_name: str, fig: str, use_agent: bool, episodes: int):
    from repro.core import RewardWeights, evaluate_policy, make_paper_env
    from repro.policies import build_policy
    for wv in (0.0, 0.25, 0.5, 0.75, 1.0):
        rest = (1.0 - wv) / 2
        kw = {"w_acc": rest, "w_lat": rest, "w_energy": rest}
        kw[weight_name] = wv
        cfg, tables = make_paper_env(weights=RewardWeights(**kw))
        t0 = time.perf_counter()
        if use_agent:
            pol = build_policy("a2c", cfg, tables, episodes=episodes)
            pol.train()
        else:
            pol = build_policy("greedy_oracle", cfg, tables)
        m = evaluate_policy(cfg, tables, pol, jax.random.key(0), episodes=2)
        us = (time.perf_counter() - t0) * 1e6
        modal = ";".join(f"{k}:v{v[0]}c{v[1]}"
                         for k, v in m["modal_selection"].items())
        row(f"{fig}_{weight_name}={wv}", us,
            f"reward={m['reward']:.3f} lat_ms={m['latency']*1e3:.1f} "
            f"E_J={m['energy']:.3f} accS={m['acc_score']:.3f} "
            f"alive={m['alive_slots']:.1f} {modal}")


def fig2_accuracy_sweep(use_agent=False, episodes=200):
    _sweep("w_acc", "fig2", use_agent, episodes)


def fig3_latency_sweep(use_agent=False, episodes=200):
    _sweep("w_lat", "fig3", use_agent, episodes)


def fig4_energy_sweep(use_agent=False, episodes=200):
    _sweep("w_energy", "fig4", use_agent, episodes)


def table2_cut_selection(use_agent=False, episodes=200):
    """Weight extremes; paper Table II qualitative claims: w_lat=1 pushes
    cuts LATER than w_lat=0 (transmission postpones offload); w_energy=1
    pulls cuts EARLY again."""
    from repro.core import RewardWeights, evaluate_policy, make_paper_env
    from repro.policies import build_policy
    results = {}
    for tag, kw in (("w2_0", dict(w_acc=0.5, w_lat=0.0, w_energy=0.5)),
                    ("w2_1", dict(w_acc=0.0, w_lat=1.0, w_energy=0.0)),
                    ("w3_0", dict(w_acc=0.5, w_lat=0.5, w_energy=0.0)),
                    ("w3_1", dict(w_acc=0.0, w_lat=0.0, w_energy=1.0))):
        cfg, tables = make_paper_env(weights=RewardWeights(**kw))
        t0 = time.perf_counter()
        if use_agent:
            pol = build_policy("a2c", cfg, tables, episodes=episodes)
            pol.train()
        else:
            pol = build_policy("greedy_oracle", cfg, tables)
        m = evaluate_policy(cfg, tables, pol, jax.random.key(0), episodes=2)
        us = (time.perf_counter() - t0) * 1e6
        results[tag] = m["modal_selection"]
        modal = ";".join(f"{k}:v{v[0]}c{v[1]}"
                         for k, v in m["modal_selection"].items())
        row(f"table2_{tag}", us, modal)
    later = sum(results["w2_1"][k][1] >= results["w2_0"][k][1]
                for k in results["w2_0"])
    earlier = sum(results["w3_1"][k][1] <= results["w3_0"][k][1]
                  for k in results["w3_0"])
    row("table2_pattern_check", 0.0,
        f"w_lat1_cut_later_or_eq={later}/3 "
        f"w_energy1_cut_earlier_or_eq={earlier}/3")


def a2c_convergence(episodes=250):
    from repro.core import evaluate_policy, make_paper_env
    from repro.policies import build_policy
    cfg, tables = make_paper_env()
    t0 = time.perf_counter()
    pol = build_policy("a2c", cfg, tables, episodes=episodes)
    hist = pol.train()
    us = (time.perf_counter() - t0) * 1e6 / episodes
    first = np.mean([h["mean_reward"] for h in hist[:20]])
    last = np.mean([h["mean_reward"] for h in hist[-20:]])
    oracle = evaluate_policy(cfg, tables,
                             build_policy("greedy_oracle", cfg, tables),
                             jax.random.key(0), episodes=2)["reward"]
    agent = evaluate_policy(cfg, tables, pol,
                            jax.random.key(0), episodes=2)["reward"]
    row("a2c_convergence", us,
        f"first20={first:.3f} last20={last:.3f} agent_eval={agent:.3f} "
        f"oracle={oracle:.3f} episodes={episodes}")


def baseline_policies():
    from repro.core import evaluate_policy, make_paper_env
    from repro.policies import build_policy, get_policy_spec, policy_names
    cfg, tables = make_paper_env()
    for name in policy_names():
        if get_policy_spec(name).trainable:
            continue
        t0 = time.perf_counter()
        m = evaluate_policy(cfg, tables, build_policy(name, cfg, tables),
                            jax.random.key(0), episodes=2)
        us = (time.perf_counter() - t0) * 1e6
        row(f"baseline_{name}", us,
            f"reward={m['reward']:.3f} lat_ms={m['latency']*1e3:.1f} "
            f"E_J={m['energy']:.3f}")


# --------------------------------------------------------------------------
# system benches
# --------------------------------------------------------------------------

def roofline_suite():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        row("roofline_suite", 0.0, "skipped=no_dryrun_results")
        return
    from repro.analysis.roofline import enrich, load
    recs = [enrich(r) for r in load(path)
            if r["mesh"] == "single" and r["status"] == "ok"
            and r.get("variant", "baseline") == "baseline"]
    for r in recs:
        row(f"roofline_{r['arch']}_{r['shape']}",
            r.get("compile_s", 0.0) * 1e6,
            f"compute_s={r['compute_s']:.4g} memory_s={r['memory_s']:.4g} "
            f"collective_s={r['collective_s']:.4g} dom={r['dominant']} "
            f"model_ratio={r['ratio']:.2f}")


def serving_decode():
    from repro.configs import get_config
    from repro.models import init
    from repro.serving import ServeConfig, ServingEngine
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=32))
    toks = (jnp.arange(4 * 64, dtype=jnp.int32).reshape(4, 64) * 3) \
        % cfg.vocab_size
    batch = {"tokens": toks}
    us = _timeit(lambda: eng.generate(batch), n=3)
    row("serving_decode", us / 32, "per_token,B=4,reduced_qwen2")


def split_inference():
    from repro.configs import get_config
    from repro.core.partition import cut_points
    from repro.models import forward_logits, init
    from repro.serving import SplitServingEngine
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    toks = (jnp.arange(2 * 64, dtype=jnp.int32).reshape(2, 64) * 3) \
        % cfg.vocab_size
    batch = {"tokens": toks}
    full_jit = jax.jit(lambda p, b: forward_logits(cfg, p, b))
    us_full = _timeit(lambda: full_jit(params, batch))
    eng = SplitServingEngine(cfg, params)
    cut = cut_points(cfg)[0]
    us_split = _timeit(lambda: eng.infer(batch, cut)[0])
    _, nbytes = eng.infer(batch, cut)
    row("split_inference", us_split,
        f"monolithic_us={us_full:.1f} overhead={us_split/max(us_full,1):.2f}x "
        f"act_bytes={nbytes}")


def hillclimb_variants():
    """§Perf variant deltas straight from the dry-run records."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        row("hillclimb_variants", 0.0, "skipped=no_dryrun_results")
        return
    from repro.analysis.roofline import enrich, load
    recs = load(path)
    rmap = {(r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline")):
            r for r in recs}
    pairs = [("deepseek-v2-lite-16b", "decode_32k",
              ["baseline", "mla_absorb"]),
             ("mixtral-8x22b", "train_4k",
              ["baseline", "moe_gather", "causal_skip", "noremat", "fsdp"]),
             ("llama-3.2-vision-90b", "prefill_32k",
              ["baseline", "hugechunk", "causal_skip"])]
    for arch, shape, variants in pairs:
        for v in variants:
            r = rmap.get((arch, shape, "single", v))
            if not r or r["status"] != "ok":
                continue
            e = enrich(r)
            row(f"perf_{arch}_{shape}_{v}", r.get("compile_s", 0) * 1e6,
                f"bound_s={e['bound_s']:.4g} compute_s={e['compute_s']:.4g} "
                f"memory_s={e['memory_s']:.4g} "
                f"collective_s={e['collective_s']:.4g} dom={e['dominant']}")


def ablation_a2c(episodes=80):
    """A2C hyper-parameter ablations (entropy bonus, discount)."""
    from repro.core import A2CConfig, make_paper_env, train_agent
    cfg, tables = make_paper_env()
    for tag, kw in (("ent0", dict(entropy_coef=0.0)),
                    ("ent0.01", dict(entropy_coef=0.01)),
                    ("ent0.05", dict(entropy_coef=0.05)),
                    ("gamma0.9", dict(gamma=0.9)),
                    ("gamma0.99", dict(gamma=0.99))):
        t0 = time.perf_counter()
        _, hist = train_agent(cfg, tables,
                              A2CConfig(episodes=episodes, **kw))
        us = (time.perf_counter() - t0) * 1e6 / episodes
        first = np.mean([h["mean_reward"] for h in hist[:15]])
        last = np.mean([h["mean_reward"] for h in hist[-15:]])
        row(f"ablation_a2c_{tag}", us,
            f"first15={first:.3f} last15={last:.3f} delta={last-first:+.3f}")


def ablation_agents(episodes=120):
    """Beyond-paper: the paper's A2C vs a PPO agent on the same env —
    empirical support for the paper's algorithm choice."""
    from repro.core import A2CConfig, make_paper_env
    from repro.core import a2c as A2C
    from repro.core import ppo as PPO
    cfg, tables = make_paper_env()
    t0 = time.perf_counter()
    _, h = A2C.train(cfg, tables, A2CConfig(episodes=episodes),
                     jax.random.key(0))
    us = (time.perf_counter() - t0) * 1e6 / episodes
    row("ablation_agents_a2c", us,
        f"first15={np.mean([x['mean_reward'] for x in h[:15]]):+.3f} "
        f"last15={np.mean([x['mean_reward'] for x in h[-15:]]):+.3f}")
    t0 = time.perf_counter()
    _, h = PPO.train(cfg, tables, PPO.PPOConfig(episodes=episodes),
                     jax.random.key(0))
    us = (time.perf_counter() - t0) * 1e6 / episodes
    row("ablation_agents_ppo", us,
        f"first15={np.mean([x['mean_reward'] for x in h[:15]]):+.3f} "
        f"last15={np.mean([x['mean_reward'] for x in h[-15:]]):+.3f}")


def continuous_batching():
    from repro.configs import get_config
    from repro.models import init
    from repro.serving.scheduler import ContinuousBatchingServer, Request
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    srv = ContinuousBatchingServer(cfg, params, max_batch=4, cache_len=64)
    r = np.random.default_rng(0)
    for i in range(10):
        srv.submit(Request(rid=i, tokens=r.integers(
            0, cfg.vocab_size, int(r.integers(4, 12))).astype(np.int32),
            max_new_tokens=6))
    t0 = time.perf_counter()
    done = srv.run()
    us = (time.perf_counter() - t0) * 1e6
    toks = sum(len(q.out) for q in done)
    row("continuous_batching", us / max(toks, 1),
        f"per_token,requests={len(done)} decode_steps={srv.stats.decode_steps} "
        f"prefills={srv.stats.prefills}")


def scheduler_throughput():
    """Continuous-batching tokens/s with mixed-length requests — the
    slot-refill path (individual retirement) is on the hot loop."""
    from repro.configs import get_config
    from repro.models import init
    from repro.serving.scheduler import ContinuousBatchingServer, Request
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    # one server across warm + timed runs: its jitted prefill/decode
    # closures (and their per-batch-size compile cache) live on the
    # instance, so a fresh server per run would re-compile in the timed
    # region; reseeding the rng repeats the exact request shapes
    srv = ContinuousBatchingServer(cfg, params, max_batch=4, cache_len=64)

    def one_run():
        r = np.random.default_rng(0)
        for i in range(12):
            srv.submit(Request(rid=i, tokens=r.integers(
                0, cfg.vocab_size, int(r.integers(4, 12))).astype(np.int32),
                max_new_tokens=int(r.integers(3, 12))))
        done = srv.run()
        return sum(len(q.out) for q in done)

    one_run()                       # warm the jits
    warm_reclaims = srv.stats.slot_reclaims
    warm_prefills = srv.stats.prefills
    samples, toks, dt = [], 0, 0.0
    for rep in range(3):            # repeated runs: the gate's noise model
        t0 = time.perf_counter()
        toks = one_run()
        dt = time.perf_counter() - t0
        samples.append(dt / max(toks, 1) * 1e6)
        if rep == 0:
            reclaims = srv.stats.slot_reclaims - warm_reclaims
            prefills = srv.stats.prefills - warm_prefills
    summ = srv.stats.latency_summary()
    row("scheduler_throughput", Timing(min(samples), samples),
        f"per_token,tok_per_s={toks/dt:.0f} "
        f"reclaims={reclaims} prefills={prefills} "
        f"p95_e2e_steps={summ['p95']:.0f}")


def train_throughput(loop_episodes=16, batch_envs=16):
    """Episodes/s of the A2C update path: looped single-env episodes vs
    one vmapped batch_envs update (same nets, same env). The batched
    path amortizes the per-episode scan/dispatch overhead AND the
    per-update host work (A2C.train extracts the stats history every
    update — the loop body here replicates train() exactly) across E
    parallel worlds inside one jit. Best-of-reps per path to shed
    scheduler noise on small hosts."""
    from repro.core import A2CConfig, init_agent, make_paper_env, \
        make_train_episode
    from repro.optim import adamw_init
    cfg, tables = make_paper_env()

    def eps_per_s(E, calls, reps=3):
        ac = A2CConfig(batch_envs=E)
        params = init_agent(cfg, tables, ac, jax.random.key(0))
        opt = adamw_init(params)
        step = make_train_episode(cfg, tables, ac)
        p, o, s = step(params, opt, jax.random.key(1))   # compile
        jax.block_until_ready(s["loss"])
        best = float("inf")
        for rep in range(reps):
            t0 = time.perf_counter()
            for i in range(calls):
                p, o, s = step(p, o, jax.random.key(2 + i))
                history = {k: float(v) for k, v in s.items()}  # as train()
            best = min(best, (time.perf_counter() - t0) / calls)
        assert history
        return E / best, best

    looped, us_loop = eps_per_s(1, loop_episodes)
    batched, us_batch = eps_per_s(batch_envs, 4)
    row("train_throughput", us_batch * 1e6,
        f"batched_eps_per_s={batched:.2f} looped_eps_per_s={looped:.2f} "
        f"speedup={batched/looped:.2f}x batch_envs={batch_envs} "
        f"looped_us_per_ep={us_loop*1e6:.0f}")


def pricing_numpy_throughput(n_devices=4096, iters=200, reps=5):
    """Actions/s through the numpy pricing path (the fleet simulator's
    per-epoch hot loop: one price_actions call per decision epoch).
    Timed in ``reps`` chunks so the gate has a noise model."""
    from repro.core import make_paper_env
    from repro.sim import AnalyticalBackend
    cfg, tables = make_paper_env()
    be = AnalyticalBackend(cfg, tables)
    r = np.random.default_rng(0)
    mids = r.integers(0, tables.n_models, n_devices).astype(np.int32)
    acts = np.stack([r.integers(0, tables.n_versions, n_devices),
                     r.integers(0, tables.n_cuts, n_devices)],
                    axis=-1).astype(np.int32)
    lp, pw = cfg.latency, cfg.power
    bw = r.uniform(lp.bw_min_bps, lp.bw_max_bps, n_devices)
    ptx = r.uniform(pw.p_tx_min, pw.p_tx_max, n_devices)
    pr = be.price(mids, acts, bw, ptx)                   # warm
    assert isinstance(pr.t_total, np.ndarray)
    chunk = max(iters // reps, 1)
    us = _timeit(lambda: be.price(mids, acts, bw, ptx),
                 n=chunk, reps=reps, warmup=1)
    row("pricing_numpy_throughput", us,
        f"per_call,devices={n_devices} "
        f"actions_per_s={n_devices/us*1e6:.0f}")


def fleet_sim(n_requests=100_000, n_uavs=8, reps=3):
    """repro.sim throughput: analytical-backend requests/s + epochs/s,
    parameterized over fleet size (the devices/sec scaling curve the
    mega-fleet roadmap item tracks)."""
    from repro.core import make_paper_env
    from repro.policies import build_policy
    from repro.sim import FleetConfig, PoissonTrace, simulate
    cfg, tables = make_paper_env(n_uavs=n_uavs, slot_seconds=10.0)
    trace = PoissonTrace(rate_rps=15.0)
    pol = build_policy("greedy_oracle", cfg, tables)
    kw = dict(n_requests=n_requests, seed=0, fleet=FleetConfig(slo_s=1.0))
    simulate(cfg, tables, pol, trace, **kw)  # warm
    samples, dts = [], []
    for _ in range(reps):   # same seed: identical epochs each repetition
        t0 = time.perf_counter()
        res = simulate(cfg, tables, pol, trace, **kw)
        dts.append(time.perf_counter() - t0)
        samples.append(dts[-1] / max(res.epochs, 1) * 1e6)
    dt = min(dts)
    s = res.summary
    name = "fleet_sim" if n_uavs == 8 else f"fleet_sim[n_uavs={n_uavs}]"
    row(name, Timing(min(samples), samples),
        f"per_epoch,req_per_s={res.served/dt:.0f} epochs_per_s="
        f"{res.epochs/dt:.1f} requests={res.served} "
        f"p95_s={s['p95']:.3f} slo_att={s['slo_attainment']:.3f}",
        devices=n_uavs,
        devices_per_s=n_uavs * res.epochs / dt)


def timeline_overhead(n_requests=100_000, n_uavs=8, reps=3):
    """Flight-recorder capture cost on the fleet_sim smoke world: wall
    ratio of ``FleetConfig.timeline`` on vs off, paired per rep (same
    seed → identical epochs). The gated value is the on/off ratio —
    capture-cost regressions show up as the increase; the acceptance
    bar is < 1.05 (under 5% added wall). The recorded trace's
    ``fleet.timeline`` span is the same cost seen as a phase."""
    from repro.core import make_paper_env
    from repro.policies import build_policy
    from repro.sim import FleetConfig, PoissonTrace, simulate
    cfg, tables = make_paper_env(n_uavs=n_uavs, slot_seconds=10.0)
    trace = PoissonTrace(rate_rps=15.0)
    pol = build_policy("greedy_oracle", cfg, tables)

    def one(timeline):
        kw = dict(n_requests=n_requests, seed=0,
                  fleet=FleetConfig(slo_s=1.0, timeline=timeline))
        t0 = time.perf_counter()
        res = simulate(cfg, tables, pol, trace, **kw)
        return time.perf_counter() - t0, res

    one(False), one(True)                      # warm (policy jit)
    ratios = []
    for _ in range(reps):
        off_s, _ = one(False)
        on_s, res = one(True)
        ratios.append(on_s / off_s)
    tl = res.timeline
    row("timeline_overhead", Timing(min(ratios), ratios),
        f"on_over_off_wall,overhead_pct={(min(ratios)-1)*100:.2f} "
        f"epochs={res.epochs} rows={len(tl)} "
        f"slo_attainment={tl.slo_report.attainment:.3f} "
        f"alerts={len(tl.slo_report.alerts)}",
        overhead_pct=(min(ratios) - 1) * 100)


def _megafleet_world(n_uavs):
    """One mega-fleet bench world: paper env provisioned per device,
    1 s slots, Poisson 5 rps/device, static oracle policy."""
    from repro.core import make_paper_env
    from repro.core.latency import LatencyParams
    from repro.policies import build_policy
    from repro.sim import AnalyticalBackend, PoissonTrace
    cfg, tables = make_paper_env(
        n_uavs=n_uavs, slot_seconds=1.0, peak_rps=10.0,
        latency=LatencyParams(server_flops=0.55e12 * n_uavs,
                              bw_max_bps=1e9),
        frames_per_slot=10.0)
    mids = np.arange(n_uavs, dtype=np.int32) % tables.n_models
    pol = build_policy("greedy_oracle", cfg, tables)
    return cfg, tables, mids, pol, AnalyticalBackend(cfg, tables), \
        PoissonTrace(rate_rps=5.0)


def _megafleet_epoch_s(world, engine, epochs, reps):
    """Best-of-reps per-epoch seconds for one engine (+ samples)."""
    from repro.sim import FleetConfig, simulate
    cfg, tables, mids, pol, backend, trace = world
    fl = FleetConfig(engine=engine, max_epochs=epochs,
                     record_epochs=False)
    kw = dict(n_requests=10**12, seed=0, fleet=fl, backend=backend,
              model_ids=mids)
    simulate(cfg, tables, pol, trace, **kw)          # warm (policy jit)
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = simulate(cfg, tables, pol, trace, **kw)
        samples.append((time.perf_counter() - t0) / res.epochs)
    return min(samples), samples, res


def megafleet_scaling(n_uavs=4096, epochs=4, reps=3):
    """Devices/sec of the vectorized fleet engine across fleet sizes —
    the mega-fleet scaling curve (n_uavs axis up to 100k devices)."""
    world = _megafleet_world(n_uavs)
    sec, samples, res = _megafleet_epoch_s(world, "vectorized",
                                           epochs, reps)
    row(f"megafleet_scaling[n_uavs={n_uavs}]",
        Timing(sec * 1e6, [s * 1e6 for s in samples]),
        f"per_epoch,devices_per_s={n_uavs/sec:,.0f} "
        f"req_per_epoch={res.served//res.epochs} engine=vectorized",
        devices=n_uavs, devices_per_s=n_uavs / sec)


def megafleet_speedup(n_uavs=32768, epochs=4, reps=3):
    """Loop-vs-vectorized cost ratio at 32k devices (the mega-fleet
    acceptance claim: vectorized >= 20x devices*epochs/sec).

    The *gated* value is the vectorized/loop per-epoch cost ratio —
    lower is better, so losing speedup shows up as the increase the
    gate flags. The speedup itself and the scaling exponent (log-log
    slope of vectorized per-epoch time over a 256..32k size sweep;
    1.0 = linear in devices) ride along as extra fields."""
    world = _megafleet_world(n_uavs)
    vec_s, vec_samples, _ = _megafleet_epoch_s(world, "vectorized",
                                               epochs, reps)
    loop_s, _, _ = _megafleet_epoch_s(world, "loop", epochs,
                                      max(reps - 1, 1))
    ratios = [v / loop_s for v in vec_samples]
    sizes = (256, 4096, 32768)
    curve = [vec_s if n == n_uavs else
             _megafleet_epoch_s(_megafleet_world(n), "vectorized",
                                epochs, reps)[0]
             for n in sizes]
    slope = np.polyfit(np.log(sizes), np.log(curve), 1)[0]
    row("megafleet_speedup", Timing(min(ratios), ratios),
        f"vec_over_loop_cost,speedup={loop_s/vec_s:.1f}x "
        f"loop_epoch_ms={loop_s*1e3:.0f} vec_epoch_ms={vec_s*1e3:.1f} "
        f"scaling_exponent={slope:.2f} devices={n_uavs}",
        speedup=loop_s / vec_s, scaling_exponent=float(slope),
        devices=n_uavs)


def scenario_sweep(n_requests=2000):
    """Every registered scenario preset through run_scenario with the
    static roster — the one-command experiment surface as a perf/smoke
    case (execute presets skipped: engine compiles dominate)."""
    from repro.scenarios import get_scenario, run_scenario, scenario_names
    for name in scenario_names():
        sc = get_scenario(name)
        if sc.execute:
            row(f"scenario_{name}", 0.0, "skipped=execute_backend")
            continue
        t0 = time.perf_counter()
        rep = run_scenario(sc, ("greedy_oracle", "device_only"),
                           n_requests=n_requests, seeds=(0,))
        us = (time.perf_counter() - t0) * 1e6
        g = rep.results["greedy_oracle"].mean
        d = rep.results["device_only"].mean
        row(f"scenario_{name}", us,
            f"requests={n_requests} oracle_p95_s={g['p95']:.3f} "
            f"oracle_slo_att={g['slo_attainment']:.3f} "
            f"device_only_slo_att={d['slo_attainment']:.3f}")


def online_adaptation(window=64, iters=50):
    """repro.online: steps/s of the jitted incremental update on a full
    replay window, plus a short drift run's recovery time (epochs from
    the brownout boundary until the adapted controller is back within
    10% of the per-regime greedy oracle)."""
    import jax

    from repro.core.env import env_reset
    from repro.online import OnlineConfig, OnlineLearner
    from repro.policies import build_policy
    from repro.scenarios import get_scenario
    from repro.sim import FleetConfig, simulate

    sc = get_scenario("link-brownout")
    cfg, tables, mids, _ = sc.build_env()
    n = cfg.n_uavs
    a2c = build_policy("a2c", cfg, tables, episodes=sc.episodes,
                       entropy_coef=sc.entropy_coef,
                       batch_envs=sc.batch_envs)
    a2c.train(seed=0, trace=sc.build_train_trace())
    snap = a2c.params

    # 1) raw incremental-update throughput on a synthetic full window
    oc = OnlineConfig(algo="a2c", gate="always")
    ln = OnlineLearner(a2c, oc, mids)
    state = env_reset(cfg, tables, jax.random.key(0),
                      model_ids=jnp.asarray(mids))
    r = np.random.default_rng(0)
    for _ in range(window):
        acts = np.stack([r.integers(0, tables.n_versions, n),
                         r.integers(0, tables.n_cuts, n)], -1)
        ln.observe_transition(state, acts.astype(np.int32),
                              r.normal(size=n), np.ones(n), 0)
    batch = ln.window.tail(window)
    step = ln._update(window)
    params, opt = a2c.params, ln._opt(a2c.params)
    params, opt = step(params, opt, batch["obs"], batch["actions"],
                       batch["logp"], batch["reward"], batch["mask"])
    jax.block_until_ready(jax.tree.leaves(params)[0])
    t0 = time.perf_counter()
    for _ in range(iters):
        params, opt = step(params, opt, batch["obs"], batch["actions"],
                           batch["logp"], batch["reward"], batch["mask"])
    jax.block_until_ready(jax.tree.leaves(params)[0])
    us = (time.perf_counter() - t0) / iters * 1e6

    # 2) drift recovery through the link-brownout preset's world
    a2c.set_params(snap)
    res = simulate(cfg, tables, a2c, sc.build_trace(),
                   n_requests=sc.n_requests, seed=0,
                   fleet=FleetConfig(slo_s=sc.slo_s), model_ids=mids,
                   schedule=sc.build_schedule(), online=sc.build_online())
    a2c.set_params(snap)
    reg = res.adaptation["regimes"][1]
    onl = res.adaptation["online"]
    rec = reg["recovery_epochs"]
    row("online_adaptation", us,
        f"update_steps_per_s={1e6/us:.1f} window={window} "
        f"scenario={sc.name} "
        f"recovery_epochs={'never' if rec is None else int(rec)} "
        f"regret={reg['regret']:.3f} updates={onl['updates']} "
        f"bursts={onl['bursts']}")


def kernels_interpret():
    from repro.kernels.flash_attention import flash_attention
    r = np.random.default_rng(0)
    q = jnp.asarray(r.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(r.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(r.normal(size=(1, 2, 256, 64)), jnp.float32)
    us = _timeit(lambda: flash_attention(q, k, v, interpret=True), n=3)
    row("flash_attention_interpret", us, "B1_H4_S256_D64,CPU_interpret_mode")


def quant_matmul(M=512, K=512, N=512):
    """int8-vs-bf16 matmul throughput (repro.quant w8a8 path).

    On TPU the int8 MXU path doubles MAC throughput; on this CPU
    container the numbers only sanity-check dispatch overheads, so the
    derived column reports GFLOP/s for both plus the quantization error."""
    from repro.kernels.quant_matmul import quant_matmul_ref
    from repro.quant import quantize, quantize_act
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(r.normal(size=(K, N)) * 0.05, jnp.float32)
    xb, wb = x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
    qt = quantize(w, "w8a8")
    xq, xs = quantize_act(x)
    xs_, ws_ = xs.reshape(-1), qt.scale.reshape(-1)

    mm = jax.jit(lambda a, b: a @ b)
    us_bf16 = _timeit(lambda: mm(xb, wb), n=10)
    qmm = jax.jit(lambda a, b, s1, s2: quant_matmul_ref(a, b, s1, s2))
    us_i8 = _timeit(lambda: qmm(xq, qt.q, xs_, ws_), n=10)
    flops = 2.0 * M * K * N
    y = x @ w
    yq = quant_matmul_ref(xq, qt.q, xs_, ws_)
    rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
    row("quant_matmul", us_i8,
        f"MKN={M} int8_gflops={flops/us_i8/1e3:.1f} "
        f"bf16_us={us_bf16:.1f} bf16_gflops={flops/us_bf16/1e3:.1f} "
        f"relerr={rel:.4f}")

    from repro.kernels.quant_matmul import quant_matmul as qmm_pallas
    us_pl = _timeit(lambda: qmm_pallas(xq, qt.q, xs_, ws_, interpret=True),
                    n=2)
    row("quant_matmul_interpret", us_pl, f"MKN={M},CPU_interpret_mode")


def cluster_routing(n_requests=12_000, reps=3):
    """repro.cluster: routed fleet throughput over the heterogeneous
    4-server pool (hetero-4 x near-far, widened (version, cut, server)
    actions, hysteresis autoscaler) — us/epoch per router baseline plus
    the SLO attainment each dispatch rule earns on the same stream."""
    from repro.cluster import (AutoscalerConfig, build_cluster, get_pool,
                               get_topology)
    from repro.core import make_paper_env
    from repro.core.latency import LatencyParams
    from repro.policies import build_policy
    from repro.sim import (AnalyticalBackend, FleetConfig, PoissonTrace,
                           simulate)
    n_uavs = 8
    cluster = build_cluster(get_pool("hetero-4"),
                            get_topology("near-far", n_uavs, 4))
    cfg, tables = make_paper_env(
        n_uavs=n_uavs, slot_seconds=10.0, peak_rps=30.0,
        latency=LatencyParams(server_flops=0.55e12 * n_uavs,
                              bw_max_bps=1e9),
        frames_per_slot=300.0, cluster=cluster)
    mids = np.arange(n_uavs, dtype=np.int32) % tables.n_models
    trace = PoissonTrace(rate_rps=8.0)
    for name in ("round_robin", "join_shortest_queue", "greedy_oracle"):
        pol = build_policy(name, cfg, tables)
        kw = dict(model_ids=mids, n_requests=n_requests, seed=0,
                  backend=AnalyticalBackend(cfg, tables),
                  fleet=FleetConfig(slo_s=2.0),
                  autoscaler=AutoscalerConfig(policy="hysteresis"))
        simulate(cfg, tables, pol, trace, **kw)   # warm (jit compiles)
        samples, dts = [], []
        for _ in range(reps):   # same seed: identical epochs each rep
            t0 = time.perf_counter()
            res = simulate(cfg, tables, pol, trace, **kw)
            dts.append(time.perf_counter() - t0)
            samples.append(dts[-1] / max(res.epochs, 1) * 1e6)
        s = res.summary
        row(f"cluster_routing[{name}]", Timing(min(samples), samples),
            f"per_epoch,req_per_s={res.served / min(dts):.0f} "
            f"slo_att={s['slo_attainment']:.3f} "
            f"p95_s={s['p95']:.3f} "
            f"scale_events={s['scale_events']:.0f} "
            f"mean_replicas={s['mean_replicas']:.2f}")


def build_matrix() -> Matrix:
    """The declarative case matrix (replaces the hand-rolled ALL-list
    dispatch): paper artifacts, system benches, and the fleet-size axis
    behind the devices/sec scaling curve."""
    m = Matrix()
    for fn in (table1_profiles, fig2_accuracy_sweep, fig3_latency_sweep,
               fig4_energy_sweep, table2_cut_selection, baseline_policies,
               a2c_convergence, ablation_a2c, ablation_agents):
        m.add(fn, tags=("paper",))
    for fn in (roofline_suite, hillclimb_variants, serving_decode,
               split_inference, continuous_batching):
        m.add(fn, tags=("system",))
    m.add(scheduler_throughput, tags=("system", "smoke"))
    m.add(fleet_sim, tags=("system", "smoke"),
          axes={"n_uavs": (8, 64, 256)})
    m.add(megafleet_scaling, tags=("system", "smoke"),
          axes={"n_uavs": (256, 4096, 32768, 100_000)})
    m.add(megafleet_speedup, tags=("system", "smoke"))
    m.add(scenario_sweep, tags=("system",))
    m.add(cluster_routing, tags=("system", "smoke"))
    m.add(timeline_overhead, tags=("system", "smoke"))
    m.add(train_throughput, tags=("system", "smoke"))
    m.add(pricing_numpy_throughput, tags=("system", "smoke"))
    m.add(online_adaptation, tags=("system",))
    m.add(kernels_interpret, tags=("system", "smoke"))
    m.add(quant_matmul, tags=("system", "smoke"))
    return m


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated group or case names "
                    "(e.g. fleet_sim or fleet_sim[n_uavs=64])")
    ap.add_argument("--tags", default=None,
                    help="comma-separated tag filter (paper, system, "
                    "smoke)")
    ap.add_argument("--agent", action="store_true",
                    help="run sweeps with trained A2C agents (slow)")
    ap.add_argument("--episodes", type=int, default=200)
    ap.add_argument("--json", default="BENCH_results.json",
                    help="write rows as JSON here ('' disables)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record obs events (spans, metrics, retrace "
                    "accounting) for the benched runs to a JSONL file")
    args = ap.parse_args()
    matrix = build_matrix()
    try:
        cases = matrix.select(
            only=args.only.split(",") if args.only else None,
            tags=args.tags.split(",") if args.tags else None)
    except KeyError as e:
        ap.error(str(e))
    overrides = {
        "a2c_convergence": dict(episodes=args.episodes),
        **{name: dict(use_agent=args.agent, episodes=args.episodes)
           for name in ("fig2_accuracy_sweep", "fig3_latency_sweep",
                        "fig4_energy_sweep", "table2_cut_selection")},
    }
    t_unix = time.time()
    result = runner.run(cases, trace=args.trace,
                        meta={"tool": "benchmarks", "argv": sys.argv[1:]},
                        overrides=overrides)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({"schema": 2, "unix_time": t_unix,
                       "argv": sys.argv[1:], "errors": result.errors,
                       "git_sha": history.git_sha(),
                       "fingerprint": history.fingerprint(),
                       "rows": result.records}, f, indent=2)
        print(f"wrote {args.json} ({len(result.records)} rows)",
              flush=True)
    if result.errors:
        raise SystemExit(1)   # make ERROR rows visible to CI


if __name__ == "__main__":
    main()

"""End-to-end latency model (paper Eqs. 4-5).

T = T_local(head) + T_trans(cut activation) + T_queue + T_remote(tail).
Throughputs are effective (not peak) FLOP/s for the TX2 / PowerEdge regime.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LatencyParams:
    device_flops: float = 0.25e12     # Jetson TX2 effective
    server_flops: float = 0.8e12      # 16-core 3.2 GHz PowerEdge effective
    job_service_s: float = 0.05       # mean service time of a queued job
    bw_min_bps: float = 16e6          # 2 MB/s
    bw_max_bps: float = 320e6         # 40 MB/s


def local_time(lp: LatencyParams, head_flops):
    return head_flops / lp.device_flops


def transmit_time(bandwidth_bps, n_bytes):
    return (n_bytes * 8.0) / jnp.maximum(bandwidth_bps, 1.0)


def remote_time(lp: LatencyParams, tail_flops, queue_len):
    """Eq. 4: T_remote = T_queue + T_comp(tail)."""
    return queue_len * lp.job_service_s + tail_flops / lp.server_flops


def total_time(lp: LatencyParams, head_flops, tail_flops, n_bytes,
               bandwidth_bps, queue_len):
    """Eq. 5."""
    return (local_time(lp, head_flops)
            + transmit_time(bandwidth_bps, n_bytes)
            + remote_time(lp, tail_flops, queue_len))

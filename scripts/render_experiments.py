"""Render §Dry-run / §Roofline / §Perf into EXPERIMENTS.md from results."""
import json, sys
sys.path.insert(0, "src")
from repro.analysis.roofline import enrich, load, fmt_s, table

recs = load("results/dryrun.jsonl")
base = [r for r in recs if r.get("variant", "baseline") == "baseline"]
ok = [r for r in base if r["status"] == "ok"]
single = [r for r in ok if r["mesh"] == "single"]
multi = [r for r in ok if r["mesh"] == "multi"]

# ---- dry-run summary ----
lines = [f"**{len(ok)}/80 combos lower + compile successfully** "
         f"({len(single)} on the 16x16 single-pod mesh / 256 chips, "
         f"{len(multi)} on the 2x16x16 multi-pod mesh / 512 chips; "
         "zero sharding or compile failures).",
         "",
         "Per-combo records (memory_analysis, cost_analysis, collective",
         "schedule, scan-aware jaxpr cost) live in `results/dryrun.jsonl`;",
         "the run log is `results/dryrun_run3.log`. Summary, single-pod:",
         "",
         "| arch | shape | compile_s | HLO len | collectives (GB, loop-aware) | arg bytes/step |",
         "|---|---|---|---|---|---|"]
for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
    coll = r.get("collectives", {}).get("total_bytes", 0) / 1e9
    lines.append(
        f"| {r['arch']} | {r['shape']} | {r.get('compile_s', 0):.1f} "
        f"| {r.get('hlo_len', 0)//1000}k | {coll:.1f} "
        f"| {r.get('argument_size_in_bytes', 0)/1e9:.1f}GB |")
lines += ["",
          "Multi-pod (512-chip) pass proves the `pod` axis shards: batch",
          "dims spread over (pod, data); for batch-1 long_500k the KV-cache",
          "sequence axis picks up both axes (context parallelism) via the",
          "logical-axis resolver (`launch/shardings.py`).",
          "",
          "`memory_analysis.temp_size` is reported for the whole partitioned",
          "module on the host platform; per-chip ~= value / n_devices. The",
          "train shapes sit at 26-180 GB global temp (0.1-0.7 GB/chip) with",
          "remat ON — see §Perf for the remat trade-off measurement."]
dryrun_md = "\n".join(lines)

roofline_md = table(recs, "single") + """

Reading: terms are per-step seconds at the roofline (best case); **dominant**
is the bottleneck the perf loop attacks; MODEL/HLO is MODEL_FLOPS (6*N_active*D
train / 2*N_active*D inference) over scan-aware compiled FLOPs — low values
flag redundant compute (remat recompute, masked-causal waste, MLA
re-expansion, MoE dispatch bookkeeping).

Highlights:
- **train_4k** is compute-dominated for every arch (tokens/chip = 4096 is
  arithmetic-intensity-rich); ratios 0.44-0.85 reflect the remat-recompute
  factor (8/6 = ideal 0.75) plus masked-full attention.
- **decode shapes** are memory-dominated (KV-cache + weight streaming), as
  expected at batch/chip <= 0.5; the SSM/hybrid archs have the smallest
  decode bounds (recurrent state instead of KV cache).
- **deepseek-v2-lite decode_32k** is the outlier: compute-dominated with
  MODEL/HLO = 0.00 — the MLA cache re-expansion pathology (fixed in §Perf).
- **long_500k** bounds are tiny because SWA/SSM versions cap per-step work;
  the data axes idle at batch=1 (noted: context-parallel cache sharding keeps
  the 512-chip mesh legal, not efficient — a real deployment would re-shape
  the mesh for single-stream decode).
"""

perf_md = """The three hillclimbed pairs (selection rationale): **deepseek-v2-lite x
decode_32k** (worst MODEL/HLO ratio, 0.00), **mixtral-8x22b x train_4k**
(largest collective term of any train row + MoE-representative), and
**llama-3.2-vision-90b x prefill_32k** (largest absolute bound; inference
prefill = the paper's serving regime). Every iteration below is a dry-run
variant (`python -m repro.launch.dryrun --variant NAME`), re-lowered and
re-analyzed; numbers are single-pod roofline terms.

### Pair 1 — deepseek-v2-lite-16b x decode_32k (paper-representative: MLA)

| variant | compute | memory | bound | MODEL/HLO |
|---|---|---|---|---|
| baseline (paper-faithful MLA) | 9.89 ms | 6.49 ms | **9.89 ms** | 0.00 |
| mla_absorb | 0.52 ms | 0.96 ms | **0.96 ms** | 0.03 |

- **Iteration 1 — hypothesis**: the compute term is ~100x MODEL_FLOPS because
  decode re-expands the compressed KV cache to per-head K/V every step:
  expansion FLOPs = 2*B*S*R*H*(d_nope+d_v) = 2*128*32768*512*16*256 = 35 TF/step,
  vs ~0.6 TF of model FLOPs. Absorbing W_uk/W_uv into the query/output
  projections attends in the 512-d latent space: per-step attention cost
  becomes 2*B*H*S*(2R+rope) ~ 4.9 TF, predicted ~7x compute cut and the
  bound moving to memory.
  **Change**: `mla_absorb` (attention.py). **Measured**: compute 9.89->0.52 ms
  (-95%), memory 6.49->0.96 ms (cache no longer expanded through HBM),
  bound **10.3x lower**. CONFIRMED (even better than predicted: expansion
  had also been double-counted through the f32 upcast).
- **Iteration 2 — floor check**: residual memory term 0.96 ms vs analytic
  floor = compressed cache (128*32k*576B*2 * 27L = 65 GB -> 0.31 ms) +
  bf16 params (31 GB -> 0.15 ms) + activations ~= 0.6-0.9 ms. We are within
  ~1.3x of the streaming floor; remaining knobs (cache dtype fp8, head
  sharding of w_uk einsums) predict <5%. STOP (converged).

Numerical parity of the absorbed path: `test_mla_absorb_decode_parity`
(rtol 2e-4).

### Pair 2 — mixtral-8x22b x train_4k (most collective-bound train row)

| variant | compute | memory | collective | bound | MODEL/HLO | temp (global) |
|---|---|---|---|---|---|---|
| baseline (GShard einsum MoE, remat) | 8.57 s | 0.86 s | 0.254 s | **8.57 s** | 0.57 | 166 GB |
| moe_gather | 8.35 s | 0.80 s | 4.04 s | 8.35 s | 0.58 | 330 GB |
| moe_chunk512 | 8.46 s | 0.86 s | 0.275 s | 8.46 s | 0.58 | 165 GB |
| causal_skip | 8.39 s | 0.77 s | 0.239 s | **8.39 s** | 0.58 | 150 GB |
| noremat | 6.45 s | 0.65 s | 0.201 s | 6.45 s | 0.76 | 4803 GB |
| noremat_skip | 6.31 s | 0.58 s | 0.186 s | 6.31 s | 0.77 | 3708 GB |

- **Iteration 1 — hypothesis**: the one-hot dispatch/combine einsums
  (2*2*T*E*C*d per chunk) waste ~5% of compute and the scatter/gather
  rewrite removes them at zero FLOPs.
  **Change**: `moe_gather`. **Measured**: compute -2.6% as predicted, BUT
  collective term exploded 0.25->4.04 s and temp doubled: under GSPMD the
  scatter-add/gather on expert-sharded buffers lowers to all-gather +
  select chains instead of the einsum's clean all-to-all pattern. REFUTED
  as a net win — einsum dispatch retained. (Lesson: SPMD-friendliness of
  the op pattern matters more than its FLOP count.)
- **Iteration 2 — hypothesis**: halving the dispatch chunk halves dispatch
  FLOPs/token. **Change**: `moe_chunk512`. **Measured**: -1.3% compute.
  CONFIRMED but immaterial — dispatch is not mixtral's bottleneck (E*C =
  chunk*K*cf is E-independent; expert matmuls dominate). REFUTED as a
  meaningful lever.
- **Iteration 3 — hypothesis**: the remat-recompute factor caps MODEL/HLO
  at 6/8 = 0.75; dropping remat should cut compute ~25%.
  **Change**: `noremat`. **Measured**: compute 8.57->6.45 s (-24.7%, ratio
  0.57->0.76 — matches the napkin exactly). CONFIRMED — but temp memory
  166 GB -> 4.8 TB global (18.8 GB/chip > 16 GB HBM): infeasible on v5e.
  **Verdict**: remat is the correct production setting; the 1.33x compute
  factor is the price of fitting. (A selective save-attention-only policy
  is the next candidate beyond this repo's scope.)
- **Iteration 4 — hypothesis**: the masked-full chunked attention computes
  both triangles; skipping fully-masked kv blocks halves attention FLOPs
  (~2% of mixtral train compute at S=4k) and cuts kv re-reads.
  **Change**: `causal_skip`. **Measured**: compute -2.1%, memory -10%,
  temp -10%. CONFIRMED; adopted (free win, exact numerics —
  `test_attention_chunk_sizes_do_not_change_results`).
- Accepted optimized config: **baseline + causal_skip** (8.39 s bound);
  three consecutive iterations under 5% on the dominant term -> STOP.

### Pair 3 — llama-3.2-vision-90b x prefill_32k (largest absolute bound)

| variant | compute | memory | bound | MODEL/HLO |
|---|---|---|---|---|
| baseline (q=512/kv=1024 chunks) | 5.37 s | 1.21 s | **5.37 s** | 0.68 |
| bigchunk (2k/4k) | 5.37 s | 0.42 s | 5.37 s | 0.68 |
| hugechunk (4k/8k) | 5.37 s | 0.29 s | 5.37 s | 0.68 |
| causal_skip | 4.50 s | 0.70 s | **4.50 s** | 0.81 |
| hugechunk_skip | 4.67 s | 0.24 s | 4.67 s | 0.78 |

- **Iteration 0 — accounting fix**: with unfused byte counting this pair
  looked memory-bound (19.4 s memory term) because the f32 attention-score
  tensors were charged to HBM; the Pallas flash kernel keeps them in VMEM.
  Switching the analyzer to kernel-fused accounting (bytes_fused,
  §Methodology) re-classified the pair as compute-bound — the perf loop
  then attacked the right term.
- **Iteration 1 — hypothesis**: kv blocks are re-read once per q block;
  4x larger tiles -> ~4x less attention HBM traffic.
  **Change**: `bigchunk`/`hugechunk`. **Measured**: memory 1.21->0.42->0.29 s
  (-76%). CONFIRMED (diminishing), bound unchanged (compute-dominated).
- **Iteration 2 — hypothesis**: masked-full attention doubles score FLOPs;
  at S=32k attention is ~30% of prefill compute, so causal skipping should
  cut ~15%. **Change**: `causal_skip`. **Measured**: compute 5.37->4.50 s
  (-16.2%, ratio 0.68->0.81). CONFIRMED — and combining with huge tiles
  (hugechunk_skip) trades 4% compute back for the best memory term
  (coarser skip granularity skips fewer blocks): tile size and skip
  granularity interact.
- Accepted optimized config: **causal_skip** (bound -16%); same change also
  takes mixtral prefill_32k 2.98->2.52 s (-15%). Remaining ratio gap
  (0.81): diagonal-block masked halves + MoE-free dense waste; predicted
  <5% per knob -> STOP.

### Cross-cutting results adopted framework-wide

- `causal_skip` exact-numerics attention skipping (config flag, default
  off to keep the paper-faithful baseline reproducible).
- `mla_absorb` for MLA decode (config flag; parity-tested).
- Kernel-fused roofline accounting (bytes_fused) as the memory term.
- Refuted-and-documented: gather MoE dispatch, microbatch accumulation
  (mb8/mb16: collective term x8-15 from per-microbatch grad reductions
  with no temp win at this scale), noremat (HBM-infeasible).
"""

src = open("EXPERIMENTS.md").read()
src = src.replace("<!-- DRYRUN_SUMMARY -->", dryrun_md)
src = src.replace("<!-- ROOFLINE_TABLE -->", roofline_md)
src = src.replace("<!-- PERF_LOG -->", perf_md)
open("EXPERIMENTS.md", "w").write(src)
print("rendered", len(src), "chars")

"""repro.scenarios: declarative experiment regimes + the one entry point.

A ``Scenario`` names a complete operating regime (env kind, fleet shape,
reward weights, workload trace, SLO, seeds, training budget); importing
this package registers the presets (``scenario_names()`` lists them) and
``run_scenario(scenario, policies)`` runs any policy roster against one
with paired-seed comparisons built in.
"""
from repro.scenarios.base import Scenario
from repro.scenarios.presets import (get_scenario, register_scenario,
                                     scenario_names)
from repro.scenarios.run import (ComparisonReport, PolicyResult,
                                 run_scenario, split_policy_name)

__all__ = [
    "Scenario", "ComparisonReport", "PolicyResult",
    "get_scenario", "register_scenario", "scenario_names", "run_scenario",
    "split_policy_name",
]

"""Reward function (paper Eqs. 8-11) + beyond-paper stability score.

R = mean_k( w1*A + w2*L + w3*E ), sum(w) = 1.
A: sigmoid-normalized accuracy; L/E: 1 - cost / all-local cost.

The paper's L/E scores normalize by the *chosen version's* own all-local
cost, so they cannot rank absolute service times across versions (heavy
run locally scores exactly like light run locally), and nothing in the
slot scores encodes request-level capacity. Under trace-driven
per-request traffic (repro.sim) that blind spot is fatal: a device whose
per-request service time exceeds the inter-arrival gap builds unbounded
backlog. ``stability_score`` closes the loop: given utilization
u = offered_rps x service_s it saturates to 1 when the device+link can
absorb the offered load and to 0 when it cannot. ``w_stab = 0`` (the
default) keeps the paper's exact reward.

The per-request score formulas (Eqs. 9-11 + stability) live in
``repro.core.pricing`` — the single backend-polymorphic cost core — and
are re-exported here; this module keeps the weights and the Eq. 8
aggregation.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.pricing import (accuracy_score, energy_score, latency_score,
                                stability_score)

__all__ = ["RewardWeights", "accuracy_score", "latency_score",
           "energy_score", "stability_score", "reward"]


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    w_acc: float = 1 / 3
    w_lat: float = 1 / 3
    w_energy: float = 1 / 3
    w_stab: float = 0.0     # beyond-paper: SLO/stability-aware shaping
    # Eq. 9 sigmoid shape
    p: float = 20.0
    q: float = 0.72
    # stability sigmoid sharpness (score = sigmoid(p_stab * (1 - u)))
    p_stab: float = 8.0

    def normalized(self) -> "RewardWeights":
        s = self.w_acc + self.w_lat + self.w_energy + self.w_stab
        return dataclasses.replace(self, w_acc=self.w_acc / s,
                                   w_lat=self.w_lat / s,
                                   w_energy=self.w_energy / s,
                                   w_stab=self.w_stab / s)


def reward(w: RewardWeights, acc_s, lat_s, energy_s, stab_s=None,
           mask=None):
    """Eq. 8: per-UAV weighted sum averaged over (active) UAVs; the
    stability term only contributes when w_stab > 0."""
    r = w.w_acc * acc_s + w.w_lat * lat_s + w.w_energy * energy_s
    if stab_s is not None:
        r = r + w.w_stab * stab_s
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(r * mask) / denom
    return jnp.mean(r)

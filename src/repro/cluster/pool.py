"""Heterogeneous edge-server pool: static description + runtime state.

``ServerSpec`` describes one server relative to the env's single-server
baseline (``LatencyParams.server_flops`` / ``job_service_s``): a FLOPs
scale for the tail compute the pricing core divides by, a service-time
scale for its background-job queue, and the AutoScale-style knobs — a
replica count, a DVFS ladder, and a per-replica power draw — that the
``Autoscaler`` (repro.cluster.autoscale) moves at runtime.

``ClusterParams`` is the *frozen, hashable* projection a cluster-mode
``EnvConfig`` carries (plain float tuples, so env configs stay usable as
jit-closure constants): per-server scales plus the per device -> server
link matrix a ``Topology`` (repro.cluster.topology) provides. The
pricing core (``core/pricing.py``) reads it to reprice the Eq. 2/3
transmission terms and the Eq. 4 queue/tail terms per *chosen* server
when actions carry a server column.

``ServerPool`` is the runtime object the fleet loop owns: live replica
counts and DVFS levels (moved per epoch by the autoscaler), the derived
effective service arrays pricing and the per-server Lindley backlog use,
and the replica-energy meter. A 1-server pool at uniform topology is
bit-identical to the classic single-server fleet: every derived quantity
is the baseline value multiplied by exactly 1.0 (tested in
tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """One edge server, relative to the baseline single server."""
    name: str = "edge"
    flops_scale: float = 1.0       # x LatencyParams.server_flops
    service_scale: float = 1.0     # x LatencyParams.job_service_s
    bg_arrival_scale: float = 1.0  # x EnvConfig.queue_arrival_rate
    bg_service_scale: float = 1.0  # x EnvConfig.queue_service_per_slot
    replicas: int = 1              # initial active replicas
    max_replicas: int = 1          # autoscaler ceiling
    # available frequency scalings, ascending; the pool starts (and the
    # env trains) at the top step — the autoscaler may walk down to
    # trade service rate for f^3 replica power
    dvfs: Tuple[float, ...] = (1.0,)
    p_replica_w: float = 0.0       # per-replica power draw at dvfs = 1.0


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """Hashable cluster description carried by ``EnvConfig.cluster``.

    Per-server entries are indexed by server id s in [0, S); link
    matrices are (n_devices, S) row-major tuples. ``nominal`` derives
    the effective service arrays at initial replicas / top DVFS — the
    operating point trainable controllers price against (the fleet's
    live autoscaler state enters through ``StateView`` instead).
    """
    flops_scale: Tuple[float, ...]
    service_scale: Tuple[float, ...]
    bg_arrival_scale: Tuple[float, ...]
    bg_service_scale: Tuple[float, ...]
    replicas: Tuple[int, ...]
    max_replicas: Tuple[int, ...]
    dvfs: Tuple[Tuple[float, ...], ...]
    p_replica_w: Tuple[float, ...]
    link_scale: Tuple[Tuple[float, ...], ...]   # (n, S) bandwidth x
    link_rtt_s: Tuple[Tuple[float, ...], ...]   # (n, S) round-trip s
    names: Tuple[str, ...]

    @property
    def n_servers(self) -> int:
        return len(self.flops_scale)

    @property
    def n_devices(self) -> int:
        return len(self.link_scale)

    def nominal(self, lp, xp=np):
        """(srv_flops, srv_service_s) at initial replicas / top DVFS.

        Multiplications keep the baseline factor first, so a 1.0-scaled
        single server reproduces ``lp.server_flops`` / ``job_service_s``
        bit-exactly.
        """
        speed = [r * d[-1] for r, d in zip(self.replicas, self.dvfs)]
        flops = xp.asarray([lp.server_flops * f * s
                            for f, s in zip(self.flops_scale, speed)])
        service = xp.asarray([lp.job_service_s * sc / s
                              for sc, s in zip(self.service_scale, speed)])
        return flops, service


def build_cluster(servers: Tuple[ServerSpec, ...],
                  topology) -> ClusterParams:
    """Fuse a server tuple and a ``Topology`` into ``ClusterParams``."""
    S = len(servers)
    if topology.n_servers != S:
        raise ValueError(
            f"topology {topology.name!r} is built for "
            f"{topology.n_servers} servers, pool has {S}")
    return ClusterParams(
        flops_scale=tuple(s.flops_scale for s in servers),
        service_scale=tuple(s.service_scale for s in servers),
        bg_arrival_scale=tuple(s.bg_arrival_scale for s in servers),
        bg_service_scale=tuple(s.bg_service_scale for s in servers),
        replicas=tuple(int(s.replicas) for s in servers),
        max_replicas=tuple(int(s.max_replicas) for s in servers),
        dvfs=tuple(tuple(float(d) for d in s.dvfs) for s in servers),
        p_replica_w=tuple(s.p_replica_w for s in servers),
        link_scale=tuple(tuple(float(v) for v in row)
                         for row in topology.link_scale),
        link_rtt_s=tuple(tuple(float(v) for v in row)
                         for row in topology.rtt_s),
        names=tuple(s.name for s in servers))


@dataclasses.dataclass
class PoolEffective:
    """Live per-server service arrays at the pool's current replica /
    DVFS state (all (S,) float64)."""
    flops: np.ndarray         # tail FLOP/s the pricing core divides by
    service_s: np.ndarray     # background-job service seconds
    bg_drain: np.ndarray      # background jobs drained per slot
    cap_scale: np.ndarray     # fleet-backlog drain multiplier


class ServerPool:
    """Runtime replica/DVFS state + replica-energy meter for one fleet
    simulation. ``tick`` advances the autoscaler (if any) on measured
    per-server queue depth and meters replica energy for the slot;
    ``effective`` derives the live service arrays under the *current
    regime's* physics (drift patches change ``lp`` mid-run)."""

    def __init__(self, cluster: ClusterParams, autoscaler=None):
        self.cluster = cluster
        S = cluster.n_servers
        self.replicas = np.asarray(cluster.replicas, dtype=np.int64)
        self.dvfs_idx = np.asarray([len(d) - 1 for d in cluster.dvfs],
                                   dtype=np.int64)
        self.energy_j = 0.0
        self.scale_events = 0
        self._replica_slots = 0.0   # sum over epochs of active replicas
        self._epochs = 0
        # last tick's snapshot — the state the epoch actually ran at
        # (taken *before* the autoscaler moves) plus its decisions; the
        # timeline's per-server series read these
        self.last_dvfs = np.asarray([cluster.dvfs[s][self.dvfs_idx[s]]
                                     for s in range(S)])
        self.last_replicas = self.replicas.copy()
        self.last_power_w = np.zeros(S)
        self.last_decisions: list = []
        self.autoscaler = None
        if autoscaler is not None:
            from repro.cluster.autoscale import Autoscaler
            self.autoscaler = Autoscaler(autoscaler, S)

    def _speed(self) -> np.ndarray:
        d = np.asarray([self.cluster.dvfs[s][self.dvfs_idx[s]]
                        for s in range(self.cluster.n_servers)])
        return self.replicas * d

    def effective(self, lp, env_cfg) -> PoolEffective:
        c = self.cluster
        speed = self._speed()
        flops = np.asarray(c.flops_scale) * speed * lp.server_flops
        service = lp.job_service_s * np.asarray(c.service_scale) / speed
        bg_drain = env_cfg.queue_service_per_slot \
            * np.asarray(c.bg_service_scale) * speed
        return PoolEffective(flops=flops, service_s=service,
                             bg_drain=bg_drain, cap_scale=speed)

    def tick(self, queue_jobs: np.ndarray, slot_seconds: float) -> None:
        """One epoch: meter replica energy at the current state, then
        let the autoscaler move replicas/DVFS for the next epoch."""
        c = self.cluster
        d = np.asarray([c.dvfs[s][self.dvfs_idx[s]]
                        for s in range(c.n_servers)])
        p = np.asarray(c.p_replica_w) * self.replicas * d ** 3
        self.energy_j += float(p.sum()) * slot_seconds
        self._replica_slots += float(self.replicas.sum())
        self._epochs += 1
        self.last_dvfs = d
        self.last_replicas = self.replicas.copy()
        self.last_power_w = p
        self.last_decisions = []
        if self.autoscaler is not None:
            self.last_decisions = self.autoscaler.step(
                self, np.asarray(queue_jobs))
            self.scale_events += len(self.last_decisions)

    def summary(self) -> Dict[str, float]:
        return {
            "server_energy_j": self.energy_j,
            "scale_events": float(self.scale_events),
            "mean_replicas": self._replica_slots / max(self._epochs, 1),
        }


# --------------------------------------------------------------------------
# pool preset registry (KeyError-listing convention, like get_trace)
# --------------------------------------------------------------------------

_POOLS: Dict[str, object] = {}


def register_pool(name: str, factory) -> None:
    if name in _POOLS:
        raise ValueError(f"server pool {name!r} already registered")
    _POOLS[name] = factory


def pool_names() -> Tuple[str, ...]:
    return tuple(sorted(_POOLS))


def get_pool(name: str, **kw) -> Tuple[ServerSpec, ...]:
    """Named pool preset -> server tuple; a miss lists every valid name
    (the registry convention shared with get_trace/get_schedule)."""
    if name not in _POOLS:
        raise KeyError(f"unknown server pool {name!r}; valid pools: "
                       f"{', '.join(pool_names())}")
    return tuple(_POOLS[name](**kw))


def _single():
    """The degenerate pool: one baseline server, no autoscaling room —
    bit-identical to the classic single-server fleet under the uniform
    topology (tests/test_cluster.py)."""
    return (ServerSpec(name="edge"),)


def _uniform(n: int = 4, p_replica_w: float = 45.0,
             max_replicas: int = 2):
    """n identical baseline-rate servers splitting the background load."""
    return tuple(ServerSpec(name=f"edge{i}", bg_arrival_scale=1.0 / n,
                            max_replicas=max_replicas,
                            p_replica_w=p_replica_w)
                 for i in range(n))


def _hetero4(p_replica_w: float = 45.0):
    """Four-tier heterogeneous pool: one fast box down to a quarter-rate
    micro-edge. Service time scales inversely with FLOPs (a slow box
    drains its background queue slowly too), and the fast servers carry
    most of the ambient background workload — so under a flash-crowd
    surge a *job-count* shortest-queue router systematically misreads
    the slow tiers as cheap."""
    tiers = ((1.0, 1.0), (0.65, 0.75), (0.4, 0.5), (0.2, 0.25))
    return tuple(
        ServerSpec(name=f"tier{i}", flops_scale=f,
                   service_scale=1.0 / f, bg_arrival_scale=bg,
                   replicas=1, max_replicas=1 + i,
                   dvfs=(0.6, 0.8, 1.0), p_replica_w=p_replica_w * f)
        for i, (f, bg) in enumerate(tiers))


register_pool("single", _single)
register_pool("uniform-4", _uniform)
register_pool("hetero-4", _hetero4)

import os

# Smoke tests and benches must see ONE device (the dry-run, and only the
# dry-run, sets --xla_force_host_platform_device_count=512 itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_batch(cfg, B=2, S=16, seed=0):
    r = np.random.default_rng(seed)
    import jax.numpy as jnp
    b = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "targets": jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.cross_attn_every:
        b["media"] = jnp.asarray(
            r.normal(size=(B, cfg.n_media_tokens, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        b["enc_frames"] = jnp.asarray(
            r.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return b

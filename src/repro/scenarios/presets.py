"""Named scenario presets + registry.

Each preset is a complete operating regime; ``scripts/simulate.py
--scenario <name>`` (flags still override individual fields) and
``run_scenario`` consume them, and the scenario-determinism test runs
every one of them twice. Registering a new requirement is one
``register_scenario`` call — no call-site plumbing.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.reward import RewardWeights
from repro.scenarios.base import Scenario

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; valid names: "
                       f"{', '.join(scenario_names())}")
    return _REGISTRY[name]


# --------------------------------------------------------------------------
# presets
# --------------------------------------------------------------------------

register_scenario(Scenario(
    name="paper-exact",
    description="the paper's 3-UAV testbed, faithful reward (no "
                "stability term), 30 s slots, ~1 fps reconnaissance "
                "load per device",
    devices=3, models="cycle",
    weights=RewardWeights(),                 # thirds, w_stab = 0
    slot_seconds=30.0, peak_rps=0.0,         # paper-faithful
    server_flops_per_device=None, bw_max_bps=None,   # testbed latency
    trace="poisson", trace_kw={"rate_rps": 1.0},
    slo_s=5.0, seeds=(0, 1, 2), n_requests=10_000,
    policies=("a2c", "greedy_oracle", "device_only", "full_offload"),
    episodes=300, entropy_coef=0.01, train_trace=None))

register_scenario(Scenario(
    name="paper-mmpp-burst",
    description="4-device fleet under 2-state MMPP bursts (2 -> 30 "
                "rps/device); the stability-aware controller's "
                "acceptance regime",
    devices=4, models="vgg",
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 30.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    seeds=(0, 2, 4), n_requests=20_000,
    policies=("a2c", "device_only", "full_offload"),
    episodes=500))

register_scenario(Scenario(
    name="diurnal-fleet",
    description="8-device fleet under a sinusoidal day/night load "
                "(2 -> 30 rps/device) with mixed model assignment",
    devices=8, models="cycle",
    trace="diurnal", trace_kw={"base_rps": 2.0, "peak_rps": 30.0},
    slot_seconds=10.0, peak_rps=30.0, slo_s=2.0,
    seeds=(0, 1, 2), n_requests=50_000,
    policies=("a2c", "device_only", "full_offload"),
    episodes=300))

register_scenario(Scenario(
    name="degraded-link",
    description="uplink collapse: WiFi ceiling cut to 64 Mb/s (floor "
                "4 Mb/s) under MMPP bursts — offloading must be "
                "re-earned per decision",
    devices=4, models="cycle",
    bw_max_bps=64e6, bw_min_bps=4e6,
    trace="mmpp", trace_kw={"rate_low_rps": 2.0, "rate_high_rps": 20.0},
    slot_seconds=10.0, peak_rps=20.0, slo_s=2.0,
    seeds=(0, 1, 2), n_requests=20_000,
    policies=("a2c", "device_only", "full_offload"),
    episodes=400))

register_scenario(Scenario(
    name="tpu-submesh",
    description="TPU adaptation: 2 head submeshes serving reduced "
                "qwen2-0.5b, version axis = {bf16, w8, w4}, ICI uplink, "
                "analytical pricing",
    env="tpu", devices=2, arch="qwen2-0.5b",
    trace="poisson", trace_kw={"rate_rps": 100.0},
    slot_seconds=1.0, peak_rps=200.0, slo_s=0.05,
    seeds=(0, 1), n_requests=20_000,
    policies=("greedy_oracle", "device_only", "full_offload"),
    episodes=200))

register_scenario(Scenario(
    name="tpu-execute",
    description="tpu-submesh plus the execute cross-check: a sampled "
                "subset of requests runs through the real "
                "SplitServingEngine (act-bytes must match exactly)",
    env="tpu", devices=2, arch="qwen2-0.5b",
    trace="poisson", trace_kw={"rate_rps": 100.0},
    slot_seconds=1.0, peak_rps=200.0, slo_s=0.05,
    seeds=(0,), n_requests=2_000,
    policies=("greedy_oracle",),
    episodes=200, execute=True, sample=8))

import os

# Smoke tests and benches must see ONE device (the dry-run, and only the
# dry-run, sets --xla_force_host_platform_device_count=512 itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ---------------------------------------------------------------------------
# hypothesis shim: the property tests hard-import hypothesis, which is a dev
# extra (requirements-dev.txt). Without it, install a minimal deterministic
# stand-in BEFORE the test modules import: @given runs the test over the
# cartesian product of a few boundary samples per strategy instead of
# randomized search. Real hypothesis, when present, is used untouched.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import inspect
    import itertools
    import sys
    import types

    class _Samples:
        def __init__(self, samples):
            self.samples = list(samples)

    def _integers(min_value, max_value):
        mid = min_value + (max_value - min_value) // 2
        return _Samples(dict.fromkeys([min_value, mid, max_value]))

    def _floats(min_value, max_value):
        return _Samples(dict.fromkeys(
            [min_value, (min_value + max_value) / 2.0, max_value]))

    _MAX_COMBOS = 32

    def _given(**strategies):
        names = list(strategies)
        combos = list(itertools.product(
            *(strategies[n].samples for n in names)))
        if len(combos) > _MAX_COMBOS:
            # evenly-spaced deterministic subsample: keeps the boundary
            # mix without the cartesian blowup on many-strategy tests
            step = len(combos) / _MAX_COMBOS
            combos = [combos[int(i * step)] for i in range(_MAX_COMBOS)]

        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                for combo in combos:
                    fn(*args, **dict(zip(names, combo)), **kwargs)
            # hide the strategy params from pytest's fixture resolution
            sig = inspect.signature(fn)
            run.__signature__ = sig.replace(parameters=[
                p for n, p in sig.parameters.items() if n not in names])
            del run.__wrapped__
            return run
        return deco

    def _settings(**kwargs):
        return lambda fn: fn

    def _none():
        return _Samples([None])

    def _one_of(*strategies):
        samples = []
        for s in strategies:
            samples.extend(s.samples)
        return _Samples(dict.fromkeys(samples))

    def _sampled_from(values):
        return _Samples(values)

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.none = _none
    _st.one_of = _one_of
    _st.sampled_from = _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_batch(cfg, B=2, S=16, seed=0):
    r = np.random.default_rng(seed)
    import jax.numpy as jnp
    b = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "targets": jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.cross_attn_every:
        b["media"] = jnp.asarray(
            r.normal(size=(B, cfg.n_media_tokens, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        b["enc_frames"] = jnp.asarray(
            r.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return b

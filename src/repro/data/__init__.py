from repro.data.pipeline import (DataConfig, SyntheticLMDataset,
                                 make_train_iterator, shard_batch)

__all__ = ["DataConfig", "SyntheticLMDataset", "make_train_iterator",
           "shard_batch"]

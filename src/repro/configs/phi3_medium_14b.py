"""phi3-medium-14b [dense] — RoPE, SwiGLU, GQA (kv=10). [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219 (Phi-3 Technical Report)",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17_920,
    vocab_size=100_352,
    head_dim=128,
    rope_theta=10_000.0,
    norm="rmsnorm",
    mlp_act="swiglu",
))

"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax attention with explicit BlockSpec VMEM tiling:
grid = (B, H, num_q_blocks, num_kv_blocks); the innermost (kv) grid dim is
sequential ("arbitrary") and accumulates (m, l, acc) in VMEM scratch —
the canonical TPU flash pattern. GQA is handled in the k/v index_map
(query head h reads kv head h // group_size), so grouped keys/values are
never materialized. Causal + sliding-window masking is positional.

TPU is the TARGET; correctness is validated on CPU with interpret=True
against kernels/ref.py (pure jnp oracle). Block defaults (128) align with
the MXU's 128-lane systolic tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, nk: int, skv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, Dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < skv           # exclude zero-padded kv slots
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_new = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret", "logit_scale"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, HK, Skv, D). Returns (B, H, Sq, Dv)."""
    B, H, Sq, D = q.shape
    _, HK, Skv, Dv = v.shape
    assert H % HK == 0
    scale = logit_scale if logit_scale is not None else D ** -0.5

    bq = min(bq, Sq)
    bk = min(bk, Skv)

    def pad(x, blk, axis):
        p = (-x.shape[axis]) % blk
        if p == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, p)
        return jnp.pad(x, widths)

    q_, k_, v_ = pad(q, bq, 2), pad(k, bk, 2), pad(v, bk, 2)
    nq, nk = q_.shape[2] // bq, k_.shape[2] // bk

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk, skv=Skv)
    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            # model layout is (B, S, G, HK, Dh): query head h -> kv head h % HK
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h % HK, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j: (b, h % HK, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, q_.shape[2], Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m (running max)
            pltpu.VMEM((bq,), jnp.float32),       # l (running denom)
            pltpu.VMEM((bq, Dv), jnp.float32),    # acc (running numerator)
        ],
        interpret=interpret,
    )(q_, k_, v_)
    return out[:, :, :Sq]

"""Advantage Actor-Critic (A2C) agent — paper Sec. II-C/D, pure JAX.

Networks follow the paper: the critic has two fully connected layers of
512 and 256 features; the actor adapts the Multi-Discrete action structure
with an extra *shared* 128-wide layer per UAV device feeding the (version,
cut-point) logit pairs. Networks and rollout machinery are shared with
the PPO ablation (``repro.core.actor_critic``).

Training is episodic ("at the end of each episode, both networks' weights
undergo updates with a batch of experienced transitions"): one jitted
``train_episode`` rolls ``batch_envs`` parallel env instances for
``episode_len`` slots with vmap-over-scan — per-env reset keys and
per-env domain-randomized task traces — then applies one mean-gradient
A2C update (n-step discounted returns, per-env advantage baseline,
entropy bonus) with AdamW. ``batch_envs=1`` is the paper's exact
single-episode update; larger values trade nothing but memory for
episodes/s and scenario diversity per update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import actor_critic as net
from repro.core.actor_critic import (actor_apply, critic_apply,  # noqa: F401
                                     greedy_actions, init_agent,
                                     logp_entropy, plan_agent,
                                     sample_actions)
from repro.core.env import EnvConfig, ProfileTables
from repro.obs import jaxmon, traindiag
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class A2CConfig:
    gamma: float = 0.95
    lr: float = 7e-4
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    episodes: int = 300         # update steps; each uses batch_envs episodes
    batch_envs: int = 1         # parallel env instances per update (vmap)
    hidden1: int = 512      # paper
    hidden2: int = 256      # paper
    uav_head: int = 128     # paper: shared per-UAV layer


def make_train_episode(env_cfg: EnvConfig, tables: ProfileTables,
                       ac: A2CConfig, model_ids=None):
    """Returns jitted (params, opt_state, rng[, task_seq]) ->
    (params, opt_state, stats).

    ``task_seq``, when given, is an (episode_len, n) array — or
    (batch_envs, episode_len, n) for per-env domain-randomized traces —
    of per-slot offered load in [0, 1] that replaces the env's Bernoulli
    task draw (env_step's next_task hook), used to train the agent
    against trace-driven traffic (repro.sim.traces)."""
    opt = AdamWConfig(lr=ac.lr, weight_decay=0.0, warmup_steps=0,
                      total_steps=ac.episodes, grad_clip=1.0,
                      min_lr_ratio=1.0)
    n = env_cfg.n_uavs
    E = max(int(ac.batch_envs), 1)
    rollout = net.make_rollout(env_cfg, tables)

    def loss_fn(params, traj, rets):
        """Mean A2C loss over the (E, T) batch -> mean gradient across E
        worlds. The networks are evaluated over one flat (E*T,) sample
        batch (plain GEMMs thread better than E-batched ones on CPU);
        the advantage baseline is then normalized per env over its own
        episode, matching the paper's per-episode update."""
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), traj)

        def per_step(obs, actions, valid):
            lp, ent = logp_entropy(params, obs, actions, valid)
            return lp, ent, critic_apply(params, obs)
        lp, ent, values = jax.vmap(per_step)(
            flat["obs"], flat["actions"], flat["valid"])
        lp = lp.reshape(rets.shape)
        values = values.reshape(rets.shape)
        adv = rets - values
        adv_n = ((adv - jnp.mean(adv, axis=1, keepdims=True))
                 / (jnp.std(adv, axis=1, keepdims=True) + 1e-6))
        actor_loss = -jnp.mean(lp * jax.lax.stop_gradient(adv_n))
        critic_loss = 0.5 * jnp.mean(jnp.square(adv))
        loss = (actor_loss + ac.value_coef * critic_loss
                - ac.entropy_coef * jnp.mean(ent))
        return loss, {"actor_loss": actor_loss, "critic_loss": critic_loss,
                      "entropy": jnp.mean(ent) / n,
                      # learner-health panel (repro.obs.traindiag):
                      # pre-normalization advantage stats, critic fit,
                      # and the old-policy logp for post-update KL
                      "adv_mean": jnp.mean(adv), "adv_std": jnp.std(adv),
                      "explained_var": traindiag.explained_variance(
                          rets, values),
                      "logp_old": lp}

    @jax.jit
    def train_episode(params, opt_state, rng, task_seq=None):
        jaxmon.count_trace("train.a2c")
        task_seq = net.prepare_task_seq(task_seq, E)
        _, traj, bootstrap = net.run_batched_episodes(
            env_cfg, tables, rollout, params, rng, E,
            model_ids=model_ids, task_seq=task_seq)
        rets = jax.vmap(net.discounted_returns, in_axes=(0, 0, None))(
            traj["reward"], bootstrap, ac.gamma)
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, traj, rets)
        lp_old = stats.pop("logp_old")
        params, opt_state, om = adamw_update(opt, params, grads, opt_state)
        # approx-KL needs the *updated* policy's logp on the same batch:
        # one extra evaluation pass, same shapes, no new trace
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), traj)
        lp_new, _ = jax.vmap(
            lambda o, a, v: logp_entropy(params, o, a, v))(
                flat["obs"], flat["actions"], flat["valid"])
        stats = dict(stats, loss=loss,
                     episode_reward=jnp.mean(jnp.sum(traj["reward"], -1)),
                     mean_reward=jnp.mean(traj["reward"]),
                     final_battery=jnp.mean(traj["battery"][:, -1]),
                     grad_norm=om["grad_norm"],
                     approx_kl=traindiag.approx_kl(
                         lp_old, lp_new.reshape(lp_old.shape)) / n)
        return params, opt_state, stats

    return train_episode


def train(env_cfg: EnvConfig, tables: ProfileTables, ac: A2CConfig,
          rng, model_ids=None, log_every: int = 0, task_sampler=None):
    """``task_sampler(episode) -> (episode_len, n_uavs)`` array, when
    given, supplies each episode's offered-load sequence (trace-driven
    training; see controller.train_agent's ``trace`` argument). With
    ``ac.batch_envs = E > 1`` each update consumes E sampled sequences
    (episode indices ep*E .. ep*E+E-1) — per-env domain randomization."""
    params = init_agent(env_cfg, tables, ac, rng)
    opt_state = adamw_init(params)
    step = make_train_episode(env_cfg, tables, ac, model_ids=model_ids)
    E = max(int(ac.batch_envs), 1)
    history = []
    for ep in range(ac.episodes):
        rng, k = jax.random.split(rng)
        if task_sampler is None:
            params, opt_state, stats = step(params, opt_state, k)
        else:
            params, opt_state, stats = step(
                params, opt_state, k, net.stack_task_seqs(task_sampler,
                                                          ep, E))
        history.append({k2: float(v) for k2, v in stats.items()})
        if log_every and (ep + 1) % log_every == 0:
            print(f"ep {ep+1:4d} reward={history[-1]['mean_reward']:+.4f} "
                  f"loss={history[-1]['loss']:+.4f}", flush=True)
    return params, history

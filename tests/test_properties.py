"""Hypothesis property tests on system invariants: ring-buffer caches,
MoE routing/capacity, chunked-attention equivalence, reward weights."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.attention import (ring_from_prefill, ring_write_step,
                                    slot_positions)
from repro.models.attention_core import chunked_attention, plain_attention


# --------------------------------------------------------------------------
# ring-buffer cache invariants
# --------------------------------------------------------------------------

@given(cache_len=st.integers(2, 16), pos=st.integers(0, 64))
@settings(max_examples=60, deadline=None)
def test_slot_positions_invariants(cache_len, pos):
    sp = np.asarray(slot_positions(jnp.int32(pos), cache_len))
    # every slot holds a position <= pos, congruent to its index mod C,
    # and within the last C positions (or empty)
    for s, p in enumerate(sp):
        assert p <= pos
        if p >= 0:
            assert p % cache_len == s
            assert pos - p < cache_len
    # the current position is always present
    assert pos in sp.tolist()
    # number of valid slots = min(pos+1, C)
    assert int((sp >= 0).sum()) == min(pos + 1, cache_len)


@given(S=st.integers(1, 24), C=st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_ring_from_prefill_matches_stepwise_writes(S, C):
    """Bulk prefill cache construction == writing tokens one at a time."""
    vals = jnp.arange(S, dtype=jnp.float32)[None, :, None]   # (1, S, 1)
    bulk = ring_from_prefill(vals, C)
    step = jnp.zeros((1, C, 1), jnp.float32)
    for p in range(S):
        step = ring_write_step(step, vals[:, p], jnp.int32(p))
    if S >= C:
        np.testing.assert_array_equal(np.asarray(bulk), np.asarray(step))
    else:
        np.testing.assert_array_equal(np.asarray(bulk[:, :S]),
                                      np.asarray(step[:, :S]))


# --------------------------------------------------------------------------
# chunked == plain attention (the internal flash reference)
# --------------------------------------------------------------------------

@given(Sq=st.integers(4, 48), window=st.one_of(st.none(),
                                               st.integers(2, 16)),
       qc=st.sampled_from([4, 8, 16]), kc=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_chunked_attention_equals_plain(Sq, window, qc, kc, seed):
    r = np.random.default_rng(seed)
    B, H, HK, D = 1, 2, 1, 8
    q = jnp.asarray(r.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, Sq, HK, D)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, Sq, HK, D)), jnp.float32)
    pos = jnp.arange(Sq, dtype=jnp.int32)
    want = plain_attention(q, k, v, q_positions=pos, kv_positions=pos,
                           causal=True, window=window)
    got = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, window=window, q_chunk=qc,
                            kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# MoE routing invariants
# --------------------------------------------------------------------------

@given(T=st.integers(2, 32), seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_moe_routing_invariants(T, seed):
    from repro.configs import get_config
    from repro.models import params as pp
    from repro.models.moe import _capacity, _route, plan_moe

    cfg = get_config("mixtral-8x22b").reduced()
    p = pp.materialize(plan_moe(cfg), jax.random.key(seed), cfg.pdtype)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(1, T, cfg.d_model)), jnp.float32)
    top_p, top_e, pos, keep, sel, aux = _route(cfg, p, x)
    C = _capacity(T, cfg)
    # normalized combine weights sum to 1 per token
    np.testing.assert_allclose(np.asarray(top_p.sum(-1)), 1.0, rtol=1e-5)
    # expert ids in range, positions below capacity when kept
    assert int(top_e.max()) < cfg.n_experts and int(top_e.min()) >= 0
    kept_pos = np.asarray(pos)[np.asarray(keep)]
    if kept_pos.size:
        assert kept_pos.max() < C
    # no two kept (token, slot) pairs share an (expert, position) cell
    e_np, p_np, k_np = (np.asarray(top_e).ravel(), np.asarray(pos).ravel(),
                        np.asarray(keep).ravel())
    cells = [(e, q) for e, q, kk in zip(e_np, p_np, k_np) if kk]
    assert len(cells) == len(set(cells))
    # Switch LB loss hovers near 1 at uniform routing; finite-sample dips
    # are expected — only guard against degenerate (<0.5) values
    assert float(aux) >= 0.5


@given(T=st.sampled_from([4, 8, 16]), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_moe_einsum_gather_equivalence(T, seed):
    from repro.configs import get_config
    from repro.models import params as pp
    from repro.models.moe import apply_moe, plan_moe

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = pp.materialize(plan_moe(cfg), jax.random.key(seed), cfg.pdtype)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, T, cfg.d_model)), jnp.float32)
    y1, a1 = apply_moe(cfg, p, x)
    y2, a2 = apply_moe(cfg.with_overrides(moe_impl="gather"), p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


# --------------------------------------------------------------------------
# profile tables
# --------------------------------------------------------------------------

@given(cut_frac=st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_cut_bytes_positive_and_bounded(cut_frac):
    from repro.core.profiles import paper_profiles
    profs = paper_profiles()
    v = profs["vgg"].versions[1]
    cut = int(cut_frac * v.n_layers)
    b = v.cut_bytes(cut)
    assert 0 <= b <= 224 * 224 * 64 * 4 * 4   # bounded by widest activation

"""Device power parameters (paper Eqs. 1-3) + UAV kinetic power model [12].

The per-request compute/transmit energy formulas live in
``repro.core.pricing`` (the single cost core) and are re-exported here;
``kinetic_power`` stays local because it is a per-slot airframe term,
not part of request pricing.

Kinetic coefficients follow Stolaroff et al., "Energy use and life cycle
greenhouse gas emissions of drones for commercial package delivery"
(Nature Comm. 2018), scaled to the Aurelia X4 Standard class quadrotor the
paper simulates. Compute/transmit constants follow the Jetson TX2 + USRP
WiFi/LTE testbed regime.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.pricing import compute_energy, transmit_energy

__all__ = ["DevicePower", "kinetic_power", "compute_energy",
           "transmit_energy"]


@dataclasses.dataclass(frozen=True)
class DevicePower:
    # kinetic power draw (W) per activity [12], Aurelia X4-class
    p_forward: float = 210.0
    p_vertical: float = 305.0
    p_rotate: float = 175.0
    p_hover: float = 165.0
    # computation (Jetson TX2 under DNN load)
    p_compute: float = 10.0
    # radio transmit power bounds (USRP B210 WiFi/LTE)
    p_tx_min: float = 0.5
    p_tx_max: float = 2.0
    # battery (Aurelia X4 ~ 710 Wh full; mission share keeps episodes short)
    battery_wh: float = 90.0

    @property
    def battery_j(self) -> float:
        return self.battery_wh * 3600.0


def kinetic_power(p: DevicePower, fwd, vert, rot):
    """Average kinetic power (W) for an activity mix over the slot.
    fwd/vert/rot are fractions; the remainder hovers."""
    hover = jnp.clip(1.0 - fwd - vert - rot, 0.0, 1.0)
    return (fwd * p.p_forward + vert * p.p_vertical + rot * p.p_rotate
            + hover * p.p_hover)

"""Online adaptation: windowed replay + jitted incremental updates +
policy hot-swap, closing the controller->serving loop under drift.

The fleet loop (``repro.sim.fleet``) captures one *measured* transition
per decision epoch — the observation the controller actually decided
from, the actions it took, its behavior log-prob, and the epoch reward
priced under the **current regime's** physics — into a windowed replay
buffer. On the configured cadence an incremental update step (one jit,
reusing ``core.actor_critic``'s return/GAE and log-prob machinery for
both the A2C and PPO objectives) improves the parameters on the recent
window, and the new parameters hot-swap into the serving loop through
the PR-4 ``Policy.jitted()`` param-swap path (``TrainablePolicy``
specializes it to re-bind without re-tracing).

Adaptation is gated by the drift monitor (``repro.online.monitor``):
under ``gate="drift"`` a Page-Hinkley trigger opens a burst of
``burst_epochs`` during which the policy explores (per-device
epsilon-mix of logit sampling over argmax) and updates run; outside
bursts the policy serves greedily and spends zero update compute —
re-arming while the EWMA regret vs the per-regime oracle stays high.
``gate="always"`` adapts continuously; ``gate="off"`` only monitors.

Everything is deterministic given the simulation seed: updates consume
no RNG (recorded actions, no sampling inside the loss), exploration
draws use the fleet's per-epoch policy key, and the replay window
flushes at regime boundaries so stale-physics rewards never leak into
the new regime's gradient (tested in ``tests/test_online.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro import obs
from repro.obs import jaxmon
from repro.online.monitor import DriftMonitor


def _normalize(x, mask):
    """Mask-weighted standardization (dead devices excluded)."""
    import jax.numpy as jnp

    denom = jnp.maximum(jnp.sum(mask), 1.0)
    mean = jnp.sum(x * mask) / denom
    var = jnp.sum(jnp.square(x - mean) * mask) / denom
    return (x - mean) / (jnp.sqrt(var) + 1e-6)


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    """Update-cadence / compute-budget knobs for online adaptation."""
    window: int = 64            # replay window, epochs
    min_window: int = 8         # don't update on fewer transitions
    update_every: int = 1       # epochs between incremental updates
    updates_per_step: int = 1   # grad steps per update (compute budget)
    # Gentle steps: Adam moves ~lr per weight per step, and per-weight
    # shifts compound through the head layers into O(100x) logit swings;
    # 1e-3 re-aligns a regime in ~30 updates while 5e-3+ saturates the
    # softmax into an arbitrary action within a burst (measured).
    lr: float = 1e-3
    gamma: float = 0.5          # short horizon: slot scores are immediate
    entropy_coef: float = 0.02  # resists softmax saturation mid-burst
    # Freeze the actor trunk (l1/l2) and adapt only the light per-UAV
    # heads (+ the critic): Adam's scale-free steps over the highly
    # correlated sliding-window gradients otherwise walk *every* weight
    # ~lr per update, and after ~100 updates the 4-layer composition
    # blows the logits up (catastrophic forgetting in minutes). Head-only
    # adaptation bounds the damage to one linear map per device — and is
    # the cheap-compute choice an edge deployment would make anyway.
    adapt_trunk: bool = False
    value_coef: float = 0.5
    clip: float = 0.2           # PPO surrogate clip (algo="ppo")
    algo: str = "a2c"           # "a2c" | "ppo" (set from the policy)
    # drift gating
    gate: str = "drift"         # "drift" | "always" | "off"
    burst_epochs: int = 60      # adaptation burst length after a trigger
    # per-device probability of sampling (vs argmax) during a burst:
    # diverse enough to feed the gradient, cheap enough that exploring
    # a catastrophic action doesn't dominate the serving metrics
    explore_eps: float = 0.25
    # Page-Hinkley only fires on reward *drops*; a policy that climbed
    # out of the hole but stalled short of the regime's oracle would
    # otherwise freeze mid-adaptation. While the EWMA regret exceeds
    # regret_frac * |oracle|, expired bursts re-arm.
    regret_frac: float = 0.3
    ewma: float = 0.2
    ph_delta: float = 0.01
    ph_lambda: float = 0.5


class ReplayWindow:
    """Windowed buffer of measured transitions, flushed at regime
    boundaries: a transition priced under the old physics is a wrong
    label for the new regime's gradient, so the window only ever holds
    consecutive same-regime epochs (newest last)."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._buf = collections.deque(maxlen=self.capacity)
        self.regime: Optional[int] = None

    def push(self, item: Dict, regime: int):
        if regime != self.regime:
            self._buf.clear()
            self.regime = regime
        self._buf.append(item)

    def __len__(self) -> int:
        return len(self._buf)

    def tail(self, n: int) -> Dict[str, np.ndarray]:
        """Stack the newest ``n`` transitions into (T, ...) arrays."""
        items = list(self._buf)[-n:]
        return {k: np.stack([it[k] for it in items])
                for k in items[0]}


def _bucket(n: int, min_window: int, capacity: int) -> int:
    """Largest min_window * 2^k <= n (capped at capacity): the update
    jit specializes on window length, so lengths are quantized to a few
    power-of-two buckets instead of retracing every epoch."""
    b = min_window
    while b * 2 <= min(n, capacity):
        b *= 2
    return b


class OnlineLearner:
    """Owns the window, the monitor, the optimizer state and the jitted
    update step for one trainable policy inside one fleet simulation."""

    def __init__(self, policy, cfg: OnlineConfig, model_ids):
        if not policy.trainable:
            raise ValueError(f"online adaptation needs a trainable policy; "
                             f"{policy.name!r} is not")
        self.policy = policy
        self.cfg = cfg
        self.window = ReplayWindow(cfg.window)
        self.monitor = DriftMonitor(ewma=cfg.ewma, ph_delta=cfg.ph_delta,
                                    ph_lambda=cfg.ph_lambda)
        self.updates = 0
        self.bursts = 0
        self.burst_until = -1
        self._o_ew = None
        self._opt_state = None
        self._update_jits: Dict[int, object] = {}
        self._capture_jits: Dict[float, object] = {}
        self._env_cfg, self._tables = policy.env_cfg, policy.tables
        self._valid = policy.tables.version_valid[np.asarray(model_ids)]

    def _capture(self, eps: float):
        """Jitted capture, specialized per exploration rate: the
        behavior density of the taken (version, cut) pair under the
        epsilon-mixed acting policy is eps * pi(a) + (1 - eps) *
        1[a == argmax] — recording the bare softmax log pi(a) instead
        would weight the mostly-argmax window as if it were sampled
        on-policy and bias the PPO ratio."""
        if eps in self._capture_jits:
            return self._capture_jits[eps]
        import jax
        import jax.numpy as jnp

        from repro.core.actor_critic import (device_logp_entropy,
                                             greedy_actions)
        from repro.core.env import observe

        env_cfg, tables, valid = self._env_cfg, self._tables, self._valid

        def capture(params, state, actions):
            jaxmon.count_trace("online.capture")
            ob = observe(env_cfg, tables, state).reshape(-1)
            lp, _ = device_logp_entropy(params, ob, actions, valid)
            if eps <= 0.0:
                # deterministic argmax behavior: density 1 for the
                # taken action
                return ob, jnp.zeros_like(lp)
            greedy = greedy_actions(params, ob, valid)
            is_greedy = jnp.all(actions == greedy, axis=-1)
            p = eps * jnp.exp(lp) + (1.0 - eps) * is_greedy
            return ob, jnp.log(jnp.maximum(p, 1e-30))

        self._capture_jits[eps] = jax.jit(capture)
        return self._capture_jits[eps]

    # -- per-epoch hooks (called from the fleet loop) ----------------------

    def observe_transition(self, state, actions, rewards, mask,
                           regime: int):
        """Record one measured transition: the decided-from observation,
        the taken actions, *per-device* rewards (the per-UAV weighted
        scores before Eq. 8's fleet mean — per-device credit is what
        gives the incremental gradient a direction when every epoch is
        equally bad on average), the alive mask, and the behavior
        log-density fixed at capture time (the PPO surrogate needs it)."""
        eps = float(getattr(self.policy, "explore", 0.0))
        obs, lp = self._capture(eps)(self.policy.params, state,
                                     np.asarray(actions))
        self.window.push({"obs": np.asarray(obs),
                          "actions": np.asarray(actions, np.int32),
                          "logp": np.asarray(lp, np.float32),
                          "reward": np.asarray(rewards, np.float32),
                          "mask": np.asarray(mask, np.float32)}, regime)

    def step(self, epoch: int, reward: float,
             oracle_reward: Optional[float] = None) -> bool:
        """Advance gating and maybe run an incremental update; returns
        True when the policy's params were hot-swapped this epoch.
        ``oracle_reward`` (the per-regime greedy oracle's epoch reward,
        supplied by the fleet loop) re-arms expired bursts while the
        policy is still far from the regime's achievable level."""
        cfg = self.cfg
        triggered = self.monitor.update(reward)
        if oracle_reward is not None:
            o = float(oracle_reward)
            self._o_ew = o if self._o_ew is None \
                else self._o_ew + cfg.ewma * (o - self._o_ew)
            # monitor.level is the same-alpha EWMA of the reward stream
            gap = self._o_ew - self.monitor.level
            if gap > cfg.regret_frac * max(abs(self._o_ew), 1e-9) and \
                    len(self.window) >= cfg.min_window:
                triggered = True
        # a trigger during an active burst does not extend it: each
        # burst's exploration cost is bounded, and if the regime is
        # still bad after the burst the gate simply re-arms
        if cfg.gate == "drift" and triggered and \
                epoch >= self.burst_until:
            self.burst_until = epoch + cfg.burst_epochs
            self.bursts += 1
            obs.event("online.burst_start", epoch=epoch,
                      until=self.burst_until, burst=self.bursts)
        active = cfg.gate == "always" or (
            cfg.gate == "drift" and epoch < self.burst_until)
        if hasattr(self.policy, "set_explore"):
            self.policy.set_explore(cfg.explore_eps if active else 0.0)
        if not active or epoch % cfg.update_every != 0:
            return False
        if len(self.window) < cfg.min_window:
            return False
        n = _bucket(len(self.window), cfg.min_window, cfg.window)
        with obs.span("online.update", window=n, algo=cfg.algo):
            batch = self.window.tail(n)
            params = self.policy.params
            for _ in range(cfg.updates_per_step):
                params, self._opt_state = self._update(n)(
                    params, self._opt(params), batch["obs"],
                    batch["actions"], batch["logp"], batch["reward"],
                    batch["mask"])
            self.updates += 1
            self.policy.set_params(params)
        obs.event("online.hotswap", epoch=epoch, updates=self.updates,
                  window=n)
        return True

    # -- update machinery --------------------------------------------------

    def _opt(self, params):
        if self._opt_state is None:
            from repro.optim import adamw_init
            self._opt_state = adamw_init(params)
        return self._opt_state

    def _update(self, n: int):
        """Jitted incremental update specialized on window length ``n``:
        per-device n-step returns (A2C) or per-device GAE + clipped
        surrogate (PPO) over the (T, n_uavs) window — the shared
        ``core.actor_critic`` return/GAE machinery vmapped across the
        device axis — one AdamW step, constant LR. Per-device credit:
        the actor gradient weights each device's log-prob by that
        device's own advantage, masked by liveness."""
        if n in self._update_jits:
            return self._update_jits[n]
        import jax
        import jax.numpy as jnp

        from repro.core.actor_critic import (critic_apply,
                                             device_logp_entropy,
                                             discounted_returns, gae)
        from repro.optim import AdamWConfig, adamw_update

        cfg = self.cfg
        opt = AdamWConfig(lr=cfg.lr, weight_decay=0.0, warmup_steps=0,
                          total_steps=1, grad_clip=1.0, min_lr_ratio=1.0)
        valid = self._valid

        def loss_fn(params, obs, actions, old_logp, rewards, mask):
            def per_step(o, a):
                lp, ent = device_logp_entropy(params, o, a, valid)
                return lp, ent, critic_apply(params, o)
            lp, ent, values = jax.vmap(per_step)(obs, actions)
            # lp/ent/rewards/mask: (T, n); values: (T,)
            # Standardize rewards over the window: drift regimes swing
            # raw scores by orders of magnitude (a congested offload's
            # latency score is ~-100x a local one's), and an O(100)
            # critic regression would dominate the global grad-norm clip
            # and starve the actor. Affine reward transforms leave the
            # normalized advantage — hence the policy gradient — intact.
            rewards = _normalize(rewards, mask) * mask
            boot = jax.lax.stop_gradient(values[-1])
            denom = jnp.maximum(jnp.sum(mask), 1.0)
            if cfg.algo == "ppo":
                advs, rets = jax.vmap(
                    gae, in_axes=(1, None, None, None, None),
                    out_axes=1)(rewards, values, boot, cfg.gamma, cfg.gamma)
                a_n = _normalize(jax.lax.stop_gradient(advs), mask)
                ratio = jnp.exp(lp - old_logp)
                surr = jnp.minimum(
                    ratio * a_n,
                    jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * a_n)
                actor_loss = -jnp.sum(surr * mask) / denom
            else:
                rets = jax.vmap(
                    discounted_returns, in_axes=(1, None, None),
                    out_axes=1)(rewards, boot, cfg.gamma)
                adv = rets - values[:, None]
                a_n = _normalize(jax.lax.stop_gradient(adv), mask)
                actor_loss = -jnp.sum(lp * a_n * mask) / denom
                rets = jax.lax.stop_gradient(rets)
            # the critic baselines the fleet-mean per-device return
            target = jnp.sum(rets * mask, -1) \
                / jnp.maximum(jnp.sum(mask, -1), 1.0)
            critic_loss = 0.5 * jnp.mean(
                jnp.square(jax.lax.stop_gradient(target) - values))
            entropy = jnp.sum(ent * mask) / denom
            return (actor_loss + cfg.value_coef * critic_loss
                    - cfg.entropy_coef * entropy)

        @jax.jit
        def update(params, opt_state, obs, actions, old_logp, rewards,
                   mask):
            jaxmon.count_trace("online.update")
            grads = jax.grad(loss_fn)(params, obs, actions, old_logp,
                                      rewards, mask)
            if not cfg.adapt_trunk:
                grads = dict(grads, actor=dict(
                    grads["actor"],
                    l1=jax.tree.map(jnp.zeros_like, grads["actor"]["l1"]),
                    l2=jax.tree.map(jnp.zeros_like, grads["actor"]["l2"])))
            params, opt_state, _ = adamw_update(opt, params, grads,
                                                opt_state)
            return params, opt_state

        self._update_jits[n] = update
        return update

    # -- bookkeeping --------------------------------------------------------

    def summary(self) -> Dict:
        return {"updates": self.updates,
                "triggers": self.monitor.triggers,
                "bursts": self.bursts,
                "algo": self.cfg.algo, "gate": self.cfg.gate,
                "window": self.cfg.window,
                "update_every": self.cfg.update_every}

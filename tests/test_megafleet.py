"""repro.sim.megafleet: the vectorized fleet engines.

The load-bearing guarantee: ``engine="vectorized"`` is *bit-identical*
to the ``engine="loop"`` oracle under the same seed — same rng stream
consumption, same padded-Lindley arithmetic, same device-order metric
recording — across stationary presets AND a drift schedule. The scan
engine trades bitwise parity for a fused jit (jax PRNG for world noise,
float32, histogram percentiles), so its contract is determinism +
statistical agreement + identical workload accounting.
"""
import numpy as np
import pytest

from repro.policies import build_policy
from repro.scenarios import get_scenario
from repro.sim import (AnalyticalBackend, EpochLog, FleetConfig,
                       presample_counts, simulate)


def _world(preset):
    sc = get_scenario(preset)
    env_cfg, tables, model_ids, bf = sc.build_env()
    return sc, env_cfg, tables, model_ids, bf


def _run(sc, env_cfg, tables, model_ids, bf, policy, engine, *,
         n_requests, seed=0, schedule=None, **fl_kw):
    fl = FleetConfig(slo_s=sc.slo_s, engine=engine, **fl_kw)
    backend = bf() if engine != "scan" else None
    return simulate(env_cfg, tables, policy, sc.build_trace(),
                    n_requests=n_requests, seed=seed, fleet=fl,
                    backend=backend, model_ids=model_ids,
                    schedule=schedule)


def _assert_bit_identical(a, b):
    assert np.array_equal(a.selection_hist, b.selection_hist)
    assert a.served == b.served
    assert a.epochs == b.epochs
    assert a.metrics.dropped == b.metrics.dropped
    assert np.array_equal(a.metrics.latencies_s, b.metrics.latencies_s)
    assert np.array_equal(a.metrics.energies_j, b.metrics.energies_j)
    assert np.array_equal(a.metrics.devices, b.metrics.devices)
    assert a.summary == b.summary
    assert list(a.epoch_log) == list(b.epoch_log)


# --------------------------------------------------------------------------
# loop vs vectorized: bit-exact parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("preset,policy_name", [
    ("diurnal-fleet", "device_only"),
    ("degraded-link", "greedy_oracle"),
    ("paper-mmpp-burst", "full_offload"),
])
def test_vectorized_matches_loop_bitexact(preset, policy_name):
    sc, env_cfg, tables, mids, bf = _world(preset)
    pol = build_policy(policy_name, env_cfg, tables)
    a = _run(sc, env_cfg, tables, mids, bf, pol, "loop",
             n_requests=4000, seed=3)
    b = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
             n_requests=4000, seed=3)
    assert a.served >= 4000
    _assert_bit_identical(a, b)


def test_vectorized_matches_loop_under_drift():
    """The regime-switch path (cached per-regime backends, trace
    scaling, battery side effects) stays bit-identical too."""
    sc, env_cfg, tables, mids, bf = _world("link-brownout")
    pol = build_policy("device_only", env_cfg, tables)
    sched = sc.build_schedule()
    a = _run(sc, env_cfg, tables, mids, bf, pol, "loop",
             n_requests=40_000, seed=1, schedule=sched)
    b = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
             n_requests=40_000, seed=1, schedule=sched)
    assert {e["regime"] for e in a.epoch_log} >= {0, 1}  # drift crossed
    _assert_bit_identical(a, b)
    assert a.adaptation == b.adaptation


def test_vectorized_matches_loop_with_dead_devices():
    """Dead devices must keep consuming the offset draws (stream-order
    invariance) while their arrivals drop — on both engines alike. The
    device-churn schedule kills devices 0-1 deterministically."""
    sc, env_cfg, tables, mids, bf = _world("device-churn")
    pol = build_policy("device_only", env_cfg, tables)
    sched = sc.build_schedule()
    a = _run(sc, env_cfg, tables, mids, bf, pol, "loop",
             n_requests=30_000, seed=0, schedule=sched)
    b = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
             n_requests=30_000, seed=0, schedule=sched)
    assert a.metrics.dropped > 0
    _assert_bit_identical(a, b)


def test_selection_hist_is_int64_and_accounts_every_request():
    sc, env_cfg, tables, mids, bf = _world("diurnal-fleet")
    pol = build_policy("device_only", env_cfg, tables)
    r = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
             n_requests=3000)
    assert r.selection_hist.dtype == np.int64
    assert r.selection_hist.sum() == r.served - r.metrics.dropped


# --------------------------------------------------------------------------
# scan engine
# --------------------------------------------------------------------------

def test_scan_deterministic_and_close_to_vectorized():
    sc, env_cfg, tables, mids, bf = _world("diurnal-fleet")
    pol = build_policy("device_only", env_cfg, tables)
    s1 = _run(sc, env_cfg, tables, mids, bf, pol, "scan",
              n_requests=15_000)
    s2 = _run(sc, env_cfg, tables, mids, bf, pol, "scan",
              n_requests=15_000)
    assert s1.summary == s2.summary
    assert np.array_equal(s1.selection_hist, s2.selection_hist)

    v = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
             n_requests=15_000)
    # identical workload accounting: the trace rng stream is shared, so
    # epochs/served match exactly; a static policy picks identical
    # actions, so the selection histogram matches exactly too
    assert s1.epochs == v.epochs
    assert s1.served == v.served
    assert np.array_equal(s1.selection_hist, v.selection_hist)
    # world noise comes from a jax PRNG instead of the numpy stream, so
    # metric agreement is statistical (f32 + log-binned percentiles)
    assert abs(s1.summary["slo_attainment"]
               - v.summary["slo_attainment"]) < 0.05
    assert s1.summary["mean"] == pytest.approx(v.summary["mean"],
                                               rel=0.15)
    assert s1.summary["energy_j"] == pytest.approx(
        v.summary["energy_j"], rel=0.01)
    assert len(s1.epoch_log) == s1.epochs
    assert s1.epoch_log[0]["arrivals"] == v.epoch_log[0]["arrivals"]


def test_scan_shard_matches_unsharded():
    """shard=True over a 1-device mesh must be bit-identical to
    shard=False (per-shard noise keys fold in the shard index; the
    unsharded path folds index 0)."""
    sc, env_cfg, tables, mids, bf = _world("diurnal-fleet")
    pol = build_policy("device_only", env_cfg, tables)
    a = _run(sc, env_cfg, tables, mids, bf, pol, "scan", n_requests=6000)
    b = _run(sc, env_cfg, tables, mids, bf, pol, "scan", n_requests=6000,
             shard=True)
    assert a.summary == b.summary
    assert np.array_equal(a.selection_hist, b.selection_hist)


def test_scan_rejects_unsupported_modes():
    sc, env_cfg, tables, mids, bf = _world("link-brownout")
    pol = build_policy("device_only", env_cfg, tables)
    with pytest.raises(ValueError, match="stationary"):
        _run(sc, env_cfg, tables, mids, bf, pol, "scan",
             n_requests=1000, schedule=sc.build_schedule())
    with pytest.raises(ValueError, match="valid engines"):
        _run(sc, env_cfg, tables, mids, bf, pol, "warp", n_requests=1000)
    with pytest.raises(ValueError, match="shard"):
        _run(sc, env_cfg, tables, mids, bf, pol, "loop",
             n_requests=1000, shard=True)


# --------------------------------------------------------------------------
# satellites: presample, EpochLog, per-regime backend cache
# --------------------------------------------------------------------------

def test_presample_counts_matches_stream():
    sc = get_scenario("diurnal-fleet")
    trace = sc.build_trace()
    r1 = np.random.default_rng(7)
    counts = presample_counts(trace, r1, 8, sc.slot_seconds, 5000, 1000)
    r2 = np.random.default_rng(7)
    stream = trace.stream(r2, 8, sc.slot_seconds)
    served = 0
    for t in range(counts.shape[0]):
        assert np.array_equal(counts[t], next(stream))
        served += int(counts[t].sum())
    assert served >= 5000
    assert int(counts[:-1].sum()) < 5000   # stops at the crossing epoch


def test_epoch_log_dict_view():
    log = EpochLog()
    for i in range(20):
        log.append({"epoch": i, "arrivals": 10 * i, "queue_jobs": 0.5 * i})
    assert len(log) == 20 and bool(log)
    assert log[0] == {"epoch": 0, "arrivals": 0, "queue_jobs": 0.0}
    assert log[-1]["epoch"] == 19
    assert [e["arrivals"] for e in log[5:8]] == [50, 60, 70]
    assert sum(e["epoch"] for e in log) == sum(range(20))
    assert log.column("arrivals").dtype == np.int64
    assert isinstance(log[3]["queue_jobs"], float)
    with pytest.raises(IndexError):
        log[20]
    assert not EpochLog()


def test_epoch_log_stride_and_cap():
    log = EpochLog(stride=3, cap=4)
    for i in range(30):
        log.append({"epoch": i})
    assert [e["epoch"] for e in log] == [0, 3, 6, 9]
    bulk = EpochLog(stride=3, cap=4)
    bulk.extend_columns(epoch=np.arange(30))
    assert [e["epoch"] for e in bulk] == [e["epoch"] for e in log]
    with pytest.raises(ValueError):
        EpochLog(stride=0)


def test_fleet_log_stride_and_cap_thread_through():
    sc, env_cfg, tables, mids, bf = _world("diurnal-fleet")
    pol = build_policy("device_only", env_cfg, tables)
    full = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
                n_requests=8000)
    strided = _run(sc, env_cfg, tables, mids, bf, pol, "vectorized",
                   n_requests=8000, log_stride=2, log_cap=2)
    assert full.epochs >= 4
    assert [e["epoch"] for e in strided.epoch_log] == [0, 2]
    assert strided.summary == full.summary   # logging never alters physics


def test_schedule_compile_caches_backends():
    sc = get_scenario("link-brownout")
    env_cfg, tables, mids, bf = sc.build_env()
    sched = sc.build_schedule()
    regimes = sched.compile(env_cfg, tables)
    assert regimes[0].backend is None          # base: fleet's own backend
    patched = [r for r in regimes if r.env_cfg is not env_cfg]
    assert patched, "schedule has no patched regime to cache for"
    for r in patched:
        assert isinstance(r.backend, AnalyticalBackend)
        assert r.backend.env_cfg is r.env_cfg
    # tables-less compile (older call sites) stays backend-free
    assert all(r.backend is None for r in sched.compile(env_cfg))

"""Advantage Actor-Critic (A2C) agent — paper Sec. II-C/D, pure JAX.

Networks follow the paper: the critic has two fully connected layers of
512 and 256 features; the actor adapts the Multi-Discrete action structure
with an extra *shared* 128-wide layer per UAV device feeding the (version,
cut-point) logit pairs.

Training is episodic ("at the end of each episode, both networks' weights
undergo updates with a batch of experienced transitions"): one jitted
``train_episode`` rolls the env for ``episode_len`` slots with lax.scan,
then applies a batched A2C update (n-step discounted returns, advantage
baseline, entropy bonus) with AdamW.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.env import (EnvConfig, ProfileTables, env_reset, env_step,
                            observe)
from repro.models import params as pp
from repro.models.params import P
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class A2CConfig:
    gamma: float = 0.95
    lr: float = 7e-4
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    episodes: int = 300
    hidden1: int = 512      # paper
    hidden2: int = 256      # paper
    uav_head: int = 128     # paper: shared per-UAV layer


def plan_agent(cfg: EnvConfig, tables: ProfileTables, ac: A2CConfig):
    n = cfg.n_uavs
    obs = n * cfg.obs_dim_per_uav
    V, K = tables.n_versions, tables.n_cuts
    h1, h2, hu = ac.hidden1, ac.hidden2, ac.uav_head
    dense = lambda i, o: {"w": P((i, o), (None, None)),
                          "b": P((o,), (None,), "zeros")}
    per_uav = lambda i, o: {"w": P((n, i, o), (None, None, None)),
                            "b": P((n, o), (None, None), "zeros")}
    return {
        "actor": {"l1": dense(obs, h1), "l2": dense(h1, h2),
                  "uav": per_uav(h2, hu),
                  "ver": per_uav(hu, V), "cut": per_uav(hu, K)},
        "critic": {"l1": dense(obs, h1), "l2": dense(h1, h2),
                   "out": dense(h2, 1)},
    }


def init_agent(cfg: EnvConfig, tables: ProfileTables, ac: A2CConfig, rng):
    return pp.materialize(plan_agent(cfg, tables, ac), rng,
                          jnp.dtype("float32"))


def _dense(p, x):
    return x @ p["w"] + p["b"]


def actor_apply(params, obs_flat):
    """obs_flat: (obs_total,) -> logits_v (n, V), logits_c (n, K)."""
    a = params["actor"]
    h = jax.nn.relu(_dense(a["l1"], obs_flat))
    h = jax.nn.relu(_dense(a["l2"], h))
    hu = jax.nn.relu(jnp.einsum("i,nio->no", h, a["uav"]["w"])
                     + a["uav"]["b"])                       # (n, hu)
    lv = jnp.einsum("no,nov->nv", hu, a["ver"]["w"]) + a["ver"]["b"]
    lc = jnp.einsum("no,nok->nk", hu, a["cut"]["w"]) + a["cut"]["b"]
    return lv, lc


def critic_apply(params, obs_flat):
    c = params["critic"]
    h = jax.nn.relu(_dense(c["l1"], obs_flat))
    h = jax.nn.relu(_dense(c["l2"], h))
    return _dense(c["out"], h)[0]


def _mask_logits(logits, valid):
    return jnp.where(valid > 0, logits, -1e9)


def sample_actions(params, obs_flat, valid_v, rng):
    lv, lc = actor_apply(params, obs_flat)
    lv = _mask_logits(lv, valid_v)
    k1, k2 = jax.random.split(rng)
    av = jax.random.categorical(k1, lv, axis=-1)
    ac_ = jax.random.categorical(k2, lc, axis=-1)
    return jnp.stack([av, ac_], axis=-1).astype(jnp.int32)


def greedy_actions(params, obs_flat, valid_v):
    lv, lc = actor_apply(params, obs_flat)
    lv = _mask_logits(lv, valid_v)
    return jnp.stack([jnp.argmax(lv, -1), jnp.argmax(lc, -1)],
                     axis=-1).astype(jnp.int32)


def _logp_entropy(params, obs_flat, actions, valid_v):
    lv, lc = actor_apply(params, obs_flat)
    lv = _mask_logits(lv, valid_v)
    logp_v = jax.nn.log_softmax(lv, -1)
    logp_c = jax.nn.log_softmax(lc, -1)
    lp = (jnp.take_along_axis(logp_v, actions[:, :1], -1)[:, 0]
          + jnp.take_along_axis(logp_c, actions[:, 1:2], -1)[:, 0])
    ent = (-jnp.sum(jnp.exp(logp_v) * logp_v, -1)
           - jnp.sum(jnp.exp(logp_c) * logp_c, -1))
    return jnp.sum(lp), jnp.sum(ent)


def make_train_episode(env_cfg: EnvConfig, tables: ProfileTables,
                       ac: A2CConfig, model_ids=None):
    """Returns jitted (params, opt_state, rng[, task_seq]) ->
    (params, opt_state, stats).

    ``task_seq``, when given, is an (episode_len, n) array of per-slot
    offered load in [0, 1] that replaces the env's Bernoulli task draw
    (env_step's next_task hook) — used to train the agent against
    trace-driven traffic (repro.sim.traces)."""
    opt = AdamWConfig(lr=ac.lr, weight_decay=0.0, warmup_steps=0,
                      total_steps=ac.episodes, grad_clip=1.0,
                      min_lr_ratio=1.0)
    n = env_cfg.n_uavs
    valid_rows = None  # computed per model assignment below

    def valid_v(state):
        return tables.version_valid[state["model_id"]]   # (n, V)

    def rollout(params, state0, rng, task_seq=None):
        def step(carry, xs):
            state = carry
            k, nxt = xs
            obs = observe(env_cfg, tables, state).reshape(-1)
            actions = sample_actions(params, obs, valid_v(state), k)
            k_env = jax.random.fold_in(k, 1)
            state2, r, info = env_step(env_cfg, tables, state, actions,
                                       k_env, next_task=nxt)
            out = {"obs": obs, "actions": actions, "reward": r,
                   "valid": valid_v(state), "alive": info["alive"],
                   "battery": info["battery"]}
            return state2, out
        keys = jax.random.split(rng, env_cfg.episode_len)
        state_T, traj = jax.lax.scan(step, state0, (keys, task_seq))
        return state_T, traj

    def returns_from(traj, bootstrap, gamma):
        def back(carry, r):
            g = r + gamma * carry
            return g, g
        _, rets = jax.lax.scan(back, bootstrap, traj["reward"], reverse=True)
        return rets

    def loss_fn(params, traj, rets):
        def per_step(obs, actions, valid):
            lp, ent = _logp_entropy(params, obs, actions, valid)
            v = critic_apply(params, obs)
            return lp, ent, v
        lp, ent, values = jax.vmap(per_step)(
            traj["obs"], traj["actions"], traj["valid"])
        adv = rets - values
        adv_n = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-6)
        actor_loss = -jnp.mean(lp * jax.lax.stop_gradient(adv_n))
        critic_loss = 0.5 * jnp.mean(jnp.square(adv))
        ent_mean = jnp.mean(ent) / n
        loss = (actor_loss + ac.value_coef * critic_loss
                - ac.entropy_coef * jnp.mean(ent))
        return loss, {"actor_loss": actor_loss, "critic_loss": critic_loss,
                      "entropy": ent_mean}

    @jax.jit
    def train_episode(params, opt_state, rng, task_seq=None):
        k0, k1, k2 = jax.random.split(rng, 3)
        state0 = env_reset(env_cfg, tables, k0, model_ids=model_ids)
        if task_seq is not None:
            # slot t's load is task_seq[t]: seed state0 with row 0 and
            # let env_step's next_task install rows 1..T-1 (last repeats)
            state0 = dict(state0, task=task_seq[0])
            task_seq = jnp.concatenate([task_seq[1:], task_seq[-1:]])
        state_T, traj = rollout(params, state0, k1, task_seq)
        obs_T = observe(env_cfg, tables, state_T).reshape(-1)
        bootstrap = critic_apply(params, obs_T)
        rets = returns_from(traj, bootstrap, ac.gamma)
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, traj, rets)
        params, opt_state, om = adamw_update(opt, params, grads, opt_state)
        stats = dict(stats, loss=loss,
                     episode_reward=jnp.sum(traj["reward"]),
                     mean_reward=jnp.mean(traj["reward"]),
                     final_battery=jnp.mean(traj["battery"][-1]),
                     grad_norm=om["grad_norm"])
        return params, opt_state, stats

    return train_episode


def train(env_cfg: EnvConfig, tables: ProfileTables, ac: A2CConfig,
          rng, model_ids=None, log_every: int = 0, task_sampler=None):
    """``task_sampler(episode) -> (episode_len, n_uavs)`` array, when
    given, supplies each episode's offered-load sequence (trace-driven
    training; see controller.train_agent's ``trace`` argument)."""
    params = init_agent(env_cfg, tables, ac, rng)
    opt_state = adamw_init(params)
    step = make_train_episode(env_cfg, tables, ac, model_ids=model_ids)
    history = []
    for ep in range(ac.episodes):
        rng, k = jax.random.split(rng)
        if task_sampler is None:
            params, opt_state, stats = step(params, opt_state, k)
        else:
            params, opt_state, stats = step(
                params, opt_state, k,
                jnp.asarray(task_sampler(ep), jnp.float32))
        history.append({k2: float(v) for k2, v in stats.items()})
        if log_every and (ep + 1) % log_every == 0:
            print(f"ep {ep+1:4d} reward={history[-1]['mean_reward']:+.4f} "
                  f"loss={history[-1]['loss']:+.4f}", flush=True)
    return params, history

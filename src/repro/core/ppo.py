"""PPO agent (beyond-paper ablation).

The paper chooses A2C "for its efficiency and effectiveness"; PPO is the
natural modern baseline to test that choice. Reuses the A2C networks and
rollout machinery; adds clipped-surrogate updates with GAE over multiple
epochs per episode batch. Compared against A2C in
``benchmarks.run --only ablation_agents``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.a2c import (A2CConfig, _logp_entropy, actor_apply,
                            critic_apply, init_agent, sample_actions)
from repro.core.env import EnvConfig, ProfileTables, env_reset, env_step, observe
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    gamma: float = 0.95
    lam: float = 0.95           # GAE
    clip: float = 0.2
    epochs: int = 4             # surrogate epochs per episode
    lr: float = 3e-4
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    episodes: int = 300
    base: A2CConfig = dataclasses.field(default_factory=A2CConfig)


def make_train_episode(env_cfg: EnvConfig, tables: ProfileTables,
                       pc: PPOConfig, model_ids=None):
    opt = AdamWConfig(lr=pc.lr, weight_decay=0.0, warmup_steps=0,
                      total_steps=pc.episodes * pc.epochs, grad_clip=1.0,
                      min_lr_ratio=1.0)
    n = env_cfg.n_uavs

    def valid_v(state):
        return tables.version_valid[state["model_id"]]

    def rollout(params, state0, rng):
        def step(state, k):
            obs = observe(env_cfg, tables, state).reshape(-1)
            valid = valid_v(state)
            actions = sample_actions(params, obs, valid, k)
            lp, _ = _logp_entropy(params, obs, actions, valid)
            v = critic_apply(params, obs)
            state2, r, info = env_step(env_cfg, tables, state, actions,
                                       jax.random.fold_in(k, 1))
            return state2, {"obs": obs, "actions": actions, "reward": r,
                            "valid": valid, "logp": lp, "value": v}
        keys = jax.random.split(rng, env_cfg.episode_len)
        return jax.lax.scan(step, state0, keys)

    def gae(traj, bootstrap):
        def back(carry, xs):
            adv_next, v_next = carry
            r, v = xs
            delta = r + pc.gamma * v_next - v
            adv = delta + pc.gamma * pc.lam * adv_next
            return (adv, v), adv
        (_, _), advs = jax.lax.scan(back, (jnp.float32(0.0), bootstrap),
                                    (traj["reward"], traj["value"]),
                                    reverse=True)
        return advs, advs + traj["value"]

    def loss_fn(params, traj, advs, rets):
        def per_step(obs, actions, valid):
            lp, ent = _logp_entropy(params, obs, actions, valid)
            return lp, ent, critic_apply(params, obs)
        lp, ent, values = jax.vmap(per_step)(
            traj["obs"], traj["actions"], traj["valid"])
        ratio = jnp.exp(lp - traj["logp"])
        a_n = (advs - jnp.mean(advs)) / (jnp.std(advs) + 1e-6)
        surr = jnp.minimum(ratio * a_n,
                           jnp.clip(ratio, 1 - pc.clip, 1 + pc.clip) * a_n)
        actor_loss = -jnp.mean(surr)
        critic_loss = 0.5 * jnp.mean(jnp.square(rets - values))
        loss = (actor_loss + pc.value_coef * critic_loss
                - pc.entropy_coef * jnp.mean(ent))
        return loss, {"actor_loss": actor_loss, "critic_loss": critic_loss}

    @jax.jit
    def train_episode(params, opt_state, rng):
        k0, k1 = jax.random.split(rng)
        state0 = env_reset(env_cfg, tables, k0, model_ids=model_ids)
        state_T, traj = rollout(params, state0, k1)
        obs_T = observe(env_cfg, tables, state_T).reshape(-1)
        advs, rets = gae(traj, critic_apply(params, obs_T))

        def epoch(carry, _):
            params, opt_state = carry
            (loss, stats), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, traj, advs, rets)
            params, opt_state, _ = adamw_update(opt, params, grads, opt_state)
            return (params, opt_state), loss
        (params, opt_state), losses = jax.lax.scan(
            epoch, (params, opt_state), None, length=pc.epochs)
        return params, opt_state, {
            "loss": losses[-1], "mean_reward": jnp.mean(traj["reward"]),
            "episode_reward": jnp.sum(traj["reward"])}

    return train_episode


def train(env_cfg: EnvConfig, tables: ProfileTables, pc: PPOConfig, rng,
          model_ids=None, log_every: int = 0):
    params = init_agent(env_cfg, tables, pc.base, rng)
    opt_state = adamw_init(params)
    step = make_train_episode(env_cfg, tables, pc, model_ids=model_ids)
    history = []
    for ep in range(pc.episodes):
        rng, k = jax.random.split(rng)
        params, opt_state, stats = step(params, opt_state, k)
        history.append({k2: float(v) for k2, v in stats.items()})
        if log_every and (ep + 1) % log_every == 0:
            print(f"ppo ep {ep+1:4d} "
                  f"reward={history[-1]['mean_reward']:+.4f}", flush=True)
    return params, history

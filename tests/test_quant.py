"""repro.quant: quantization roundtrips, the int8 Pallas matmul, version
tables derived from real variants, and split-execution correctness of the
controller's full (version, cut) action."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (evaluate_policy, make_tpu_env, resolve_selection,
                        transformer_profile)
from repro.policies import build_policy
from repro.core.partition import cut_for_layer, cut_points
from repro.kernels.quant_matmul import quant_matmul, quant_matmul_ref
from repro.models import forward_logits, init
from repro.quant import (DEFAULT_VERSIONS, QTensor, accuracy_proxy,
                         build_version_params, dequantize_tree, get_version,
                         quantize, quantize_act, quantize_tree,
                         relative_quant_error, tree_weight_bytes)
from repro.serving import SplitServingEngine
from tests.conftest import make_batch


def _rand(shape, seed=0, scale=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=shape) * scale, jnp.float32)


# --------------------------------------------------------------------------
# quantize / dequantize
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode,max_rel", [("w8wo", 0.02), ("w8a8", 0.02),
                                          ("w4", 0.15)])
def test_quantize_roundtrip_error(mode, max_rel):
    w = _rand((96, 130), scale=0.1)
    qt = quantize(w, mode)
    rel = float(jnp.linalg.norm(w - qt.dequantize()) / jnp.linalg.norm(w))
    assert rel < max_rel, (mode, rel)
    assert qt.shape == w.shape


def test_int4_packing_is_lossless():
    """Packing two int4 codes per byte must not change the dequantization
    (pack -> unpack is the identity on the codes)."""
    w = _rand((64, 40), seed=3)
    qt = quantize(w, "w4")
    assert qt.q.shape == (32, 40) and qt.q.dtype == jnp.uint8
    from repro.quant.quantize import _QMAX, _pack_int4, _unpack_int4
    codes = _unpack_int4(qt.q)
    assert int(jnp.max(jnp.abs(codes))) <= _QMAX[4]
    np.testing.assert_array_equal(np.asarray(_unpack_int4(_pack_int4(codes))),
                                  np.asarray(codes))


def test_quantized_tree_slices_and_scans():
    """QTensor leaves must survive the stacked-param operations partition
    and model code perform: leading-axis tree slicing."""
    w = _rand((4, 64, 40), seed=1)
    qt = quantize(w, "w4")
    sl = jax.tree.map(lambda a: a[1:3], qt)
    np.testing.assert_allclose(np.asarray(sl.dequantize()),
                               np.asarray(qt.dequantize()[1:3]), rtol=1e-6)


def test_quantize_tree_selects_dense_weights_only():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    qp = quantize_tree(params, "w8wo")
    stack = qp["stacks"]["main"]["blk"]
    assert isinstance(stack["attn"]["wq"], QTensor)
    assert isinstance(stack["mlp"]["w_down"], QTensor)
    # embeddings and norms stay full precision
    assert not isinstance(qp["tok_embed"], QTensor)
    assert not isinstance(stack["norm1"]["scale"], QTensor)
    # dequantize_tree restores plain arrays of the original shapes
    dq = dequantize_tree(qp)
    assert dq["stacks"]["main"]["blk"]["attn"]["wq"].shape \
        == params["stacks"]["main"]["blk"]["attn"]["wq"].shape


def test_quantize_tree_skips_moe_experts():
    """Routed expert weights reuse the dense-MLP leaf names but are
    einsum-consumed — they must stay full precision and the quantized
    MoE model must still run."""
    cfg = get_config("mixtral-8x22b").reduced()
    params = init(cfg, jax.random.key(1))
    qp = quantize_tree(params, "w8wo")
    moe = qp["stacks"]["main"]["blk"]["moe"]
    assert not isinstance(moe["w_gate"], QTensor)
    assert not isinstance(moe["router"], QTensor)
    # attention projections around the MoE are still quantized
    assert isinstance(qp["stacks"]["main"]["blk"]["attn"]["wq"], QTensor)
    batch = make_batch(cfg)
    del batch["targets"]
    full = forward_logits(cfg, params, batch)
    ql = forward_logits(cfg, qp, batch)
    rel = float(jnp.linalg.norm(ql - full) / jnp.linalg.norm(full))
    assert rel < 0.1, rel


def test_quantized_tree_bytes_shrink():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    b16 = tree_weight_bytes(params)
    b8 = tree_weight_bytes(quantize_tree(params, "w8wo"))
    b4 = tree_weight_bytes(quantize_tree(params, "w4"))
    assert b4 < b8 < b16


# --------------------------------------------------------------------------
# int8 matmul kernel
# --------------------------------------------------------------------------

def test_quant_matmul_ref_matches_dequantized_matmul():
    """The int32 accumulation is exact, so the rescaled int8 matmul must
    equal the f32 matmul of the dequantized operands to float tolerance."""
    x = _rand((10, 96), seed=5)
    w = _rand((96, 130), seed=6, scale=0.1)
    qt = quantize(w, "w8a8")
    xq, xs = quantize_act(x)
    got = quant_matmul_ref(xq, qt.q, xs.reshape(-1), qt.scale.reshape(-1))
    want = (xq.astype(jnp.float32) * xs) @ qt.dequantize()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,K,N", [(10, 96, 130), (128, 128, 128),
                                   (1, 260, 50)])
def test_quant_matmul_pallas_matches_ref(M, K, N):
    x = _rand((M, K), seed=7)
    w = _rand((K, N), seed=8, scale=0.1)
    qt = quantize(w, "w8a8")
    xq, xs = quantize_act(x)
    ref = quant_matmul_ref(xq, qt.q, xs.reshape(-1), qt.scale.reshape(-1))
    got = quant_matmul(xq, qt.q, xs.reshape(-1), qt.scale.reshape(-1),
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_dense_dispatch_pallas_vs_ref(monkeypatch):
    """layers.dense on a w8a8 leaf: the REPRO_USE_PALLAS=interpret path
    must match the jnp-reference path bit-for-bit (same int8 codes in,
    same int32 accumulation)."""
    from repro.models.layers import dense
    x = _rand((2, 8, 96), seed=9)
    qt = quantize(_rand((96, 64), seed=10, scale=0.1), "w8a8")
    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    y_ref = dense(x, qt)
    assert y_ref.shape == (2, 8, 64)
    monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
    y_pl = dense(x, qt)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# version registry -> env tables
# --------------------------------------------------------------------------

def test_version_registry_derives_tables():
    bf16, w8, w4 = (get_version(n) for n in DEFAULT_VERSIONS)
    # accuracy proxy strictly ordered by measured quantization error
    assert accuracy_proxy(bf16) > accuracy_proxy(w8) > accuracy_proxy(w4)
    assert relative_quant_error(16, 0) == 0.0
    # weight shipping ordered by code width; w8a8 halves the MAC cost
    assert bf16.bytes_per_param > w8.bytes_per_param > w4.bytes_per_param
    assert w8.matmul_cost_scale == 0.5 and w4.matmul_cost_scale == 1.0
    assert w8.act_itemsize == 1 and w4.act_itemsize == 2


def test_transformer_profile_tables_from_quant():
    cfg = get_config("qwen2-0.5b")
    prof = transformer_profile(cfg)
    by_name = {v.version: v for v in prof.versions}
    assert set(by_name) == set(DEFAULT_VERSIONS)
    assert by_name["bf16"].accuracy > by_name["w8"].accuracy \
        > by_name["w4"].accuracy
    # w8a8 halves the dense-projection share of FLOPs (scores and other
    # einsum-consumed compute stay full precision)
    assert by_name["bf16"].total_flops / 2 < by_name["w8"].total_flops \
        < by_name["bf16"].total_flops
    # w8 ships int8 cut activations; bf16/w4 ship the compute dtype
    c = by_name["bf16"].cut_points[0]
    act_width = cfg.cdtype.itemsize
    assert by_name["w8"].cut_bytes(c) == pytest.approx(
        by_name["bf16"].cut_bytes(c) / act_width)
    assert by_name["w4"].cut_bytes(c) == by_name["bf16"].cut_bytes(c)
    # weight shipping: only the dense share prices at the code width, so
    # w4 < w8 < bf16 with w4 well under half for a dense-dominated arch
    wb = {n: by_name[n].tail_weight_bytes(c) for n in by_name}
    assert wb["w4"] < wb["w8"] < wb["bf16"]
    assert wb["w4"] < 0.5 * wb["bf16"]


# --------------------------------------------------------------------------
# split execution with quantized versions
# --------------------------------------------------------------------------

def test_split_engine_quantized_versions_match_bf16():
    """bf16 split == full forward exactly; quantized versions track the
    bf16 logits (w8 within the acceptance rtol, w4 within its looser,
    measured-error-priced bound)."""
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    del batch["targets"]
    full = forward_logits(cfg, params, batch)
    eng = SplitServingEngine(cfg, params, versions=DEFAULT_VERSIONS)
    for cut in cut_points(cfg):
        lf, bf = eng.infer(batch, cut, "bf16")
        np.testing.assert_allclose(np.asarray(lf), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)
        l8, b8 = eng.infer(batch, cut, "w8")
        rel8 = float(jnp.linalg.norm(l8 - lf) / jnp.linalg.norm(lf))
        assert rel8 < 0.1, (cut, rel8)
        l4, _ = eng.infer(batch, cut, "w4")
        rel4 = float(jnp.linalg.norm(l4 - lf) / jnp.linalg.norm(lf))
        assert rel4 < 0.5, (cut, rel4)
        # w8 ships int8 codes (+ f32 row scales) across the link
        assert b8 < bf


def test_split_engine_w8_pallas_interpret(monkeypatch):
    """The w8a8 trunk runs through the Pallas kernel end-to-end in
    interpret mode and stays close to the jnp-reference path."""
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    batch = make_batch(cfg, B=1, S=8)
    del batch["targets"]
    cut = cut_points(cfg)[0]
    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    ref_logits, _ = SplitServingEngine(
        cfg, params, versions=("w8",)).infer(batch, cut, "w8")
    monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
    pl_logits, _ = SplitServingEngine(
        cfg, params, versions=("w8",)).infer(batch, cut, "w8")
    np.testing.assert_allclose(np.asarray(pl_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_ssm_arch_not_spuriously_penalized():
    """A pure-SSM trunk quantizes (almost) nothing, so its quant versions
    must not be charged the dense-probe accuracy error (or FLOP/weight
    discounts) the executable model doesn't exhibit."""
    prof = transformer_profile(get_config("falcon-mamba-7b"))
    by = {v.version: v for v in prof.versions}
    assert by["w4"].accuracy == pytest.approx(by["bf16"].accuracy)
    assert by["w8"].total_flops == pytest.approx(by["bf16"].total_flops)


def test_cut_for_layer_covers_all_archs():
    for arch in ("qwen2-0.5b", "falcon-mamba-7b", "recurrentgemma-2b"):
        cfg = get_config(arch).reduced()
        legal = set(cut_points(cfg))
        prof = transformer_profile(cfg)
        for v in prof.versions:
            for layer in v.cut_points:
                assert cut_for_layer(cfg, layer) in legal


# --------------------------------------------------------------------------
# acceptance: controller decision -> quantized split execution, end to end
# --------------------------------------------------------------------------

def test_tpu_env_modal_selection_executes_quantized():
    """evaluate_policy on a make_tpu_env setup whose version axis is
    {bf16, w8, w4} derived from repro.quant; the modal (version, cut) is
    executed by SplitServingEngine with the matching quantized params."""
    arch = "qwen2-0.5b"
    env_cfg, tables = make_tpu_env([arch], reduced=True, episode_len=16)
    assert tables.n_versions == len(DEFAULT_VERSIONS)
    assert float(jnp.min(tables.tail_weight_bytes)) >= 0.0
    m = evaluate_policy(env_cfg, tables,
                        build_policy("greedy_oracle", env_cfg, tables),
                        jax.random.key(0), episodes=1)
    assert np.isfinite(m["reward"])
    j, k = m["modal_selection"][arch]

    cfg = get_config(arch).reduced()
    prof = transformer_profile(cfg)
    version, cut = resolve_selection(cfg, prof, j, k)
    assert version in DEFAULT_VERSIONS

    params = init(cfg, jax.random.key(0))
    eng = SplitServingEngine(cfg, params, versions=DEFAULT_VERSIONS)
    batch = make_batch(cfg)
    del batch["targets"]
    logits_sel, act_bytes = eng.infer(batch, cut, version)
    logits_bf16, _ = eng.infer(batch, cut, "bf16")
    assert act_bytes > 0
    rel = float(jnp.linalg.norm(logits_sel - logits_bf16)
                / jnp.maximum(jnp.linalg.norm(logits_bf16), 1e-12))
    tol = 0.1 if version in ("bf16", "w8") else 0.5
    assert rel <= tol, (version, rel)
    # the quantized engine's param trees really are quantized
    vp = build_version_params(cfg, params, ("w8",))["w8"]
    assert isinstance(vp["stacks"]["main"]["blk"]["attn"]["wq"], QTensor)


def test_weight_ship_amortization_raises_latency():
    from repro.core.env import env_reset
    from repro.core.env import action_costs
    arch = "qwen2-0.5b"
    cfg0, tables = make_tpu_env([arch], weight_ship_slots=0.0)
    cfg1, _ = make_tpu_env([arch], weight_ship_slots=8.0)
    state = env_reset(cfg0, tables, jax.random.key(0))
    a = jnp.asarray([[2, 0]], jnp.int32)          # w4, earliest cut
    t0 = action_costs(cfg0, tables, state, a)[3]
    t1 = action_costs(cfg1, tables, state, a)[3]
    assert float(t1[0]) > float(t0[0])

"""repro.obs.timeline — the fleet's flight recorder.

A ``Timeline`` captures columnar per-epoch time-series from a fleet
simulation: fleet aggregates (latency percentiles, energy, drops,
goodput, SLO hits), per-server series in cluster runs (queue depth,
DVFS step, replicas, replica power), and annotation events (drift
regime switches, autoscaler decisions with their measured-depth
trigger, adapter hot-swaps, Page-Hinkley trips). Columns follow the
``EpochLog`` discipline — one typed, geometrically-grown numpy array
per key, ``stride`` bounding memory on mega-fleet horizons — extended
with fixed-width (epoch, server) vector columns for the per-server
series.

Capture rules (DESIGN.md §9/§13):

- **Null by default.** ``FleetConfig.timeline=False`` allocates nothing
  and adds zero work to the epoch loop.
- **Result-neutral.** Capture only *reads* simulation state — no RNG,
  no mutation, no float-summation-order changes — so ``SimResult`` is
  bit-identical with capture on vs off (tested across all engines).
- **Scan-carry rule.** The jitted scan engine cannot host-callback per
  epoch; only O(1)-per-epoch accumulators ride in the scan's stacked
  ``ys`` outputs and are extracted host-side afterwards. Per-epoch
  percentile columns are therefore NaN under ``engine="scan"`` (mean /
  max / energy / SLO columns stay exact).

``to_json()`` serializes one run; ``write_timeline`` bundles a whole
``ComparisonReport``'s runs into the flight-recorder file
``scripts/fleetview.py`` renders.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

TIMELINE_SCHEMA = 1

# scalar per-epoch columns every engine fills (NaN where undefined)
FLEET_COLUMNS = ("epoch", "arrivals", "served", "dropped", "slo_hits",
                 "alive", "regime", "queue_jobs", "backlog_s",
                 "lat_mean", "lat_p50", "lat_p95", "lat_p99", "lat_max",
                 "energy_wh", "goodput")

# per-server vector columns (cluster runs only)
SERVER_COLUMNS = ("srv_queue", "srv_dvfs", "srv_replicas", "srv_power_w")

_J_PER_WH = 3600.0


class Timeline:
    """Columnar per-epoch flight recorder for one simulation run."""

    def __init__(self, *, slo_s: float = 1.0, slot_seconds: float = 1.0,
                 stride: int = 1, n_servers: int = 0,
                 server_names: Optional[List[str]] = None,
                 engine: str = "loop"):
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        self.slo_s = float(slo_s)
        self.slot_seconds = float(slot_seconds)
        self.stride = int(stride)
        self.n_servers = int(n_servers)
        self.server_names = list(server_names or [])
        self.engine = engine
        self._cols: Dict[str, np.ndarray] = {}
        self._n = 0
        self._offered = 0
        self._pending: Optional[Dict] = None
        self.annotations: List[Dict] = []
        self.slo_report = None          # repro.obs.slo.SLOReport

    # -- columnar store (EpochLog discipline + vector columns) -------------

    def _alloc(self, key: str, v) -> np.ndarray:
        a = np.asarray(v)
        if a.ndim == 0:
            dtype = np.int64 if a.dtype.kind in "iu" else np.float64
            return np.zeros(16, dtype)
        dtype = np.int64 if a.dtype.kind in "iu" else np.float64
        return np.zeros((16,) + a.shape, dtype)

    def _grow(self, need: int):
        for k, col in self._cols.items():
            if col.shape[0] < need:
                new = np.zeros((max(need, 2 * col.shape[0]),)
                               + col.shape[1:], col.dtype)
                new[:self._n] = col[:self._n]
                self._cols[k] = new

    def _store(self, row: Dict) -> None:
        if not self._cols:
            self._cols = {k: self._alloc(k, v) for k, v in row.items()}
        self._grow(self._n + 1)
        for k, v in row.items():
            self._cols[k][self._n] = v
        self._n += 1

    def _flush_pending(self) -> None:
        if self._pending is None:
            return
        row, self._pending = self._pending, None
        self._store(row)

    def _append_row(self, row: Dict) -> None:
        keep = self._offered % self.stride == 0
        self._offered += 1
        if keep:
            self._pending = None
            self._store(row)
        else:
            # hold the horizon's final epoch (EpochLog stride rule)
            self._pending = row

    def column(self, key: str) -> np.ndarray:
        self._flush_pending()
        return self._cols[key][:self._n]

    @property
    def columns(self) -> Dict[str, np.ndarray]:
        self._flush_pending()
        return {k: c[:self._n] for k, c in self._cols.items()}

    def __len__(self) -> int:
        self._flush_pending()
        return self._n

    def __repr__(self) -> str:
        return (f"Timeline(rows={len(self)}, engine={self.engine!r}, "
                f"servers={self.n_servers}, "
                f"annotations={len(self.annotations)})")

    # -- capture API (called from the fleet loop / scan extraction) --------

    def append_epoch(self, *, epoch: int, arrivals: int, dropped: int,
                     slo_hits: int, alive: int, regime: int,
                     queue_jobs: float, backlog_s: float,
                     lat: Optional[np.ndarray] = None,
                     energy_j: float = 0.0,
                     srv_queue: Optional[np.ndarray] = None,
                     srv_dvfs: Optional[np.ndarray] = None,
                     srv_replicas: Optional[np.ndarray] = None,
                     srv_power_w: Optional[np.ndarray] = None) -> None:
        """Record one host-engine epoch. ``lat`` is the epoch's
        per-request latency array (percentiles are summarized here and
        the array is not retained)."""
        served = 0 if lat is None else int(lat.size)
        if served:
            p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
            lmean, lmax = float(np.mean(lat)), float(np.max(lat))
        else:
            p50 = p95 = p99 = lmean = lmax = float("nan")
        row = {
            "epoch": int(epoch), "arrivals": int(arrivals),
            "served": served, "dropped": int(dropped),
            "slo_hits": int(slo_hits), "alive": int(alive),
            "regime": int(regime), "queue_jobs": float(queue_jobs),
            "backlog_s": float(backlog_s),
            "lat_mean": lmean, "lat_p50": float(p50),
            "lat_p95": float(p95), "lat_p99": float(p99), "lat_max": lmax,
            "energy_wh": float(energy_j) / _J_PER_WH,
            "goodput": float(slo_hits) / self.slot_seconds,
        }
        if self.n_servers:
            # np.array copies: the pool mutates these in place next epoch
            row["srv_queue"] = np.array(srv_queue, np.float64)
            row["srv_dvfs"] = np.array(srv_dvfs, np.float64)
            row["srv_replicas"] = np.array(srv_replicas, np.int64)
            row["srv_power_w"] = np.array(srv_power_w, np.float64)
        self._append_row(row)

    def extend_epochs(self, *, epoch, arrivals, served, dropped, slo_hits,
                      alive, queue_jobs, backlog_s, lat_sum, lat_max,
                      energy_j) -> None:
        """Bulk-append the scan engine's stacked per-epoch outputs
        (host-side, after the scan returns). Only O(1)-per-epoch
        accumulators exist on that path, so percentile columns are NaN
        (the scan-carry rule)."""
        epoch = np.asarray(epoch, np.int64)
        T = epoch.shape[0]
        served = np.asarray(served, np.float64)
        lat_sum = np.asarray(lat_sum, np.float64)
        with np.errstate(invalid="ignore", divide="ignore"):
            lat_mean = np.where(served > 0, lat_sum / served, np.nan)
        lat_max = np.where(served > 0, np.asarray(lat_max, np.float64),
                           np.nan)
        nan = np.full(T, np.nan)
        slo_hits = np.asarray(slo_hits, np.int64)
        rows = {
            "epoch": epoch, "arrivals": np.asarray(arrivals, np.int64),
            "served": served.astype(np.int64),
            "dropped": np.asarray(dropped, np.int64),
            "slo_hits": slo_hits, "alive": np.asarray(alive, np.int64),
            "regime": np.zeros(T, np.int64),
            "queue_jobs": np.asarray(queue_jobs, np.float64),
            "backlog_s": np.asarray(backlog_s, np.float64),
            "lat_mean": lat_mean, "lat_p50": nan, "lat_p95": nan,
            "lat_p99": nan, "lat_max": lat_max,
            "energy_wh": np.asarray(energy_j, np.float64) / _J_PER_WH,
            "goodput": slo_hits / self.slot_seconds,
        }
        keep = (np.arange(self._offered, self._offered + T)
                % self.stride) == 0
        self._offered += T
        sel = {k: v[keep] for k, v in rows.items()}
        m = len(sel["epoch"])
        stored_last = T > 0 and bool(keep[-1])
        self._pending = None if stored_last or T == 0 \
            else {k: v[-1] for k, v in rows.items()}
        if m == 0:
            return
        if not self._cols:
            self._cols = {k: self._alloc(k, v[0]) for k, v in sel.items()}
        self._grow(self._n + m)
        for k, v in sel.items():
            self._cols[k][self._n:self._n + m] = v
        self._n += m

    def annotate(self, epoch: int, kind: str, **attrs) -> None:
        """Mark a point event on the timeline (regime switch, autoscale
        decision, hot-swap, drift trigger, SLO alert)."""
        self.annotations.append({"epoch": int(epoch), "kind": str(kind),
                                 **attrs})

    def finalize(self, slo_cfg=None, *, emit_events: bool = True):
        """Compute the SRE error-budget report from the recorded series
        (repro.obs.slo), annotate its burn alerts, and optionally mirror
        them into the active obs recorder. Idempotent."""
        if self.slo_report is not None or len(self) == 0:
            return self.slo_report
        from repro.obs import slo as slo_mod
        cfg = slo_cfg if slo_cfg is not None else slo_mod.SLOConfig()
        self.slo_report = slo_mod.compute(
            self.column("epoch"), self.column("arrivals"),
            self.column("slo_hits"), cfg)
        for a in self.slo_report.alerts:
            self.annotate(a["start"], "slo_alert", **{
                k: v for k, v in a.items() if k != "start"})
        if emit_events:
            slo_mod.emit_events(self.slo_report)
        return self.slo_report

    # -- serialization ------------------------------------------------------

    def to_json(self) -> Dict:
        self._flush_pending()
        cols, servers = {}, {}
        for k, c in self.columns.items():
            if c.ndim == 1:
                cols[k] = _jsonable(c)
            else:
                servers[k] = [_jsonable(c[:, s])
                              for s in range(c.shape[1])]
        out = {"schema": TIMELINE_SCHEMA, "engine": self.engine,
               "epochs": len(self), "stride": self.stride,
               "slo_s": self.slo_s, "slot_seconds": self.slot_seconds,
               "columns": cols, "annotations": list(self.annotations)}
        if self.n_servers:
            out["servers"] = {"n": self.n_servers,
                              "names": self.server_names, **servers}
        if self.slo_report is not None:
            out["slo"] = self.slo_report.to_json()
        return out


def _jsonable(arr: np.ndarray) -> List:
    """Column -> JSON list; NaN becomes null so the export stays
    strictly machine-readable."""
    if arr.dtype.kind == "f":
        return [None if np.isnan(v) else float(v) for v in arr]
    return [int(v) for v in arr]


def write_timeline(path: str, runs: List[Dict],
                   meta: Optional[Dict] = None) -> None:
    """Write the flight-recorder file: ``runs`` is a list of
    ``{"policy", "seed", "timeline": Timeline}`` entries (one per
    (policy, seed) simulation). ``path`` "-" streams to stdout."""
    doc = {"type": "timeline", "schema": TIMELINE_SCHEMA,
           "meta": dict(meta or {}),
           "runs": [{**{k: v for k, v in r.items() if k != "timeline"},
                     "timeline": (r["timeline"].to_json()
                                  if isinstance(r["timeline"], Timeline)
                                  else r["timeline"])}
                    for r in runs]}
    text = json.dumps(doc, indent=None, separators=(",", ":"))
    if path == "-":
        import sys
        sys.stdout.write(text + "\n")
    else:
        with open(path, "w") as f:
            f.write(text + "\n")


def read_timeline(path: str) -> Dict:
    """Load and schema-check a flight-recorder file."""
    if path == "-":
        import sys
        doc = json.load(sys.stdin)
    else:
        with open(path) as f:
            doc = json.load(f)
    if doc.get("type") != "timeline":
        raise ValueError(f"{path}: not a timeline file (write one with "
                         "simulate.py --timeline-out)")
    if doc.get("schema") != TIMELINE_SCHEMA:
        raise ValueError(f"{path}: timeline schema {doc.get('schema')!r} "
                         f"!= supported {TIMELINE_SCHEMA}")
    return doc

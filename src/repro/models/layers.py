"""Shared primitive layers: norms, MLPs, rotary embeddings, positions."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.quant.quantize import QTensor


def dense(x, w):
    """``x @ w`` where w may be a quantized ``QTensor`` leaf.

    The quantized path dispatches through kernels/ops.py (REPRO_USE_PALLAS
    selects the Pallas int8 kernel); the import is deferred because
    kernels -> ref -> ssm imports this module at package-init time.
    """
    if isinstance(w, QTensor):
        from repro.kernels import ops
        return ops.quantized_dense(x, w)
    return x @ w


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def plan_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim if dim is not None else cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": P((d,), (None,), "ones"),
                "bias": P((d,), (None,), "zeros")}
    return {"scale": P((d,), (None,), "ones")}


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x, scale, eps: float = 1e-6):
    """Per-head RMSNorm on the trailing head_dim (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def plan_mlp(cfg: ModelConfig, d_in: Optional[int] = None,
             d_ff: Optional[int] = None, bias: bool = False):
    d = d_in if d_in is not None else cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    plan = {"w_down": P((f, d), ("ff", "embed"))}
    if cfg.mlp_act in ("swiglu", "geglu"):
        plan["w_gate"] = P((d, f), ("embed", "ff"))
        plan["w_up"] = P((d, f), ("embed", "ff"))
    else:  # gelu
        plan["w_up"] = P((d, f), ("embed", "ff"))
    if bias:
        plan["b_up"] = P((f,), ("ff",), "zeros")
        plan["b_down"] = P((d,), (None,), "zeros")
    return plan


def apply_mlp(cfg: ModelConfig, p, x):
    if "w_gate" in p:
        g = dense(x, p["w_gate"])
        u = dense(x, p["w_up"])
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(g) * u
    else:
        h = dense(x, p["w_up"])
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h)
    y = dense(h, p["w_down"])
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (S,) int32."""
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)                      # (half,)
    ang = positions.astype(jnp.float32)[:, None] * inv[None]  # (S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int, offset=0):
    pos = (jnp.arange(n, dtype=jnp.float32) + offset)[:, None]
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (n, d)


# --------------------------------------------------------------------------
# causal depthwise conv (mamba / rg-lru), as shifted adds (SPMD friendly)
# --------------------------------------------------------------------------

def causal_conv1d(x, w, b=None):
    """x: (B, S, C); w: (K, C) depthwise causal kernel; returns (B, S, C)."""
    K = w.shape[0]
    y = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[K - 1 - i]
    if b is not None:
        y = y + b
    return y


def causal_conv1d_step(x_t, conv_state, w, b=None):
    """One decode step. x_t: (B, C); conv_state: (B, K-1, C) holding the
    previous K-1 inputs (oldest first). Returns (y_t, new_conv_state)."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x_t.dtype)
    if b is not None:
        y = y + b
    return y, window[:, 1:]

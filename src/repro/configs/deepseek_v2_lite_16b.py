"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512), 2 shared + 64 routed top-6.

Assignment header says "MoE 64e top-6" while the bracket note says
"2 shared+160 routed" (which is full DeepSeek-V2); we follow the primary
numbers and the published V2-Lite card: 64 routed experts, top-6, 2 shared,
per-expert FFN 1408, first layer dense. [arXiv:2405.04434]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434 (DeepSeek-V2)",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # routed-expert hidden size (per assignment)
    vocab_size=102_400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10_000.0,
    norm="rmsnorm",
    mlp_act="swiglu",
))

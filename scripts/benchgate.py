"""Gate a benchmark run against the append-only perf history.

Two-command local workflow (also what CI's bench-gate job runs):

    # 1. measure: repeated samples + per-case obs phase breakdowns
    PYTHONPATH=src python benchmarks/run.py --tags smoke \
        --json BENCH_results.json --trace bench_events.jsonl
    # 2. gate vs matching-fingerprint baselines, then append this run
    PYTHONPATH=src python scripts/benchgate.py BENCH_results.json \
        --history BENCH_history.jsonl

Exit status: 1 when any case regresses (median slowdown beyond
--min-effect AND Mann-Whitney p < --alpha vs the pooled baseline of
the last --pool matching-fingerprint runs); 0 otherwise — including
when the gate *refuses* to compare because history only exists under
other environment fingerprints (pass --strict to make refusal/new
baselines exit 2). A failing report names the regressed case AND its
dominant regressed obs phase.

The run is appended to history after gating (so the next run baselines
on it) unless it failed the gate — a regression must not become its
own baseline. --append-always / --no-append override.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.bench import gate as bgate
from repro.bench import history as bhist


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("results", nargs="?", default="BENCH_results.json",
                    help="benchmarks/run.py --json output (schema 2)")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="append-only history file (created if missing)")
    ap.add_argument("--min-effect", type=float, default=0.10,
                    help="minimum median slowdown to fail on (0.10 = "
                    "10%%; smaller significant shifts pass)")
    ap.add_argument("--alpha", type=float, default=0.05,
                    help="one-sided Mann-Whitney significance level")
    ap.add_argument("--pool", type=int, default=bhist.DEFAULT_POOL,
                    help="matching-fingerprint runs pooled as baseline")
    ap.add_argument("--min-samples", type=int, default=3,
                    help="fewer samples on either side -> case is "
                    "reported as 'insufficient', never gated")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the gate report as JSON ('-' = stdout)")
    ap.add_argument("--no-append", action="store_true",
                    help="never append this run to history")
    ap.add_argument("--append-always", action="store_true",
                    help="append even when the gate fails")
    ap.add_argument("--strict", action="store_true",
                    help="exit 2 when nothing could be gated (refused "
                    "fingerprint or all-new cases)")
    args = ap.parse_args()

    try:
        with open(args.results) as f:
            results = json.load(f)
    except (OSError, ValueError) as e:
        raise SystemExit(f"benchgate: cannot read {args.results}: {e}")
    if results.get("schema") != 2:
        raise SystemExit(
            f"benchgate: {args.results} has schema "
            f"{results.get('schema')!r}, need 2 (re-run benchmarks/"
            f"run.py from this tree)")
    records = results.get("rows", [])
    fp = results.get("fingerprint") or bhist.fingerprint()

    hist_rows = bhist.load(args.history)
    report = bgate.gate_records(
        records, hist_rows, fp, min_effect=args.min_effect,
        alpha=args.alpha, pool=args.pool, min_samples=args.min_samples)
    print(bgate.render(report, records))

    if args.json:
        payload = json.dumps(report.to_json(), indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")
            print(f"wrote {args.json}")

    do_append = not args.no_append and \
        (args.append_always or not report.failed)
    if do_append:
        run_id = f"{results.get('git_sha', 'unknown')}-" \
                 f"{results.get('unix_time', 0):.0f}"
        rows = bhist.stamp(records, run_id=run_id,
                           t_unix=float(results.get("unix_time", 0.0)),
                           sha=results.get("git_sha"), fp=fp)
        bhist.append(args.history, rows)
        print(f"appended {len(rows)} row(s) to {args.history}")
    elif report.failed:
        print(f"NOT appended to {args.history} (gate failed; a "
              f"regression must not become its own baseline — "
              f"--append-always to override)")

    if report.failed:
        return 1
    if args.strict and (report.refused or not any(
            v.status in ("ok", "improved", "regression", "insufficient")
            for v in report.verdicts)):
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""AutoScale-style server-side autoscaling over measured queue depth.

The fleet's servers trade *replica energy* against *queue wait*: every
active replica draws ``p_replica_w * dvfs^3`` watts continuously
(metered into ``ServerPool.energy_j`` and the run summary), while more
replicas / higher DVFS drain both the background queue and the
fleet-induced tail backlog faster. The ``Autoscaler`` closes that loop
per decision epoch from the same measured per-server queue depth the
controller observes:

- ``policy="threshold"``: react every epoch — scale a server up when
  its queue exceeds ``up_queue`` jobs, down when below ``down_queue``.
- ``policy="hysteresis"``: AutoScale's conservative variant — act only
  after ``patience`` *consecutive* breaches and hold a ``cooldown`` of
  epochs after every action, so transient bursts don't thrash replicas.

Scaling up prefers capacity in-place first (step the DVFS ladder to the
top) then adds a replica; scaling down retires replicas before slowing
the survivors — mirroring AutoScale's "run wide and slow" energy
ordering in reverse. The autoscaler consumes no randomness, so runs
stay bit-reproducible and paired seeds stay paired.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    policy: str = "hysteresis"     # "threshold" | "hysteresis"
    up_queue: float = 8.0          # jobs; scale up above this
    down_queue: float = 2.0        # jobs; scale down below this
    patience: int = 3              # consecutive breaches (hysteresis)
    cooldown: int = 8              # epochs held after an action

    def __post_init__(self):
        if self.policy not in ("threshold", "hysteresis"):
            raise ValueError(
                f"unknown autoscaler policy {self.policy!r}; valid "
                "policies: threshold, hysteresis")
        if self.down_queue >= self.up_queue:
            raise ValueError(
                f"down_queue ({self.down_queue}) must be below up_queue "
                f"({self.up_queue}) or the autoscaler oscillates")


class Autoscaler:
    """Per-server threshold/hysteresis state for one ServerPool."""

    def __init__(self, cfg: AutoscalerConfig, n_servers: int):
        self.cfg = cfg
        self._up_streak = np.zeros(n_servers, dtype=np.int64)
        self._down_streak = np.zeros(n_servers, dtype=np.int64)
        self._hold = np.zeros(n_servers, dtype=np.int64)

    def step(self, pool, queue_jobs: np.ndarray) -> List[Dict]:
        """Advance one epoch on measured queue depth; mutates the pool's
        ``replicas``/``dvfs_idx`` in place and returns one decision dict
        per server that moved — the action taken plus the measured depth
        that triggered it (the timeline's ``autoscale`` annotations)."""
        cfg = self.cfg
        c = pool.cluster
        decisions: List[Dict] = []
        over = queue_jobs > cfg.up_queue
        under = queue_jobs < cfg.down_queue
        self._up_streak = np.where(over, self._up_streak + 1, 0)
        self._down_streak = np.where(under, self._down_streak + 1, 0)
        for s in range(c.n_servers):
            if self._hold[s] > 0:
                self._hold[s] -= 1
                continue
            if cfg.policy == "threshold":
                go_up, go_down = over[s], under[s]
            else:
                go_up = self._up_streak[s] >= cfg.patience
                go_down = self._down_streak[s] >= cfg.patience
            if go_up:
                if pool.dvfs_idx[s] < len(c.dvfs[s]) - 1:
                    pool.dvfs_idx[s] += 1
                    action = "dvfs_up"
                elif pool.replicas[s] < c.max_replicas[s]:
                    pool.replicas[s] += 1
                    action = "replica_up"
                else:
                    continue          # already at full capacity
            elif go_down:
                if pool.replicas[s] > 1:
                    pool.replicas[s] -= 1
                    action = "replica_down"
                elif pool.dvfs_idx[s] > 0:
                    pool.dvfs_idx[s] -= 1
                    action = "dvfs_down"
                else:
                    continue          # already at the floor
            else:
                continue
            decisions.append({"server": s, "action": action,
                              "queue": float(queue_jobs[s])})
            self._hold[s] = cfg.cooldown if cfg.policy == "hysteresis" \
                else 0
            self._up_streak[s] = self._down_streak[s] = 0
        return decisions

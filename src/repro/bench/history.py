"""repro.bench.history — append-only perf trajectory.

``BENCH_history.jsonl`` holds one line per benchmark row per run:
the record the runner produced (name, samples, CI bounds, phases)
stamped with the run id, unix time, git sha and an **environment
fingerprint** — host, machine, CPU count, python/jax versions, jax
backend, Pallas flag. Baselines are only ever selected from rows whose
fingerprint matches the current environment byte-for-byte: timings
from a 2-core laptop say nothing about a 4-core CI runner, and gating
across them would manufacture regressions. CI normalizes its
ephemeral hostnames via ``REPRO_BENCH_HOST``.

Error records (``{"error": ...}``, no timing fields) are appended too
— the history is the full story — but ``baseline_for`` skips them
explicitly so a crashed run can never poison baseline statistics.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

HISTORY_SCHEMA = 1

# rows from this many most-recent matching runs are pooled into the
# baseline sample set (more samples -> a sharper Mann-Whitney test)
DEFAULT_POOL = 3


def git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha[:12]
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def fingerprint() -> Dict[str, object]:
    """The environment key baselines must match on. ``REPRO_BENCH_HOST``
    overrides the hostname (CI runners are ephemeral but uniform)."""
    try:
        import jax
        jax_ver = jax.__version__
        backend = jax.default_backend()
    except Exception:       # noqa: BLE001 — fingerprint works without jax
        jax_ver, backend = "none", "none"
    return {
        "host": os.environ.get("REPRO_BENCH_HOST") or platform.node(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        "python": platform.python_version(),
        "jax": jax_ver,
        "backend": backend,
        "pallas": os.environ.get("REPRO_USE_PALLAS", "0"),
    }


def fp_key(fp: Dict[str, object]) -> str:
    return "|".join(f"{k}={fp[k]}" for k in sorted(fp))


# --------------------------------------------------------------------------
# JSONL I/O
# --------------------------------------------------------------------------

def append(path: str, rows: Sequence[Dict]) -> None:
    """Append rows (one JSON line each) — never rewrites prior history."""
    with open(path, "a") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")


def load(path: str) -> List[Dict]:
    """All history rows, file order (oldest first). Missing file -> []."""
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def stamp(records: Sequence[Dict], *, run_id: str, t_unix: float,
          sha: Optional[str] = None,
          fp: Optional[Dict] = None) -> List[Dict]:
    """Records -> history rows: schema + run/sha/time/fingerprint."""
    sha = sha or git_sha()
    fp = fp or fingerprint()
    return [{"schema": HISTORY_SCHEMA, "run_id": run_id,
             "t_unix": t_unix, "git_sha": sha, "fingerprint": fp, **r}
            for r in records]


# --------------------------------------------------------------------------
# baseline selection
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Baseline:
    """Pooled baseline for one case name: samples from the ``pool``
    most recent matching-fingerprint runs, plus those source rows (the
    gate averages their phase breakdowns for attribution)."""
    name: str
    samples: List[float]
    rows: List[Dict]        # newest last

    @property
    def shas(self) -> List[str]:
        return [r.get("git_sha", "?") for r in self.rows]


def usable(row: Dict) -> bool:
    """A history row baselines may draw from: non-error, has samples."""
    return "error" not in row and bool(row.get("samples"))


def baseline_for(name: str, fp: Dict, rows: Sequence[Dict],
                 pool: int = DEFAULT_POOL) -> Optional[Baseline]:
    """Most recent ``pool`` matching rows for ``name`` under ``fp``;
    None when no matching-fingerprint history exists (verdict "new" —
    or "fingerprint_mismatch" when other-fingerprint rows do exist)."""
    key = fp_key(fp)
    match = [r for r in rows
             if r.get("name") == name and usable(r)
             and fp_key(r.get("fingerprint", {})) == key]
    if not match:
        return None
    match = match[-pool:]
    samples: List[float] = []
    for r in match:
        samples.extend(float(s) for s in r["samples"])
    return Baseline(name=name, samples=samples, rows=match)


def has_foreign_fingerprint(name: str, fp: Dict,
                            rows: Sequence[Dict]) -> bool:
    """True when history holds usable rows for ``name`` under a
    *different* fingerprint — the refuse-to-gate signal."""
    key = fp_key(fp)
    return any(r.get("name") == name and usable(r)
               and fp_key(r.get("fingerprint", {})) != key
               for r in rows)

"""Trainable policies (A2C — the paper's algorithm — and the PPO
ablation) behind the Policy protocol.

Lifecycle: ``build`` (untrained nets bound to one env) → ``train(seed,
trace)`` (batched vmapped-env updates; a workload trace switches the
task feature to trace-driven offered load) → ``save``/``load`` (one-file
.npz artifacts via ``repro.checkpointing``, structure-checked on
restore) → greedy ``act``. A trained controller is therefore a reusable
artifact: ``scripts/simulate.py --save-policy`` / ``--load-policy``
round-trips it without retraining, reproducing bit-identical actions.
"""
from __future__ import annotations

import jax

from repro.checkpointing import load_tree, save_tree
from repro.core import a2c as A2C
from repro.core import ppo as PPO
from repro.core.actor_critic import (greedy_actions, init_agent,
                                     sample_actions)
from repro.core.controller import make_task_sampler
from repro.core.env import observe
from repro.policies.base import Policy, PolicySpec, register

_ARTIFACT_SCHEMA = 1


class TrainablePolicy(Policy):
    trainable = True
    algo = "a2c"            # online-update objective (repro.online.adapt)

    def __init__(self, env_cfg, tables, config):
        super().__init__(env_cfg, tables)
        self.config = config
        self.params = None
        self.history = None
        self.explore = 0.0
        self._token = object()
        self._jit_cache = {}

    def _bump_token(self):
        """Invalidate the jitted-act cache: ``Policy.jitted`` compares
        ``_cache_token`` by identity, so anything that changes the baked
        params or the act semantics (train/load/hot-swap/explore) must
        mint a fresh token object."""
        self._token = object()

    # -- subclass hooks ----------------------------------------------------
    def _init_params(self, rng):
        raise NotImplementedError

    def _train(self, seed, trace, log_every):
        raise NotImplementedError

    # -- lifecycle ---------------------------------------------------------
    def train(self, seed: int = 0, trace=None, log_every: int = 0):
        """Train from scratch; returns the per-episode stats history."""
        self.params, self.history = self._train(seed, trace, log_every)
        self._bump_token()
        return self.history

    def set_params(self, params):
        """Hot-swap the serving parameters (the online-adaptation path):
        the next ``jitted()`` call re-traces against the new params. The
        swap is by reference — JAX arrays are immutable, so holding the
        previous ``.params`` tree is a free pre-drift snapshot."""
        self.params = params
        self._bump_token()
        return self

    def set_explore(self, explore: float):
        """Set the exploration rate in [0, 1]: each epoch, each device
        independently samples the masked logits with probability
        ``explore`` and acts greedily otherwise. Adaptation bursts need
        action diversity for the incremental policy gradient, but full
        sampling is needlessly destructive when a few actions are
        catastrophic — epsilon-mixing bounds the serving cost of
        exploring. No-op token-wise when unchanged."""
        explore = float(explore)
        if explore != self.explore:
            self.explore = explore
            self._bump_token()
        return self

    def _act(self, params, state, rng, eps: float):
        """Greedy decide, epsilon-mixed with logit sampling per device
        when ``eps`` > 0 (pure jnp; jit-traced with ``eps`` static)."""
        import jax
        import jax.numpy as jnp

        obs = observe(self.env_cfg, self.tables, state).reshape(-1)
        valid = self.tables.version_valid[state["model_id"]]
        greedy = greedy_actions(params, obs, valid)
        if eps <= 0.0 or rng is None:
            return greedy
        k1, k2 = jax.random.split(rng)
        sampled = sample_actions(params, obs, valid, k1)
        if eps >= 1.0:
            return sampled
        pick = jax.random.bernoulli(k2, eps, (greedy.shape[0], 1))
        return jnp.where(pick, sampled, greedy)

    def act(self, state, rng=None):
        if self.params is None:
            raise RuntimeError(f"policy {self.name!r}: call train() or "
                               "load() before act()")
        return self._act(self.params, state, rng, self.explore)

    def jitted(self):
        """Params-parametric specialization of ``Policy.jitted``: the
        compiled decide step takes the parameter pytree as an argument,
        so an online hot-swap (``set_params`` every few epochs under
        ``repro.online.adapt``) re-binds instantly instead of paying a
        re-trace per swap. One trace per exploration rate (greedy serving
        and each burst epsilon); all of them read ``self.params`` at
        call time, so they are never stale. The returned callable keeps
        the base-class identity contract: stable while params/explore
        are unchanged, a fresh object after any swap."""
        import jax

        from repro.obs import jaxmon

        if self.params is None:
            raise RuntimeError(f"policy {self.name!r}: call train() or "
                               "load() before act()")
        token = self._token
        if self._jit_fn is None or self._jit_token is not token:
            eps = float(self.explore)
            if eps not in self._jit_cache:
                def _act(params, state, rng, _eps=eps):
                    # trace-time counter: a param hot-swap re-binds the
                    # compiled fn and must NOT move this (measured
                    # invariant — tests/test_obs.py)
                    jaxmon.count_trace(f"decide.{self.name}")
                    return self._act(params, state, rng, _eps)

                self._jit_cache[eps] = jax.jit(_act)
            fn = self._jit_cache[eps]
            self._jit_fn = lambda state, rng: fn(self.params, state, rng)
            self._jit_token = token
        return self._jit_fn

    def _cache_token(self):
        return self._token

    def save(self, path: str) -> str:
        if self.params is None:
            raise RuntimeError(f"policy {self.name!r}: nothing to save "
                               "before train() or load()")
        return save_tree(path, self.params,
                         meta={"schema": _ARTIFACT_SCHEMA,
                               "policy": self.name})

    def load(self, path: str) -> "TrainablePolicy":
        """Restore a ``save``d artifact. The template params (same env
        dims, same net widths) structure-check the restore, so loading a
        controller trained for a different fleet fails loudly."""
        template = self.params if self.params is not None \
            else self._init_params(jax.random.key(0))
        params, meta = load_tree(path, template)
        saved_as = meta.get("policy")
        if saved_as is not None and saved_as != self.name:
            raise ValueError(f"artifact {path!r} holds a {saved_as!r} "
                             f"policy, not {self.name!r}")
        self.params = params
        self._bump_token()
        return self


class A2CPolicy(TrainablePolicy):
    """The paper's controller (Sec. II-C/D)."""

    name = "a2c"        # artifacts stay loadable from direct construction

    def __init__(self, env_cfg, tables, **cfg_kw):
        super().__init__(env_cfg, tables, A2C.A2CConfig(**cfg_kw))

    def _init_params(self, rng):
        return init_agent(self.env_cfg, self.tables, self.config, rng)

    def _train(self, seed, trace, log_every):
        return A2C.train(self.env_cfg, self.tables, self.config,
                         jax.random.key(seed), log_every=log_every,
                         task_sampler=make_task_sampler(self.env_cfg, trace,
                                                        seed))


class PPOPolicy(TrainablePolicy):
    """Beyond-paper ablation: clipped-surrogate PPO on the same nets."""

    name = "ppo"
    algo = "ppo"

    def __init__(self, env_cfg, tables, **cfg_kw):
        super().__init__(env_cfg, tables, PPO.PPOConfig(**cfg_kw))

    def _init_params(self, rng):
        return init_agent(self.env_cfg, self.tables, self.config.base, rng)

    def _train(self, seed, trace, log_every):
        return PPO.train(self.env_cfg, self.tables, self.config,
                         jax.random.key(seed), log_every=log_every,
                         task_sampler=make_task_sampler(self.env_cfg, trace,
                                                        seed))


register(PolicySpec(
    "a2c", A2CPolicy, trainable=True,
    description="A2C controller (the paper's algorithm); kwargs -> "
                "A2CConfig (episodes, entropy_coef, batch_envs, ...)"))
register(PolicySpec(
    "ppo", PPOPolicy, trainable=True,
    description="PPO ablation on the shared nets; kwargs -> PPOConfig"))

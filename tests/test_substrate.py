"""Substrate tests: optimizer, data pipeline, checkpointing, param plans,
sharding resolution, analysis tooling. Includes hypothesis property tests."""
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import ALL_ARCHS, get_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import params as pp
from repro.models.params import P
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(opt, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


@given(step=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_cosine_schedule_bounds(step):
    opt = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=10_000,
                      min_lr_ratio=0.1)
    lr = float(cosine_schedule(opt, jnp.int32(step)))
    assert 0.0 <= lr <= opt.lr * (1 + 1e-5)   # f32 rounding headroom


def test_grad_clip_keeps_update_finite():
    opt = AdamWConfig(lr=1e-2, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e9)}
    params2, _, m = adamw_update(opt, params, grads, state)
    assert np.isfinite(np.asarray(params2["w"])).all()
    assert float(m["grad_norm"]) > 1e8


# --------------------------------------------------------------------------
# param plans
# --------------------------------------------------------------------------

def test_param_plan_axes_match_shapes():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        from repro.models.model import plan_model
        plan = plan_model(cfg)
        for path, p in pp._iter_with_path(plan):
            assert len(p.shape) == len(p.axes), (arch, path)


def test_materialize_deterministic_and_path_dependent():
    plan = {"a": P((4, 4), (None, None)), "b": P((4, 4), (None, None))}
    t1 = pp.materialize(plan, jax.random.key(0), jnp.float32)
    t2 = pp.materialize(plan, jax.random.key(0), jnp.float32)
    np.testing.assert_array_equal(np.asarray(t1["a"]), np.asarray(t2["a"]))
    assert not np.allclose(np.asarray(t1["a"]), np.asarray(t1["b"]))


def test_abstract_matches_materialize():
    cfg = get_config("qwen2-0.5b").reduced()
    from repro.models.model import abstract_params, init
    abs_p = abstract_params(cfg)
    real = init(cfg, jax.random.key(0))
    assert jax.tree.structure(abs_p) == jax.tree.structure(real)
    for a, b in zip(jax.tree.leaves(abs_p), jax.tree.leaves(real)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_param_counts_in_expected_range():
    """Full-size configs must land near the advertised model sizes."""
    from repro.models.model import n_params
    expect = {"qwen2-0.5b": (0.35e9, 0.8e9),
              "starcoder2-3b": (2.5e9, 3.8e9),
              "phi3-medium-14b": (12e9, 16e9),
              "falcon-mamba-7b": (6e9, 8.5e9),
              "mixtral-8x22b": (120e9, 150e9),
              "llama-3.2-vision-90b": (75e9, 100e9)}
    for arch, (lo, hi) in expect.items():
        n = n_params(get_config(arch))
        assert lo < n < hi, (arch, n)


# --------------------------------------------------------------------------
# sharding resolution
# --------------------------------------------------------------------------

def test_resolve_spec_divisibility_fallbacks():
    from repro.launch.shardings import logical_rules, resolve_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    fm = FakeMesh()
    cfg = get_config("mixtral-8x22b")
    rules = logical_rules(cfg, fm)
    # 8 experts % 16 != 0 -> experts replicated, ff gets model
    spec = resolve_spec(("experts", "embed", "ff"), (8, 6144, 16384),
                        rules, fm)
    assert spec[0] is None and spec[2] == "model"
    cfg2 = get_config("deepseek-v2-lite-16b")
    rules2 = logical_rules(cfg2, fm)
    # 64 experts % 16 == 0 -> expert parallelism
    spec2 = resolve_spec(("experts", "embed", "ff"), (64, 2048, 1408),
                         rules2, fm)
    assert spec2[0] == "model"
    # batch=1 can't shard over data -> cache seq picks it up
    spec3 = resolve_spec(("batch", "kv_cache_seq", "kv_heads", None),
                         (1, 524288, 8, 128), rules2, fm)
    assert spec3[0] is None and spec3[1] == "data"


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_synthetic_data_deterministic():
    cfg = get_config("qwen2-0.5b").reduced()
    d = DataConfig(batch_size=2, seq_len=32, seed=1)
    ds1, ds2 = SyntheticLMDataset(cfg, d), SyntheticLMDataset(cfg, d)
    b1, b2 = ds1.batch(5), ds2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 32)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < cfg.vocab_size).all()
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_model_learns_synthetic_data():
    """End-to-end: loss decreases when training on the structured stream."""
    from repro.launch.steps import make_train_step
    cfg = get_config("qwen2-0.5b").reduced().with_overrides(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    from repro.models import init
    params = init(cfg, jax.random.key(0))
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                      weight_decay=0.01)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    opt_state = adamw_init(params)
    ds = SyntheticLMDataset(cfg, DataConfig(batch_size=8, seq_len=64))
    losses = []
    for i in range(45):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpointing import (latest_step, restore_checkpoint,
                                     save_checkpoint)
    from repro.models import init
    cfg = get_config("qwen3-0.6b").reduced()
    params = init(cfg, jax.random.key(0))
    save_checkpoint(str(tmp_path), 7, params)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), 7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_mismatch_raises(tmp_path):
    from repro.checkpointing import restore_checkpoint, save_checkpoint
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"b": jnp.zeros((2,))})


# --------------------------------------------------------------------------
# analysis tooling
# --------------------------------------------------------------------------

def test_jaxpr_cost_scan_awareness():
    """The walker must multiply scan bodies by trip count (cost_analysis
    does not — that asymmetry is the point of the walker)."""
    from repro.analysis.jaxpr_cost import analyze_fn

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w2 = jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)
    w8 = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    c2 = analyze_fn(f, x, w2)["flops"]
    c8 = analyze_fn(f, x, w8)["flops"]
    assert abs(c8 / c2 - 4.0) < 0.01


def test_hlo_collective_parser_smoke():
    from repro.analysis.hlo_collectives import collective_bytes
    hlo = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %p = f32[8,128] parameter(0)
  ROOT %ar = f32[8,128] all-reduce(%p), to_apply=%add
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2 * 8 * 128 * 4
    assert out["n_all-reduce"] == 1

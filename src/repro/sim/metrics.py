"""Per-request fleet metrics: latency percentiles, SLO attainment,
goodput and energy — not just slot-averaged scores.

``summarize_latencies`` is the shared schema: the fleet simulator and
the continuous-batching scheduler (``serving.ServerStats``) both report
through it, so a latency table means the same thing whether the numbers
came from the analytical pricer or from wall-clock decode steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

# Keys every latency report carries (values are floats; "unit" is the
# only string: "s" for the simulator, "steps" for the scheduler).
LATENCY_SCHEMA = ("count", "mean", "p50", "p95", "p99", "max",
                  "slo", "slo_attainment", "goodput")


def summarize_latencies(latencies, *, slo: Optional[float] = None,
                        duration: Optional[float] = None,
                        unit: str = "s") -> Dict:
    """Percentiles + SLO attainment + goodput for a latency array.

    ``slo``: deadline in the same unit; attainment is the fraction of
    requests at or under it. ``duration``: wall span of the measurement
    window; goodput is SLO-met requests per unit duration (falls back
    to all completed requests when no SLO is given).
    """
    lat = np.asarray(latencies, dtype=np.float64).ravel()
    out = {k: 0.0 for k in LATENCY_SCHEMA}
    out["unit"] = unit
    out["count"] = float(lat.size)
    out["slo"] = float(slo) if slo is not None else float("nan")
    if lat.size == 0:
        out["slo_attainment"] = float("nan")
        return out
    out["mean"] = float(np.mean(lat))
    p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
    out["p50"], out["p95"], out["p99"] = float(p50), float(p95), float(p99)
    out["max"] = float(np.max(lat))
    good = float(np.sum(lat <= slo)) if slo is not None else float(lat.size)
    out["slo_attainment"] = good / lat.size if slo is not None \
        else float("nan")
    out["goodput"] = good / duration if duration else 0.0
    return out


@dataclasses.dataclass
class FleetMetrics:
    """Streaming accumulator for per-request outcomes.

    Latency/energy arrays are appended per (device, epoch) batch and
    concatenated once at summary time, so recording is O(1) per batch
    and a multi-million-request run stays a handful of numpy arrays.
    """
    slo_s: float = 1.0
    _lat: List[np.ndarray] = dataclasses.field(default_factory=list)
    _energy: List[np.ndarray] = dataclasses.field(default_factory=list)
    _device: List[np.ndarray] = dataclasses.field(default_factory=list)
    dropped: int = 0

    def record(self, latencies_s, energies_j=None, device=None):
        lat = np.asarray(latencies_s, dtype=np.float64).ravel()
        if lat.size == 0:
            return
        self._lat.append(lat)
        if energies_j is not None:
            e = np.asarray(energies_j, dtype=np.float64).ravel()
            self._energy.append(np.broadcast_to(e, lat.shape).copy()
                                if e.size != lat.size else e)
        if device is not None:
            d = np.asarray(device, dtype=np.int32)
            # scalar (the loop engine's per-device batches) broadcasts;
            # the vectorized engine passes one per-request id array
            self._device.append(np.broadcast_to(d, lat.shape).copy()
                                if d.shape != lat.shape else d)

    def drop(self, n: int):
        """Requests lost outright (dead device): SLO misses, no latency."""
        self.dropped += int(n)

    def mark(self) -> Tuple[int, int]:
        """Opaque position in the (latency, energy) batch lists; pair
        with ``since`` to slice out one epoch's recordings."""
        return (len(self._lat), len(self._energy))

    def since(self, mark: Tuple[int, int]) -> Tuple[np.ndarray, np.ndarray]:
        """(latencies, energies) recorded after ``mark`` — read-only
        concatenated views the timeline capture summarizes per epoch."""
        i, j = mark
        lat = np.concatenate(self._lat[i:]) if len(self._lat) > i \
            else np.zeros(0)
        en = np.concatenate(self._energy[j:]) if len(self._energy) > j \
            else np.zeros(0)
        return lat, en

    @property
    def latencies_s(self) -> np.ndarray:
        return np.concatenate(self._lat) if self._lat else np.zeros(0)

    @property
    def energies_j(self) -> np.ndarray:
        return np.concatenate(self._energy) if self._energy else np.zeros(0)

    @property
    def devices(self) -> np.ndarray:
        return np.concatenate(self._device) if self._device \
            else np.zeros(0, np.int32)

    def summary(self, duration_s: Optional[float] = None) -> Dict:
        lat = self.latencies_s
        out = summarize_latencies(lat, slo=self.slo_s, duration=duration_s,
                                  unit="s")
        # dropped requests count against attainment and goodput
        total = lat.size + self.dropped
        if total:
            met = out["slo_attainment"] * lat.size if lat.size else 0.0
            out["slo_attainment"] = met / total
        out["dropped"] = float(self.dropped)
        e = self.energies_j
        out["energy_j"] = float(np.sum(e))
        out["energy_per_request_j"] = float(np.mean(e)) if e.size else 0.0
        out["duration_s"] = float(duration_s) if duration_s else 0.0
        return out


class EpochLog:
    """Columnar per-epoch log with a dict-row view.

    ``record_epochs=True`` used to allocate a Python dict per epoch —
    ~400 bytes and a GC object each for runs that can span 100k epochs.
    This stores one preallocated, geometrically-grown numpy column per
    key and materializes dict rows only on access, so existing
    consumers (``log[0]["arrivals"]``, ``log[8:]``, iteration, ``len``)
    keep working unchanged.

    ``stride`` keeps every stride-th offered row; ``cap`` stops keeping
    rows after ``cap`` are stored. Both bound memory on mega-fleet
    horizons without touching the simulation itself.

    The most recently offered row is always retained (cap permitting):
    a stride-skipped final epoch is held pending and materialized on
    first read, so timelines and summaries agree at the horizon even
    when the run length isn't stride-aligned.
    """

    def __init__(self, stride: int = 1, cap: Optional[int] = None):
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        self.stride = int(stride)
        self.cap = cap if cap is None else int(cap)
        self._cols: Dict[str, np.ndarray] = {}
        self._n = 0          # rows stored
        self._offered = 0    # rows offered (pre stride/cap)
        self._pending: Optional[Dict] = None   # last stride-skipped row

    def _grow(self, need: int):
        for k, col in self._cols.items():
            if col.shape[0] < need:
                new = np.zeros(max(need, 2 * col.shape[0]), col.dtype)
                new[:self._n] = col[:self._n]
                self._cols[k] = new

    def _store(self, row: Dict) -> None:
        if not self._cols:
            for k, v in row.items():
                dtype = np.int64 if isinstance(v, (int, np.integer)) \
                    else np.float64 if isinstance(v, (float, np.floating)) \
                    else object
                self._cols[k] = np.zeros(16, dtype)
        self._grow(self._n + 1)
        for k, v in row.items():
            self._cols[k][self._n] = v
        self._n += 1

    def _flush_pending(self) -> None:
        """Materialize the held final row before any read."""
        if self._pending is None:
            return
        row, self._pending = self._pending, None
        if self.cap is None or self._n < self.cap:
            self._store(row)

    def append(self, row: Dict) -> None:
        keep = (self._offered % self.stride == 0) and (
            self.cap is None or self._n < self.cap)
        self._offered += 1
        if not keep:
            # hold the row: if it turns out to be the horizon's last,
            # reads materialize it so the log ends at the final epoch
            self._pending = dict(row)
            return
        self._pending = None
        self._store(row)

    def extend_columns(self, **cols) -> None:
        """Bulk-append equal-length columns (the scan engine's stacked
        per-epoch outputs), applying stride/cap by slicing."""
        T = len(next(iter(cols.values())))
        idx = np.arange(self._offered, self._offered + T)
        keep = (idx % self.stride) == 0
        self._offered += T
        arrs = {k: np.asarray(v) for k, v in cols.items()}
        sel = {k: v[keep] for k, v in arrs.items()}
        kept = len(next(iter(sel.values()))) if sel else 0
        m = kept
        if self.cap is not None:
            m = min(m, max(self.cap - self._n, 0))
        # the batch's final row stays pending unless it was stored
        stored_last = T > 0 and bool(keep[-1]) and m == kept
        self._pending = None if stored_last or T == 0 \
            else {k: v[-1] for k, v in arrs.items()}
        if m == 0:
            return
        if not self._cols:
            self._cols = {k: np.zeros(16, np.asarray(v).dtype)
                          for k, v in sel.items()}
        self._grow(self._n + m)
        for k, v in sel.items():
            self._cols[k][self._n:self._n + m] = v[:m]
        self._n += m

    def column(self, key: str) -> np.ndarray:
        self._flush_pending()
        return self._cols[key][:self._n]

    @property
    def columns(self) -> Dict[str, np.ndarray]:
        self._flush_pending()
        return {k: c[:self._n] for k, c in self._cols.items()}

    def _row(self, i: int) -> Dict:
        return {k: c[i].item() if hasattr(c[i], "item") else c[i]
                for k, c in self._cols.items()}

    def __len__(self) -> int:
        self._flush_pending()
        return self._n

    def __bool__(self) -> bool:
        self._flush_pending()
        return self._n > 0

    def __iter__(self) -> Iterator[Dict]:
        self._flush_pending()
        return (self._row(i) for i in range(self._n))

    def __getitem__(self, i):
        self._flush_pending()
        if isinstance(i, slice):
            return [self._row(j) for j in range(*i.indices(self._n))]
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError(i)
        return self._row(i)

    def __repr__(self) -> str:
        return (f"EpochLog(rows={self._n}, offered={self._offered}, "
                f"keys={list(self._cols)})")

"""Symmetric per-channel weight quantization of parameter pytrees.

A quantized weight is a ``QTensor`` — a registered pytree node holding the
integer codes ``q`` and the per-output-channel ``scale`` (f32), so quantized
param trees flow through ``jax.tree.map`` slicing (core/partition.py) and
``lax.scan`` layer unstacking (models/model.py) unchanged.

Modes:
  "w8wo" — int8 weight-only (activations stay in compute dtype)
  "w4"   — int4 weight-only, two codes packed per uint8 along the
           contraction axis (axis -2: every dense weight here is (in, out))
  "w8a8" — int8 weights + dynamic per-row int8 activations; dispatched to
           the Pallas int8 matmul (kernels/quant_matmul.py) via
           models/layers.py::dense -> kernels/ops.py::quantized_dense

All modes are symmetric: scale = amax / qmax over the contraction axis, so
dequantization is a single broadcast multiply.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Tuple

import jax
import jax.numpy as jnp

MODES = ("w8wo", "w4", "w8a8")
_QMAX = {8: 127, 4: 7}

# dense-projection leaf names consumed via layers.dense (plain ``x @ w``
# with w of shape (..., in, out)); einsum/reshape-consumed weights (MoE
# experts, MLA up-projections, SSM/LRU mixers) and embeddings stay full
# precision (quantize_tree additionally excludes the whole moe subtree).
DENSE_WEIGHTS: FrozenSet[str] = frozenset(
    {"wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down", "lm_head"})


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Quantized weight leaf: integer codes + per-channel f32 scale.

    ``q``: int8 (w8wo/w8a8) or uint8 nibble-packed (w4, contraction axis
    halved); ``scale``: f32 of shape (..., G, out) where G is the number of
    scale groups along the contraction axis (G=1 for the int8 per-channel
    modes, contraction/32 for w4 group-wise). ``bits``/``act_bits`` are
    static aux data and survive tracing.
    """
    q: jax.Array
    scale: jax.Array
    bits: int = 8
    act_bits: int = 0

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.act_bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self) -> Tuple[int, ...]:
        s = tuple(self.q.shape)
        if self.bits == 4:
            s = s[:-2] + (s[-2] * 2, s[-1])
        return s

    @property
    def nbytes(self) -> int:
        return int(self.q.size * self.q.dtype.itemsize
                   + self.scale.size * self.scale.dtype.itemsize)

    def dequantize(self) -> jax.Array:
        q = _unpack_int4(self.q) if self.bits == 4 else self.q
        d, n = q.shape[-2], q.shape[-1]
        groups = self.scale.shape[-2]
        qg = q.astype(jnp.float32).reshape(*q.shape[:-2], groups,
                                           d // groups, n)
        out = qg * self.scale[..., :, None, :]
        return out.reshape(*q.shape[:-2], d, n)


def _pack_int4(q: jax.Array) -> jax.Array:
    """int8 codes in [-8, 7], (..., d, n) -> uint8 nibbles (..., d//2, n)."""
    u = q.astype(jnp.int32) & 0xF
    lo, hi = u[..., 0::2, :], u[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def _unpack_int4(packed: jax.Array) -> jax.Array:
    """uint8 nibbles (..., d2, n) -> sign-extended int8 codes (..., d2*2, n)."""
    p = packed.astype(jnp.int32)
    lo, hi = p & 0xF, (p >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    pair = jnp.stack([lo, hi], axis=-2)                  # (..., d2, 2, n)
    out = pair.reshape(*packed.shape[:-2], packed.shape[-2] * 2,
                       packed.shape[-1])
    return out.astype(jnp.int8)


W4_GROUP = 32   # contraction-axis scale-group size for int4 (AWQ-style)


def quantize(w: jax.Array, mode: str) -> QTensor:
    """Symmetric quantization of one (..., in, out) weight.

    Scales are per output channel; w4 additionally groups the contraction
    axis (W4_GROUP rows per scale) — 15 int4 levels need finer scale
    granularity than a whole-column amax to stay usable.
    """
    if mode not in MODES:
        raise ValueError(f"unknown quant mode {mode!r}; known: {MODES}")
    bits = 4 if mode == "w4" else 8
    act_bits = 8 if mode == "w8a8" else 0
    qmax = _QMAX[bits]
    d, n = w.shape[-2], w.shape[-1]
    g = W4_GROUP if (bits == 4 and d % W4_GROUP == 0) else d
    wf = w.astype(jnp.float32).reshape(*w.shape[:-2], d // g, g, n)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax           # (..., G, 1, n)
    q = jnp.clip(jnp.round(wf / scale), -qmax, qmax).astype(jnp.int8)
    q = q.reshape(*w.shape[:-2], d, n)
    scale = scale[..., 0, :]                         # (..., G, n)
    if bits == 4:
        if d % 2:
            raise ValueError(f"w4 needs an even contraction dim, got {w.shape}")
        q = _pack_int4(q)
    return QTensor(q, scale, bits, act_bits)


def quantize_act(x: jax.Array):
    """Dynamic per-row int8 activation quantization (contraction = last axis).

    Returns (q int8, scale f32 with last axis reduced to 1)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_tree(tree: Dict, mode: str,
                  names: FrozenSet[str] = DENSE_WEIGHTS) -> Dict:
    """Quantize every dense-projection leaf of a param tree.

    Selection is by leaf name (the plan key), not shape: only weights the
    models consume through ``layers.dense`` are converted, so
    einsum-consumed params keep their layout. The ``moe`` subtree is
    excluded wholesale — routed expert weights reuse the dense-MLP leaf
    names but are consumed by the GShard dispatch einsums (models/moe.py).
    Leading stacking dims pass through — scale and codes both keep the
    (layers, ...) prefix that scan/slicing expect.
    """
    if mode not in MODES:
        raise ValueError(f"unknown quant mode {mode!r}; known: {MODES}")

    def rec(node, key):
        if key == "moe":
            return node
        if isinstance(node, dict):
            return {k: rec(v, k) for k, v in node.items()}
        if key in names and getattr(node, "ndim", 0) >= 2:
            return quantize(node, mode)
        return node
    return rec(tree, "")


def dequantize_tree(tree):
    """Inverse of quantize_tree: QTensor leaves -> f32 dense weights."""
    return jax.tree.map(
        lambda x: x.dequantize() if isinstance(x, QTensor) else x,
        tree, is_leaf=lambda x: isinstance(x, QTensor))


def tree_weight_bytes(tree) -> int:
    """Actual bytes of a (possibly partially quantized) param tree."""
    total = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        else:
            total += int(leaf.size * leaf.dtype.itemsize)
    return total

"""Baseline execution-profile policies the paper implicitly compares
against: device-only, full-offload, random, and a per-step greedy oracle.

The greedy oracle enumerates every (version, cut) pair per UAV under the
*current* state and picks the per-UAV reward argmax — since Eq. 8 averages
a per-UAV score, per-UAV argmax is the per-step optimum (the RL agent can
only beat it through multi-step battery/queue effects).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.env import EnvConfig, ProfileTables, action_costs


def device_only(cfg: EnvConfig, tables: ProfileTables, state, rng=None):
    """Lightweight version, run everything locally (last cut)."""
    n = cfg.n_uavs
    return jnp.stack([jnp.zeros((n,), jnp.int32),
                      jnp.full((n,), tables.n_cuts - 1, jnp.int32)], -1)


def full_offload(cfg: EnvConfig, tables: ProfileTables, state, rng=None):
    """Heavy version, cut as early as possible."""
    n = cfg.n_uavs
    j = (tables.version_valid[state["model_id"]].sum(-1) - 1).astype(jnp.int32)
    return jnp.stack([j, jnp.zeros((n,), jnp.int32)], -1)


def random_policy(cfg: EnvConfig, tables: ProfileTables, state, rng):
    n = cfg.n_uavs
    k1, k2 = jax.random.split(rng)
    nv = tables.version_valid[state["model_id"]].sum(-1).astype(jnp.int32)
    j = jax.random.randint(k1, (n,), 0, tables.n_versions) % nv
    k = jax.random.randint(k2, (n,), 0, tables.n_cuts)
    return jnp.stack([j, k], -1).astype(jnp.int32)


def greedy_oracle(cfg: EnvConfig, tables: ProfileTables, state, rng=None):
    """Per-step per-UAV reward argmax over all (j, k)."""
    n = cfg.n_uavs
    V, K = tables.n_versions, tables.n_cuts
    w = cfg.weights

    jj, kk = jnp.meshgrid(jnp.arange(V), jnp.arange(K), indexing="ij")
    pairs = jnp.stack([jj.ravel(), kk.ravel()], -1).astype(jnp.int32)  # (VK,2)

    def score(pair):
        actions = jnp.tile(pair[None], (n, 1))
        acc_s, lat_s, en_s, _, _, stab_s = action_costs(
            cfg, tables, state, actions)
        valid = tables.version_valid[state["model_id"], pair[0]]
        s = (w.w_acc * acc_s + w.w_lat * lat_s + w.w_energy * en_s
             + w.w_stab * stab_s)
        return jnp.where(valid > 0, s, -jnp.inf)

    scores = jax.vmap(score)(pairs)          # (VK, n)
    best = jnp.argmax(scores, axis=0)        # (n,)
    return pairs[best]


POLICIES = {
    "device_only": device_only,
    "full_offload": full_offload,
    "random": random_policy,
    "greedy_oracle": greedy_oracle,
}

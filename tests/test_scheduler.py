"""Continuous-batching scheduler: admission, retirement, correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init
from repro.serving import ServeConfig, ServingEngine
from repro.serving.scheduler import ContinuousBatchingServer, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init(cfg, jax.random.key(0))
    return cfg, params


def test_all_requests_complete(setup):
    cfg, params = setup
    srv = ContinuousBatchingServer(cfg, params, max_batch=3, cache_len=64)
    r = np.random.default_rng(1)
    reqs = [Request(rid=i, tokens=r.integers(
                0, cfg.vocab_size, int(r.integers(3, 10))).astype(np.int32),
                max_new_tokens=4 + i % 3) for i in range(8)]
    for q in reqs:
        srv.submit(q)
    done = srv.run()
    assert len(done) == 8
    assert all(q.done for q in done)
    assert srv.stats.admitted == 8
    # never more than max_batch slots in flight
    assert srv.stats.prefills >= 3   # 8 requests through 3 slots


def test_matches_offline_engine(setup):
    """Same-prompt cohort must produce the same tokens as the plain engine."""
    cfg, params = setup
    r = np.random.default_rng(2)
    prompts = r.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    n_new = 5

    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=n_new,
                                                 cache_len=64))
    want = np.asarray(eng.generate({"tokens": jnp.asarray(prompts)}))

    srv = ContinuousBatchingServer(cfg, params, max_batch=2, cache_len=64)
    for i in range(2):
        srv.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=n_new))
    done = sorted(srv.run(), key=lambda q: q.rid)
    got = np.asarray([q.out for q in done])
    np.testing.assert_array_equal(got, want)


def test_eos_early_stop(setup):
    cfg, params = setup
    srv = ContinuousBatchingServer(cfg, params, max_batch=1, cache_len=64)
    # pick eos = the model's first greedy token so it stops immediately
    probe = ContinuousBatchingServer(cfg, params, max_batch=1, cache_len=64)
    probe.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=1))
    first = probe.run()[0].out[0]
    srv.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                       max_new_tokens=50, eos_id=first))
    done = srv.run()
    assert len(done[0].out) == 1   # stopped at eos immediately

"""EdgeRL-routed split inference on a transformer (the paper's deployment
pattern mapped to the TPU stack, DESIGN.md §2-3).

The controller trains on the TPU-adapted env (device submesh <-> server
submesh, ICI uplink) whose version axis is the repro.quant registry
(bf16 / w8 / w4); its greedy decisions then route request batches:
(version j, cut l) -> the matching *quantized* head jit on the "device",
activation across the link (int8 for w8), tail jit on the "server".
Prints per-slot decisions with the measured activation bytes that cross
the link and the env's cost estimates.

    PYTHONPATH=src python examples/split_serving.py [--arch qwen2-0.5b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (A2CConfig, decide, env_reset, env_step, make_tpu_env,
                        resolve_selection, train_agent, transformer_profile)
from repro.core.env import action_costs
from repro.models import init
from repro.quant import DEFAULT_VERSIONS
from repro.serving import SplitServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--episodes", type=int, default=120)
    ap.add_argument("--slots", type=int, default=6)
    args = ap.parse_args()

    # 1) controller: train A2C on the TPU-adapted EdgeRL env, profiled on
    #    the reduced arch so its table indices address the executable model
    env_cfg, tables = make_tpu_env([args.arch], reduced=True)
    print(f"training controller on TPU env for {args.episodes} episodes ...")
    agent, _ = train_agent(env_cfg, tables, A2CConfig(episodes=args.episodes))

    # 2) executor: reduced model + quantized version params + split engine
    cfg = get_config(args.arch).reduced()
    profile = transformer_profile(cfg)
    params = init(cfg, jax.random.key(0))
    engine = SplitServingEngine(cfg, params, versions=DEFAULT_VERSIONS)
    toks = (jnp.arange(2 * 32, dtype=jnp.int32).reshape(2, 32) * 11) \
        % cfg.vocab_size
    batch = {"tokens": toks}
    if cfg.cross_attn_every:
        batch["media"] = jnp.zeros((2, cfg.n_media_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["enc_frames"] = jnp.zeros((2, cfg.encoder_seq, cfg.d_model))

    # 3) serve: each slot, controller decides -> engine executes that
    #    (version, cut) with the matching quantized params
    state = env_reset(env_cfg, tables, jax.random.key(7))
    rng = jax.random.key(3)
    print(f"\n{'slot':>4} {'ver':>5} {'cut':>12} {'act_bytes':>10} "
          f"{'est_lat_ms':>10} {'est_E_J':>8}")
    for t in range(args.slots):
        actions = decide(agent, env_cfg, tables, state)
        j, k = int(actions[0, 0]), int(actions[0, 1])
        version, cut = resolve_selection(cfg, profile, j, k)
        logits, nbytes = engine.infer(batch, cut, version)
        costs = action_costs(env_cfg, tables, state, actions)
        t_total, e_inf = costs[3], costs[4]
        print(f"{t:4d} {version:>5} {str(cut):>12} {nbytes:10d} "
              f"{float(t_total[0])*1e3:10.2f} {float(e_inf[0]):8.3f}")
        rng, k_env = jax.random.split(rng)
        state, _, _ = env_step(env_cfg, tables, state, actions, k_env)
    print("\nlogits shape:", logits.shape, "(classification-style scoring)")


if __name__ == "__main__":
    main()

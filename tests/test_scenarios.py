"""repro.scenarios: preset registry, scenario determinism (every preset,
twice, one seed -> identical ComparisonReport metrics), paired streams
through run_scenario, and the CLI surface (scripts/simulate.py
--scenario / --save-policy / --load-policy)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.scenarios import get_scenario, run_scenario, scenario_names

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_preset_registry_complete():
    names = scenario_names()
    assert len(names) >= 6
    for name in ("paper-exact", "paper-mmpp-burst", "diurnal-fleet",
                 "degraded-link", "tpu-submesh", "tpu-execute"):
        assert name in names, names


def test_get_scenario_miss_lists_valid_names():
    with pytest.raises(KeyError) as e:
        get_scenario("no-such-scenario")
    for name in scenario_names():
        assert name in str(e.value)


def test_run_scenario_rejects_unknown_policy_before_building():
    sc = get_scenario("paper-mmpp-burst")
    with pytest.raises(KeyError, match="valid names"):
        run_scenario(sc, ("oracle",))


# --------------------------------------------------------------------------
# determinism: the paired-seed guarantee extends to the scenario API
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(scenario_names()))
def test_every_preset_is_deterministic(name):
    """Each registered preset run twice under one seed produces
    identical ComparisonReport metrics (wall-clock-dependent execute
    cross-check fields excluded by comparing the metric dicts)."""
    sc = get_scenario(name)
    kw = dict(policies=("device_only",), n_requests=400, seeds=(0,))
    r1 = run_scenario(sc, **kw)
    r2 = run_scenario(sc, **kw)
    a, b = r1.results["device_only"], r2.results["device_only"]
    assert a.mean == b.mean
    assert a.per_seed == b.per_seed


def test_run_scenario_pairs_streams_across_policies():
    sc = get_scenario("degraded-link")
    rep = run_scenario(sc, ("device_only", "full_offload"),
                       n_requests=1500, seeds=(0, 1))
    d = rep.results["device_only"].per_seed
    f = rep.results["full_offload"].per_seed
    for i in range(2):
        assert d[i]["requests"] == f[i]["requests"]   # same offered stream
    assert rep.seeds == (0, 1)
    # report serializes (json round-trip used by the CLI --json path)
    blob = json.dumps(rep.to_json(), default=str)
    assert "device_only" in blob


# --------------------------------------------------------------------------
# CLI surface
# --------------------------------------------------------------------------

def _cli(*argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "simulate.py"),
         *argv],
        capture_output=True, text=True, timeout=timeout, env=env)


def test_cli_list_scenarios():
    out = _cli("--list-scenarios")
    assert out.returncode == 0, out.stderr
    for name in scenario_names():
        assert name in out.stdout
    assert len([ln for ln in out.stdout.splitlines()
                if ln and not ln.startswith(" ")]) >= 6


def test_cli_save_then_load_reproduces_metrics(tmp_path):
    """The acceptance flow: train once with --save-policy, reload with
    --load-policy; paired-seed metrics identical, no retraining."""
    art = str(tmp_path / "ctrl.npz")
    ja, jb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    common = ("--scenario", "paper-mmpp-burst", "--compare", "a2c",
              "--episodes", "2", "--requests", "600", "--seeds", "0,1")
    out = _cli(*common, "--save-policy", art, "--json", ja)
    assert out.returncode == 0, out.stderr
    assert os.path.exists(art)
    out = _cli(*common, "--load-policy", art, "--json", jb)
    assert out.returncode == 0, out.stderr
    a = json.load(open(ja))["policies"]["a2c"]
    b = json.load(open(jb))["policies"]["a2c"]
    assert a["trained"] and not b["trained"]
    assert a["mean"] == b["mean"]
    assert a["per_seed"] == b["per_seed"]


def test_cli_rejects_unknown_policy_with_valid_names():
    out = _cli("--scenario", "paper-mmpp-burst", "--compare", "oracle")
    assert out.returncode != 0
    assert "greedy_oracle" in out.stderr

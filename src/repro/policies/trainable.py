"""Trainable policies (A2C — the paper's algorithm — and the PPO
ablation) behind the Policy protocol.

Lifecycle: ``build`` (untrained nets bound to one env) → ``train(seed,
trace)`` (batched vmapped-env updates; a workload trace switches the
task feature to trace-driven offered load) → ``save``/``load`` (one-file
.npz artifacts via ``repro.checkpointing``, structure-checked on
restore) → greedy ``act``. A trained controller is therefore a reusable
artifact: ``scripts/simulate.py --save-policy`` / ``--load-policy``
round-trips it without retraining, reproducing bit-identical actions.
"""
from __future__ import annotations

import jax

from repro.checkpointing import load_tree, save_tree
from repro.core import a2c as A2C
from repro.core import ppo as PPO
from repro.core.actor_critic import greedy_actions, init_agent
from repro.core.controller import make_task_sampler
from repro.core.env import observe
from repro.policies.base import Policy, PolicySpec, register

_ARTIFACT_SCHEMA = 1


class TrainablePolicy(Policy):
    trainable = True

    def __init__(self, env_cfg, tables, config):
        super().__init__(env_cfg, tables)
        self.config = config
        self.params = None
        self.history = None

    # -- subclass hooks ----------------------------------------------------
    def _init_params(self, rng):
        raise NotImplementedError

    def _train(self, seed, trace, log_every):
        raise NotImplementedError

    # -- lifecycle ---------------------------------------------------------
    def train(self, seed: int = 0, trace=None, log_every: int = 0):
        """Train from scratch; returns the per-episode stats history."""
        self.params, self.history = self._train(seed, trace, log_every)
        return self.history

    def act(self, state, rng=None):
        if self.params is None:
            raise RuntimeError(f"policy {self.name!r}: call train() or "
                               "load() before act()")
        obs = observe(self.env_cfg, self.tables, state).reshape(-1)
        valid = self.tables.version_valid[state["model_id"]]
        return greedy_actions(self.params, obs, valid)

    def _cache_token(self):
        return self.params

    def save(self, path: str) -> str:
        if self.params is None:
            raise RuntimeError(f"policy {self.name!r}: nothing to save "
                               "before train() or load()")
        return save_tree(path, self.params,
                         meta={"schema": _ARTIFACT_SCHEMA,
                               "policy": self.name})

    def load(self, path: str) -> "TrainablePolicy":
        """Restore a ``save``d artifact. The template params (same env
        dims, same net widths) structure-check the restore, so loading a
        controller trained for a different fleet fails loudly."""
        template = self.params if self.params is not None \
            else self._init_params(jax.random.key(0))
        params, meta = load_tree(path, template)
        saved_as = meta.get("policy")
        if saved_as is not None and saved_as != self.name:
            raise ValueError(f"artifact {path!r} holds a {saved_as!r} "
                             f"policy, not {self.name!r}")
        self.params = params
        return self


class A2CPolicy(TrainablePolicy):
    """The paper's controller (Sec. II-C/D)."""

    name = "a2c"        # artifacts stay loadable from direct construction

    def __init__(self, env_cfg, tables, **cfg_kw):
        super().__init__(env_cfg, tables, A2C.A2CConfig(**cfg_kw))

    def _init_params(self, rng):
        return init_agent(self.env_cfg, self.tables, self.config, rng)

    def _train(self, seed, trace, log_every):
        return A2C.train(self.env_cfg, self.tables, self.config,
                         jax.random.key(seed), log_every=log_every,
                         task_sampler=make_task_sampler(self.env_cfg, trace,
                                                        seed))


class PPOPolicy(TrainablePolicy):
    """Beyond-paper ablation: clipped-surrogate PPO on the same nets."""

    name = "ppo"

    def __init__(self, env_cfg, tables, **cfg_kw):
        super().__init__(env_cfg, tables, PPO.PPOConfig(**cfg_kw))

    def _init_params(self, rng):
        return init_agent(self.env_cfg, self.tables, self.config.base, rng)

    def _train(self, seed, trace, log_every):
        return PPO.train(self.env_cfg, self.tables, self.config,
                         jax.random.key(seed), log_every=log_every,
                         task_sampler=make_task_sampler(self.env_cfg, trace,
                                                        seed))


register(PolicySpec(
    "a2c", A2CPolicy, trainable=True,
    description="A2C controller (the paper's algorithm); kwargs -> "
                "A2CConfig (episodes, entropy_coef, batch_envs, ...)"))
register(PolicySpec(
    "ppo", PPOPolicy, trainable=True,
    description="PPO ablation on the shared nets; kwargs -> PPOConfig"))

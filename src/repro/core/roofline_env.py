"""Beyond-paper integration: EdgeRL profiles from *measured* dry-run
artifacts.

The paper profiles its CNNs by running them on the testbed. Our TPU
analogue of "running on the testbed" is the dry-run: per (arch, shape)
we have scan-aware compiled FLOPs, fused HBM bytes and collective bytes
(results/dryrun.jsonl). ``dryrun_profiles`` converts those records into
EdgeRL ``ModelProfile``s — per-layer FLOPs scaled so the arch total
matches the MEASURED compiled FLOPs (not the analytic estimate), i.e.
the controller optimizes against what the compiler actually emitted,
including remat/dispatch overheads the analytic model misses.

    cfg, tables = make_dryrun_tpu_env(["qwen2-0.5b", ...],
                                      results="results/dryrun.jsonl")
"""
from __future__ import annotations

import json
from typing import Dict, Sequence, Tuple

from repro.configs import SHAPES, get_config
from repro.core.controller import _TPU_LATENCY, _TPU_POWER
from repro.core.env import EnvConfig, ProfileTables, build_tables
from repro.core.profiles import ModelProfile
from repro.core.reward import RewardWeights


def _load_records(path: str) -> Dict[Tuple[str, str], dict]:
    out = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if (r.get("status") == "ok" and r.get("mesh") == "single"
                    and r.get("variant", "baseline") == "baseline"):
                out[(r["arch"], r["shape"])] = r
    return out


def dryrun_profile(arch: str, records, *, shape: str = "prefill_32k",
                   n_cuts: int = 4) -> ModelProfile:
    """ModelProfile whose total FLOPs equal the measured compiled FLOPs.

    The version axis is the repro.quant registry, like transformer_profile:
    the bf16 FLOPs are calibrated to the measured compiled FLOPs, then each
    quantized version applies its MXU cost scale on top of the calibrated
    numbers (quantization changes the MAC throughput, not the compiled op
    graph the dry-run measured). Version construction is shared with
    transformer_profile (profiles.build_quant_versions)."""
    from repro.core.profiles import build_quant_versions, spread_cuts
    from repro.core.transformer_cost import block_flops_per_token

    cfg = get_config(arch)
    rec = records.get((arch, shape))
    info = SHAPES[shape]
    tokens = info["global_batch"] * info["seq_len"]

    analytic = block_flops_per_token(cfg, seq_ctx=info["seq_len"])
    scale = 1.0
    if rec:
        # calibrate to the measured compiled FLOPs per token
        measured_per_tok = rec["jaxpr_flops"] / tokens
        scale = measured_per_tok / max(sum(analytic), 1.0)
    versions = build_quant_versions(cfg, analytic,
                                    seq_len=info["seq_len"],
                                    cuts=spread_cuts(len(analytic), n_cuts),
                                    flops_scale=scale)
    return ModelProfile(arch, versions)


def make_dryrun_tpu_env(arch_names: Sequence[str],
                        results: str = "results/dryrun.jsonl",
                        weights: RewardWeights = RewardWeights(),
                        **env_kw) -> Tuple[EnvConfig, ProfileTables]:
    records = _load_records(results)
    profs = [dryrun_profile(a, records) for a in arch_names]
    tables = build_tables(profs)
    cfg = EnvConfig(n_uavs=len(arch_names), latency=_TPU_LATENCY,
                    power=_TPU_POWER, weights=weights.normalized(),
                    frames_per_slot=1000.0, **env_kw)
    return cfg, tables

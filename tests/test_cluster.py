"""repro.cluster: pool/topology/autoscaler units, the degenerate
1-server bit-parity guarantee against the classic single-server fleet
(engines loop and vectorized), server-axis pricing parity numpy≡jnp,
router baselines over the widened (version, cut, server) action space,
and the cluster scenario presets."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (Autoscaler, AutoscalerConfig, ServerPool,
                           ServerSpec, build_cluster, get_pool,
                           get_topology, pool_names, topology_names)
from repro.core import (A2CConfig, env_reset, env_step, init_agent,
                        make_paper_env)
from repro.core import pricing
from repro.core.actor_critic import greedy_actions, sample_actions
from repro.core.env import OBS_FEATURES, observe
from repro.core.latency import LatencyParams
from repro.policies import get_policy_spec, policy_names
from repro.sim import AnalyticalBackend, FleetConfig, get_trace, simulate


def _cluster(pool="single", topology="uniform", devices=4):
    servers = get_pool(pool)
    return build_cluster(servers,
                         get_topology(topology, devices, len(servers)))


def _cluster_env(pool="hetero-4", topology="near-far", devices=4, **kw):
    return make_paper_env(
        n_uavs=devices,
        latency=LatencyParams(server_flops=devices * 0.55e12,
                              bw_max_bps=1e9),
        slot_seconds=10.0, peak_rps=30.0, frames_per_slot=300.0,
        cluster=_cluster(pool, topology, devices), **kw)


# --------------------------------------------------------------------------
# registries: the KeyError-listing convention
# --------------------------------------------------------------------------

def test_pool_registry_miss_lists_valid_names():
    assert {"single", "uniform-4", "hetero-4"} <= set(pool_names())
    with pytest.raises(KeyError) as e:
        get_pool("no-such-pool")
    for name in pool_names():
        assert name in str(e.value)


def test_topology_registry_miss_lists_valid_names():
    assert {"uniform", "near-far", "tiered"} <= set(topology_names())
    with pytest.raises(KeyError) as e:
        get_topology("no-such-topology", 4, 2)
    for name in topology_names():
        assert name in str(e.value)


def test_build_cluster_rejects_server_count_mismatch():
    servers = get_pool("hetero-4")
    with pytest.raises(ValueError, match="4 servers"):
        build_cluster(servers[:2], get_topology("uniform", 4, 4))


def test_routers_registered_as_policies():
    assert {"round_robin", "join_shortest_queue",
            "local_only"} <= set(policy_names())


def test_router_rejects_non_cluster_env():
    env_cfg, tables = make_paper_env()
    with pytest.raises(ValueError, match="cluster-mode env"):
        get_policy_spec("round_robin").build(env_cfg, tables)


# --------------------------------------------------------------------------
# pool / autoscaler units
# --------------------------------------------------------------------------

def test_pool_effective_matches_nominal_at_initial_state():
    cluster = _cluster("hetero-4", "near-far")
    env_cfg, _ = _cluster_env()
    pool = ServerPool(cluster)
    eff = pool.effective(env_cfg.latency, env_cfg)
    flops, service = cluster.nominal(env_cfg.latency, xp=np)
    np.testing.assert_array_equal(eff.flops, flops)
    np.testing.assert_array_equal(eff.service_s, service)


def test_pool_meters_replica_energy_cubed_in_dvfs():
    spec = ServerSpec(dvfs=(0.5, 1.0), p_replica_w=40.0, replicas=2,
                      max_replicas=2)
    cluster = build_cluster((spec,), get_topology("uniform", 1, 1))
    pool = ServerPool(cluster)
    pool.tick(np.zeros(1), slot_seconds=10.0)   # 2 replicas at dvfs 1.0
    assert pool.energy_j == pytest.approx(40.0 * 2 * 1.0 ** 3 * 10.0)
    pool.dvfs_idx[:] = 0                        # walk down the ladder
    pool.tick(np.zeros(1), slot_seconds=10.0)
    assert pool.energy_j == pytest.approx(
        40.0 * 2 * 10.0 + 40.0 * 2 * 0.5 ** 3 * 10.0)
    assert pool.summary()["mean_replicas"] == 2.0


def test_autoscaler_threshold_scales_dvfs_first_then_replicas():
    spec = ServerSpec(dvfs=(0.6, 1.0), max_replicas=2, p_replica_w=45.0)
    cluster = build_cluster((spec,), get_topology("uniform", 1, 1))
    pool = ServerPool(cluster)
    pool.dvfs_idx[:] = 0    # start below the top DVFS step
    asc = Autoscaler(AutoscalerConfig(policy="threshold"), 1)
    deep = np.asarray([50.0])
    decisions = asc.step(pool, deep)
    assert [d["action"] for d in decisions] == ["dvfs_up"]
    assert decisions[0]["queue"] == 50.0    # measured-depth trigger
    assert pool.dvfs_idx[0] == 1 and pool.replicas[0] == 1   # DVFS first
    assert [d["action"] for d in asc.step(pool, deep)] == ["replica_up"]
    assert pool.replicas[0] == 2                             # then replica
    assert asc.step(pool, deep) == []                        # at capacity


def test_autoscaler_threshold_scales_down_replicas_first():
    spec = ServerSpec(dvfs=(0.6, 1.0), replicas=2, max_replicas=2)
    cluster = build_cluster((spec,), get_topology("uniform", 1, 1))
    pool = ServerPool(cluster)
    asc = Autoscaler(AutoscalerConfig(policy="threshold"), 1)
    idle = np.asarray([0.0])
    assert [d["action"] for d in asc.step(pool, idle)] == ["replica_down"]
    assert pool.replicas[0] == 1 and pool.dvfs_idx[0] == 1   # replica first
    assert [d["action"] for d in asc.step(pool, idle)] == ["dvfs_down"]
    assert pool.dvfs_idx[0] == 0                             # then DVFS
    assert asc.step(pool, idle) == []                        # at the floor


def test_autoscaler_hysteresis_waits_for_patience_then_cools_down():
    spec = ServerSpec(dvfs=(0.6, 1.0), max_replicas=2)
    cluster = build_cluster((spec,), get_topology("uniform", 1, 1))
    pool = ServerPool(cluster)
    pool.dvfs_idx[:] = 0
    asc = Autoscaler(AutoscalerConfig(policy="hysteresis", patience=3,
                                      cooldown=2), 1)
    deep = np.asarray([50.0])
    assert asc.step(pool, deep) == []     # breach 1
    assert asc.step(pool, deep) == []     # breach 2
    assert len(asc.step(pool, deep)) == 1     # breach 3: acts
    assert pool.dvfs_idx[0] == 1
    assert asc.step(pool, deep) == []     # cooldown epoch 1
    assert asc.step(pool, deep) == []     # cooldown epoch 2
    # the breach never cleared: streak rode through the hold, so the
    # first post-cooldown epoch escalates (replica, DVFS already topped)
    assert len(asc.step(pool, deep)) == 1
    assert pool.replicas[0] == 2
    # a calm epoch resets the streak: no further action
    asc.step(pool, np.asarray([0.0]))
    assert pool.replicas[0] == 2


def test_autoscaler_config_validates():
    with pytest.raises(ValueError, match="valid policies"):
        AutoscalerConfig(policy="magic")
    with pytest.raises(ValueError, match="down_queue"):
        AutoscalerConfig(up_queue=2.0, down_queue=2.0)


# --------------------------------------------------------------------------
# tentpole guarantee: a 1-server pool at uniform topology is the classic
# single-server fleet, bit for bit
# --------------------------------------------------------------------------

def _fleet_run(cluster, policy_name, engine, n_requests=2500, seed=0):
    kw = {"cluster": cluster} if cluster is not None else {}
    env_cfg, tables = make_paper_env(
        n_uavs=4, latency=LatencyParams(server_flops=4 * 0.55e12,
                                        bw_max_bps=1e9),
        slot_seconds=10.0, peak_rps=30.0, frames_per_slot=300.0, **kw)
    model_ids = np.arange(4, dtype=np.int32) % tables.n_models
    policy = get_policy_spec(policy_name).build(env_cfg, tables)
    trace = get_trace("mmpp", rate_low_rps=2.0, rate_high_rps=25.0)
    return simulate(env_cfg, tables, model_ids=model_ids, policy=policy,
                    trace=trace, n_requests=n_requests, seed=seed,
                    backend=AnalyticalBackend(env_cfg, tables),
                    fleet=FleetConfig(slo_s=2.0, engine=engine))


@pytest.mark.parametrize("engine", ["loop", "vectorized"])
@pytest.mark.parametrize("policy", ["greedy_oracle", "full_offload"])
def test_degenerate_pool_bit_identical_to_single_server(engine, policy):
    """The whole cluster path (per-server queues, topology repricing,
    pool-effective service arrays) collapses to exactly the legacy
    single-server fleet when the pool is one baseline server behind a
    uniform topology — per-request latencies and every shared summary
    metric bitwise equal, offloading policies included."""
    legacy = _fleet_run(None, policy, engine)
    degen = _fleet_run(_cluster("single", "uniform"), policy, engine)
    np.testing.assert_array_equal(
        np.asarray(legacy.metrics.latencies_s),
        np.asarray(degen.metrics.latencies_s))
    shared = set(legacy.summary) & set(degen.summary)
    assert shared >= {"mean", "p95", "slo_attainment", "energy_j"} \
        or shared  # schema drift guard: at minimum the dicts overlap
    for k in sorted(shared):
        assert legacy.summary[k] == degen.summary[k], k
    # cluster-only meters ride along without perturbing the physics
    assert {"server_energy_j", "scale_events",
            "mean_replicas"} <= set(degen.summary)


def test_cluster_fleet_bit_reproducible_with_autoscaler():
    cluster = _cluster("hetero-4", "near-far")
    runs = []
    for _ in range(2):
        env_cfg, tables = _cluster_env()
        model_ids = np.arange(4, dtype=np.int32) % tables.n_models
        policy = get_policy_spec("join_shortest_queue").build(env_cfg,
                                                              tables)
        res = simulate(env_cfg, tables, model_ids=model_ids, policy=policy,
                       trace=get_trace("poisson", rate_rps=8.0),
                       n_requests=2000, seed=0,
                       backend=AnalyticalBackend(env_cfg, tables),
                       fleet=FleetConfig(slo_s=2.0),
                       autoscaler=AutoscalerConfig(policy="hysteresis"))
        runs.append(res)
    a, b = runs
    assert a.summary == b.summary
    np.testing.assert_array_equal(np.asarray(a.metrics.latencies_s),
                                  np.asarray(b.metrics.latencies_s))
    assert a.server_hist is not None
    assert a.server_hist.shape == (cluster.n_servers,)
    assert a.server_hist.sum() > 0


def test_scan_engine_rejects_cluster_mode():
    env_cfg, tables = _cluster_env()
    model_ids = np.arange(4, dtype=np.int32) % tables.n_models
    policy = get_policy_spec("device_only").build(env_cfg, tables)
    with pytest.raises(ValueError, match="scan"):
        simulate(env_cfg, tables, model_ids=model_ids, policy=policy,
                 trace=get_trace("poisson", rate_rps=8.0),
                 n_requests=500, seed=0,
                 backend=AnalyticalBackend(env_cfg, tables),
                 fleet=FleetConfig(engine="scan"))


# --------------------------------------------------------------------------
# pricing: the server axis, numpy ≡ jnp
# --------------------------------------------------------------------------

def _cluster_view_actions(cfg, tables, seed, n):
    r = np.random.default_rng(seed)
    lp, pw = cfg.latency, cfg.power
    S = cfg.cluster.n_servers
    srv_flops, srv_service_s = cfg.cluster.nominal(lp, xp=np)
    view = pricing.StateView(
        model_id=r.integers(0, tables.n_models, n).astype(np.int32),
        bandwidth=r.uniform(lp.bw_min_bps, lp.bw_max_bps, n)
        .astype(np.float32),
        p_tx=r.uniform(pw.p_tx_min, pw.p_tx_max, n).astype(np.float32),
        queue=r.uniform(0.0, 12.0, S).astype(np.float32),
        load=r.uniform(0.0, 1.0, n).astype(np.float32),
        srv_flops=srv_flops.astype(np.float32),
        srv_service_s=srv_service_s.astype(np.float32),
        link_scale=np.asarray(cfg.cluster.link_scale, np.float32),
        link_rtt_s=np.asarray(cfg.cluster.link_rtt_s, np.float32))
    actions = np.stack([r.integers(0, tables.n_versions, n),
                        r.integers(0, tables.n_cuts, n),
                        r.integers(0, S, n)], axis=-1).astype(np.int32)
    return view, actions


@pytest.mark.parametrize("n", [1, 8])
def test_pricing_server_axis_numpy_jnp_parity(n):
    """Per-server tables + a server action column through xp=np and
    xp=jnp agree to 1e-6 on every PricingBreakdown field."""
    cfg, tables = _cluster_env(devices=n)
    np_tables = pricing.numpy_tables(tables)
    for seed in (0, 1):
        view, actions = _cluster_view_actions(cfg, tables, seed, n)
        br_np = pricing.price_actions(cfg, np_tables, view, actions, xp=np)
        jview = pricing.StateView(
            **{f.name: (None if getattr(view, f.name) is None
                        else jnp.asarray(getattr(view, f.name)))
               for f in dataclasses.fields(view)})
        br_j = pricing.price_actions(cfg, tables, jview,
                                     jnp.asarray(actions), xp=jnp)
        for f in dataclasses.fields(pricing.PricingBreakdown):
            x = np.asarray(getattr(br_np, f.name))
            y = np.asarray(getattr(br_j, f.name))
            if f.name == "offloaded":
                np.testing.assert_array_equal(x, y, err_msg=f.name)
            else:
                np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6,
                                           err_msg=f.name)


def test_pricing_view_defaults_to_nominal_cluster_arrays():
    """A cluster view without per-server arrays prices at the nominal
    operating point (ClusterParams.nominal + the static link matrices)
    — what env training sees."""
    cfg, tables = _cluster_env()
    np_tables = pricing.numpy_tables(tables)
    view, actions = _cluster_view_actions(cfg, tables, 2, 4)
    bare = dataclasses.replace(view, srv_flops=None, srv_service_s=None,
                               link_scale=None, link_rtt_s=None)
    br_full = pricing.price_actions(cfg, np_tables, view, actions, xp=np)
    br_bare = pricing.price_actions(cfg, np_tables, bare, actions, xp=np)
    np.testing.assert_allclose(np.asarray(br_bare.t_total),
                               np.asarray(br_full.t_total),
                               rtol=1e-6, atol=1e-6)


def test_pricing_queue_gated_on_chosen_server_tail():
    """A terminal cut runs no tail on the chosen server: even a deep
    per-server queue must charge no queue wait to that action."""
    cfg, tables = _cluster_env()
    np_tables = pricing.numpy_tables(tables)
    view, _ = _cluster_view_actions(cfg, tables, 0, 4)
    view = dataclasses.replace(
        view, queue=np.full(cfg.cluster.n_servers, 500.0, np.float32))
    terminal = np.stack([np.zeros(4, np.int32),
                         np.full(4, tables.n_cuts - 1, np.int32),
                         np.arange(4, dtype=np.int32)], -1)
    br = pricing.price_actions(cfg, np_tables, view, terminal, xp=np)
    assert not np.any(np.asarray(br.offloaded))
    np.testing.assert_array_equal(np.asarray(br.queue_s), 0.0)
    # the same cuts made non-terminal see the per-server queue
    split = np.stack([np.zeros(4, np.int32), np.zeros(4, np.int32),
                      np.arange(4, dtype=np.int32)], -1)
    br2 = pricing.price_actions(cfg, np_tables, view, split, xp=np)
    assert np.all(np.asarray(br2.queue_s)[np.asarray(br2.offloaded)] > 0)


def test_pricing_server_axis_reprices_link_per_target():
    """Identical (version, cut) to a far server pays the degraded link
    and its RTT: tx_s strictly above the near server's."""
    cfg, tables = _cluster_env(pool="hetero-4", topology="near-far")
    np_tables = pricing.numpy_tables(tables)
    view, _ = _cluster_view_actions(cfg, tables, 1, 4)
    near = np.asarray(cfg.cluster.link_scale).argmax(axis=1)
    far = np.asarray(cfg.cluster.link_scale).argmin(axis=1)
    a_near = np.stack([np.zeros(4, np.int32), np.zeros(4, np.int32),
                       near.astype(np.int32)], -1)
    a_far = np.stack([np.zeros(4, np.int32), np.zeros(4, np.int32),
                      far.astype(np.int32)], -1)
    tx_near = np.asarray(pricing.price_actions(
        cfg, np_tables, view, a_near, xp=np).tx_s)
    tx_far = np.asarray(pricing.price_actions(
        cfg, np_tables, view, a_far, xp=np).tx_s)
    assert np.all(tx_far > tx_near)


# --------------------------------------------------------------------------
# env + controller: the widened action space
# --------------------------------------------------------------------------

def test_env_widens_obs_and_action_space():
    cfg, tables = _cluster_env()
    S = cfg.cluster.n_servers
    assert cfg.n_servers == S and cfg.action_dim == 3
    assert cfg.obs_dim_per_uav == len(OBS_FEATURES) + S - 1
    state = env_reset(cfg, tables, jax.random.key(0))
    assert state["queue"].shape == (S,)
    obs_flat = observe(cfg, tables, state)
    assert obs_flat.shape == (cfg.n_uavs, cfg.obs_dim_per_uav)


def test_agent_learns_server_head_and_samples_triples():
    cfg, tables = _cluster_env()
    params = init_agent(cfg, tables, A2CConfig(), jax.random.key(0))
    assert "srv" in params["actor"]
    state = env_reset(cfg, tables, jax.random.key(1))
    obs_flat = observe(cfg, tables, state).reshape(-1)
    valid = tables.version_valid[state["model_id"]]
    acts = sample_actions(params, obs_flat, valid, jax.random.key(2))
    assert acts.shape == (cfg.n_uavs, 3)
    assert np.all(np.asarray(acts[:, 2]) >= 0)
    assert np.all(np.asarray(acts[:, 2]) < cfg.n_servers)
    greedy = greedy_actions(params, obs_flat, valid)
    assert greedy.shape == (cfg.n_uavs, 3)
    # env consumes the widened actions
    _, reward, _ = env_step(cfg, tables, state, acts, jax.random.key(3))
    assert np.isfinite(float(reward.mean()))


def test_routers_route_where_their_rule_says():
    cfg, tables = _cluster_env(devices=8)
    S = cfg.cluster.n_servers
    state = env_reset(cfg, tables, jax.random.key(0))
    rng = jax.random.key(9)
    rr = get_policy_spec("round_robin").build(cfg, tables)
    acts = np.asarray(rr.act(state, rng))
    t = int(np.asarray(state["t"]))
    np.testing.assert_array_equal(acts[:, 2], (np.arange(8) + t) % S)

    deep = dict(state)
    deep["queue"] = jnp.asarray([9.0, 1.0, 5.0, 7.0])
    jsq = get_policy_spec("join_shortest_queue").build(cfg, tables)
    np.testing.assert_array_equal(np.asarray(jsq.act(deep, rng))[:, 2], 1)

    lo = get_policy_spec("local_only").build(cfg, tables)
    lacts = np.asarray(lo.act(state, rng))
    np.testing.assert_array_equal(lacts[:, 1], tables.n_cuts - 1)
    assert not np.any(np.asarray(pricing.price_actions(
        cfg, pricing.numpy_tables(tables),
        pricing.view_from_state(state), lacts, xp=np).offloaded))


# --------------------------------------------------------------------------
# scenarios: presets + builders
# --------------------------------------------------------------------------

def test_cluster_presets_registered_and_build():
    from repro.scenarios import get_scenario
    for name in ("edge-cluster", "cluster-brownout"):
        sc = get_scenario(name)
        cluster = sc.build_cluster()
        assert cluster.n_servers == 4
        assert cluster.n_devices == sc.devices
        assert sc.build_autoscaler() is not None


def test_autoscale_without_pool_rejected():
    from repro.scenarios import get_scenario
    sc = get_scenario("edge-cluster").replace(pool=None)
    with pytest.raises(ValueError, match="without a server pool"):
        sc.build_autoscaler()


def test_tpu_env_rejects_pool():
    from repro.scenarios import get_scenario
    sc = get_scenario("tpu-submesh").replace(pool="hetero-4")
    with pytest.raises(ValueError, match="single shared server"):
        sc.build_env()

"""Step functions + abstract input specs for training / prefill / decode.

``input_specs(cfg, shape_name)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the same
pattern the dry-run lowers against. ``make_*_step`` return pure functions
suitable for jax.jit with in_shardings from ``step_shardings``.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.configs.base import ModelConfig
from repro.launch import shardings as sh
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update

LONG_WINDOW = 8192   # sliding window applied for long-context shapes


def config_for_shape(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Shape-compatibility overrides for an input shape.

    long_500k requires sub-quadratic attention: archs whose config declares
    no window get an 8k sliding-window override. This is decoupled from
    cfg.versions (the EdgeRL version axis, now the repro.quant registry);
    SSM/hybrid archs run natively.
    """
    if shape_name == "long_500k" and not cfg.ssm:
        if cfg.sliding_window is None and not cfg.block_pattern:
            cfg = cfg.with_overrides(sliding_window=LONG_WINDOW)
    # big-model dry-runs use bf16 params/compute
    return cfg


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, remat: bool = True,
                    microbatches: int = 1):
    """microbatches > 1: gradient accumulation over batch slices (scan) —
    divides live activation memory by the microbatch count at the price of
    re-running the forward/backward per slice (perf knob; §Perf)."""
    def grad_fn(params, mb):
        def loss_fn(p):
            return M.forward_train(cfg, p, mb, remat=remat)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            n = microbatches
            mbs = jax.tree.map(
                lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

            def body(acc, mb):
                (l, met), g = grad_fn(params, mb)
                g_acc, l_acc = acc
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), met

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), metrics = jax.lax.scan(
                body, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        params2, opt_state2, om = adamw_update(opt, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **om)
        return params2, opt_state2, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)
    return serve_step


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict:
    b = {"tokens": _sds((B, S), "int32"), "targets": _sds((B, S), "int32")}
    if cfg.cross_attn_every:
        b["media"] = _sds((B, cfg.n_media_tokens, cfg.d_model), cfg.cdtype)
    if cfg.enc_dec:
        b["enc_frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), cfg.cdtype)
    return b


def batch_axes(cfg: ModelConfig) -> Dict:
    b = {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
    if cfg.cross_attn_every:
        b["media"] = ("batch", None, None)
    if cfg.enc_dec:
        b["enc_frames"] = ("batch", None, None)
    return b


def prefill_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict:
    b = batch_specs(cfg, B, S)
    del b["targets"]
    return b


def prefill_batch_axes(cfg: ModelConfig) -> Dict:
    b = batch_axes(cfg)
    del b["targets"]
    return b


def cache_specs(cfg: ModelConfig, B: int, seq_len: int):
    fn = functools.partial(M.init_cache, cfg, B, seq_len)
    return jax.eval_shape(fn)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """All abstract inputs for one assigned (arch x shape) dry-run."""
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    cfg = config_for_shape(cfg, shape_name)
    if info["kind"] == "train":
        params = M.abstract_params(cfg)
        opt_state = jax.eval_shape(adamw_init, params)
        return {"params": params, "opt_state": opt_state,
                "batch": batch_specs(cfg, B, S)}
    if info["kind"] == "prefill":
        return {"params": M.abstract_params(cfg),
                "batch": prefill_batch_specs(cfg, B, S)}
    # decode
    return {"params": M.abstract_params(cfg),
            "cache": cache_specs(cfg, B, S),
            "token": _sds((B,), "int32"),
            "pos": _sds((), "int32")}


def step_shardings(cfg: ModelConfig, shape_name: str, mesh):
    """NamedSharding trees matching input_specs structure."""
    cfg = config_for_shape(cfg, shape_name)
    rules = sh.logical_rules(cfg, mesh)
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    p_axes = M.param_axes(cfg)
    p_specs = M.abstract_params(cfg)
    p_sh = sh.tree_shardings(mesh, p_axes, p_specs, rules)
    if info["kind"] == "train":
        opt_specs = jax.eval_shape(adamw_init, p_specs)
        opt_sh = {"m": p_sh, "v": p_sh, "step": sh.replicated(mesh)}
        b_sh = sh.tree_shardings(mesh, batch_axes(cfg),
                                 batch_specs(cfg, B, S), rules)
        return {"params": p_sh, "opt_state": opt_sh, "batch": b_sh}
    if info["kind"] == "prefill":
        b_sh = sh.tree_shardings(mesh, prefill_batch_axes(cfg),
                                 prefill_batch_specs(cfg, B, S), rules)
        return {"params": p_sh, "batch": b_sh}
    c_sh = sh.tree_shardings(mesh, M.cache_axes(cfg),
                             cache_specs(cfg, B, S), rules)
    tok_sh = sh.tree_shardings(mesh, {"t": ("batch",)},
                               {"t": _sds((B,), "int32")}, rules)["t"]
    return {"params": p_sh, "cache": c_sh, "token": tok_sh,
            "pos": sh.replicated(mesh)}

"""Mamba-1 selective SSM block (falcon-mamba).

Forward (train/prefill) uses a chunked scan: an outer ``lax.scan`` over
sequence chunks carries the (B, d_inner, d_state) recurrent state, and a
short inner scan runs the recurrence within each chunk — the discretized
(B, S, d_inner, d_state) tensor is never materialized for the full
sequence. Decode is a single recurrent step against {conv, ssm} state.
The Pallas kernel (kernels/mamba_scan.py) implements the same chunked
recurrence with VMEM tiling; kernels/ref.py oracles against this module.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models.layers import causal_conv1d, causal_conv1d_step

SSM_CHUNK = 256


def plan_ssm(cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    n, r, k = cfg.ssm_state, cfg.resolved_dt_rank, cfg.ssm_conv

    def a_log_init(key, shape, dtype):
        # S4D-real init: A_n = -(n+1); stacking-aware (state dim is last)
        a = jnp.broadcast_to(
            jnp.arange(1, shape[-1] + 1, dtype=jnp.float32), shape)
        return jnp.log(a).astype(dtype)

    return {
        "in_proj": P((d, 2 * di), ("embed", "inner")),
        "conv_w": P((k, di), (None, "inner"), "normal", scale=0.1),
        "conv_b": P((di,), ("inner",), "zeros"),
        "x_proj": P((di, r + 2 * n), ("inner", None)),
        "dt_proj": P((r, di), (None, "inner"), scale=r ** -0.5),
        "dt_bias": P((di,), ("inner",),
                     lambda key, shape, dtype: jnp.full(shape, -4.6, dtype)),
        "a_log": P((di, n), ("inner", None), a_log_init, dtype="float32"),
        "d_skip": P((di,), ("inner",), "ones", dtype="float32"),
        "out_proj": P((di, d), ("inner", "embed")),
    }


def _ssm_params(cfg: ModelConfig, p, u):
    """u: (B, T, di) post-conv activations -> (dt, Bm, Cm)."""
    n, r = cfg.ssm_state, cfg.resolved_dt_rank
    xdbc = u @ p["x_proj"]                                  # (B,T,r+2n)
    dt = jax.nn.softplus(xdbc[..., :r] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)  # (B,T,di)
    Bm = xdbc[..., r:r + n].astype(jnp.float32)             # (B,T,n)
    Cm = xdbc[..., r + n:].astype(jnp.float32)              # (B,T,n)
    return dt, Bm, Cm


def ssm_scan_chunked(cfg: ModelConfig, p, u, h0: Optional[jax.Array] = None,
                     chunk: int = SSM_CHUNK):
    """Selective scan. u: (B, S, di). Returns (y, h_final)."""
    B, S, di = u.shape
    n = cfg.ssm_state
    A = -jnp.exp(p["a_log"].astype(jnp.float32))            # (di, n)
    dt, Bm, Cm = _ssm_params(cfg, p, u)
    uf = u.astype(jnp.float32)

    h = h0 if h0 is not None else jnp.zeros((B, di, n), jnp.float32)
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs                            # (B,di),(B,di),(B,n),(B,n)
        dA = jnp.exp(dt_t[..., None] * A[None])             # (B,di,n)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]     # (B,di,n)
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    def chunk_body(h, xs):
        uc, dtc, Bc, Cc = xs                                # (B,chunk,·)
        h, yc = jax.lax.scan(
            step, h, (uc.transpose(1, 0, 2), dtc.transpose(1, 0, 2),
                      Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2)))
        return h, yc.transpose(1, 0, 2)                     # (B,chunk,di)

    if nc == 1:
        h, y = chunk_body(h, (uf, dt, Bm, Cm))
    else:
        split = lambda x: x.reshape(B, nc, chunk, x.shape[-1]).transpose(1, 0, 2, 3)
        h, ys = jax.lax.scan(chunk_body, h, (split(uf), split(dt),
                                             split(Bm), split(Cm)))
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + uf * p["d_skip"][None, None]
    return y.astype(u.dtype), h


def apply_ssm(cfg: ModelConfig, p, x, *, mode: str, cache=None):
    """Mamba mixer. x: (B, S, d). Returns (out, new_cache).

    cache = {"conv": (B, K-1, di), "ssm": (B, di, n)} for decode.
    """
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]

    new_cache = None
    if mode == "decode":
        u_t, conv_state = causal_conv1d_step(
            xin[:, 0], cache["conv"], p["conv_w"], p["conv_b"])
        u = jax.nn.silu(u_t)[:, None]                       # (B,1,di)
        y, h = ssm_scan_chunked(cfg, p, u, h0=cache["ssm"].astype(jnp.float32))
        new_cache = {"conv": conv_state, "ssm": h.astype(cache["ssm"].dtype)}
    else:
        from repro.kernels import ops as kops
        u = jax.nn.silu(causal_conv1d(xin, p["conv_w"], p["conv_b"]))
        if kops.use_pallas() and S % 128 == 0 and di % 128 == 0:
            dt, Bm, Cm = _ssm_params(cfg, p, u)
            y, h = kops.mamba_scan_full(cfg, p, u, dt, Bm, Cm)
        else:
            y, h = ssm_scan_chunked(cfg, p, u)
        if mode == "prefill":
            K = cfg.ssm_conv
            tail = xin[:, -(K - 1):]
            pad = jnp.zeros((B, max(0, (K - 1) - S), di), xin.dtype)
            new_cache = {"conv": jnp.concatenate([pad, tail], axis=1),
                         "ssm": h.astype(x.dtype)}
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, new_cache

"""EdgeRL core: the paper's contribution as a composable JAX module.

Profiles (CNN analytic + transformer), the single backend-polymorphic
cost core (pricing: Eqs. 1-5 and 9-11 under jnp *and* numpy), the
EdgeEnv MDP (Eq. 6-7), reward aggregation (Eq. 8), the A2C agent
(Sec. II-C, batched over vmapped parallel envs) and the centralized
controller (Sec. II-D).
"""
from repro.core.env import (OBS_FEATURES, EnvConfig, ProfileTables,
                            action_breakdown, build_tables, env_reset,
                            env_step, observe)
from repro.core.pricing import (PricingBreakdown, StateView, numpy_tables,
                                price_actions, view_from_state)
from repro.core.reward import RewardWeights
from repro.core.a2c import A2CConfig, train, init_agent, make_train_episode
from repro.core.ppo import PPOConfig
from repro.core.profiles import paper_profiles, transformer_profile
from repro.core.controller import (make_paper_env, make_tpu_env,
                                   make_task_sampler, measured_state,
                                   resolve_selection, train_agent,
                                   evaluate_policy, decide)
from repro.core.roofline_env import make_dryrun_tpu_env

__all__ = [
    "OBS_FEATURES", "EnvConfig", "ProfileTables", "build_tables",
    "env_reset", "env_step", "observe", "action_breakdown",
    "PricingBreakdown", "StateView", "price_actions", "view_from_state",
    "numpy_tables", "RewardWeights", "A2CConfig", "PPOConfig",
    "train", "init_agent", "make_train_episode", "paper_profiles",
    "transformer_profile", "make_paper_env", "make_tpu_env",
    "make_task_sampler", "measured_state", "resolve_selection",
    "train_agent", "evaluate_policy", "decide", "make_dryrun_tpu_env",
]

"""Single source of truth for the per-request cost model (Eqs. 1-5, 9-11).

Every consumer of the paper's physics prices through one function,

    price_actions(cfg, tables, view, actions, xp=...) -> PricingBreakdown

written against the array-API namespace ``xp``: the identical code runs
under ``jax.numpy`` (the jit/scan/vmap training and evaluation hot paths
— ``env.action_costs``, ``baselines.greedy_oracle``) and under ``numpy``
(the fleet-simulator hot path at ~1e5 req/s —
``sim.backends.AnalyticalBackend``, and ``ExecuteBackend``'s
expected-cost cross-check). New cost terms (weight-ship amortization
today; per-layer mixed precision or KV-cache quant tomorrow) land here
exactly once and are immediately priced identically by the controller
that trains and the simulator that scores it.

Consumer map (DESIGN.md §6):
  env.action_costs            thin wrapper (jnp), feeds env_step/reward
  baselines.greedy_oracle     scores the full (V, K) grid per state
  sim.backends.AnalyticalBackend   numpy epoch pricing for the fleet loop
  sim.backends.ExecuteBackend      expected cost for wall-clock checks

Formula inventory (no per-request latency/energy math lives elsewhere):
  Eq. 1  E_comp = P_comp * T_local                (compute_energy)
  Eq. 2  E_trans = P_tx * 8 D / B                 (transmit_energy)
  Eq. 4  T_remote = queue * t_job + tail / F_srv  (remote_time)
  Eq. 5  T = T_local + T_trans + T_remote         (price_actions)
  Eq. 9-11 + stability score                      (*_score helpers)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StateView:
    """The slice of world state pricing needs. Env state dicts
    (``view_from_state``), fleet measurements, and vmapped batches all
    project onto it; per-device arrays are (n,), ``queue`` is the shared
    server queue depth (jobs) and ``load`` the offered-load fraction of
    ``cfg.peak_rps`` in [0, 1] (the env's generalized task feature).

    Cluster mode (actions carry a server column): ``queue`` becomes the
    per-server depth (S,), and the optional per-server fields override
    the nominal service/link arrays derived from ``cfg.cluster`` — the
    fleet loop passes the pool's *live* autoscaler state through them
    while training envs price at the nominal operating point."""
    model_id: object
    bandwidth: object
    p_tx: object
    queue: object
    load: object
    srv_flops: object = None       # (S,) effective tail FLOP/s
    srv_service_s: object = None   # (S,) background-job service seconds
    link_scale: object = None      # (n, S) bandwidth multiplier
    link_rtt_s: object = None      # (n, S) per-transfer delay, seconds


def view_from_state(state) -> StateView:
    """Project an env/measured state dict onto the pricing inputs."""
    return StateView(model_id=state["model_id"], bandwidth=state["bandwidth"],
                     p_tx=state["p_tx"], queue=state["queue"],
                     load=state["task"])


@dataclasses.dataclass(frozen=True)
class PricingBreakdown:
    """Per-device per-request costs and derived scores for one action set.

    Times are seconds, energy joules, bytes per request. ``queue_s`` is
    the Eq. 4 server wait *as seen by the view's queue*, already gated on
    ``offloaded`` (a terminal cut never visits the server queue); the
    fleet loop prices with queue=0 and adds its own measured wait.
    ``wire_bytes`` includes the weight-ship amortization surcharge,
    ``act_bytes`` is the raw cut activation (what an executed split must
    measure). Scores are the paper's Eqs. 9-11 plus the beyond-paper
    stability score of ``service_s`` (head + link, the work the device
    serializes per request) against the offered load."""
    head_s: object
    tx_s: object
    tail_s: object
    queue_s: object
    t_total: object
    energy_j: object
    act_bytes: object
    wire_bytes: object
    offloaded: object
    t_full_local: object
    e_full_local: object
    service_s: object
    acc_score: object
    lat_score: object
    energy_score: object
    stab_score: object


def _sigmoid(z, xp):
    # clip keeps numpy from overflow-warning on exp of large |z|
    z = xp.clip(z, -60.0, 60.0)
    return 1.0 / (1.0 + xp.exp(-z))


def local_time(lp, head_flops, xp=jnp):
    """Eq. 5 head term: T_local = head / F_dev."""
    return head_flops / lp.device_flops


def transmit_time(bandwidth_bps, n_bytes, xp=jnp):
    """Eq. 5 link term: T_trans = 8 D / B."""
    return (n_bytes * 8.0) / xp.maximum(bandwidth_bps, 1.0)


def remote_time(lp, tail_flops, queue_len, xp=jnp):
    """Eq. 4: T_remote = T_queue + T_comp(tail)."""
    return queue_len * lp.job_service_s + tail_flops / lp.server_flops


def total_time(lp, head_flops, tail_flops, n_bytes, bandwidth_bps,
               queue_len, xp=jnp):
    """Eq. 5 (ungated; ``price_actions`` gates the queue on offload)."""
    return (local_time(lp, head_flops, xp)
            + transmit_time(bandwidth_bps, n_bytes, xp)
            + remote_time(lp, tail_flops, queue_len, xp))


def compute_energy(p, t_local_s, xp=jnp):
    """Eq. 1: E_comp = P_comp * T_local."""
    return p.p_compute * t_local_s


def transmit_energy(p_tx_w, bandwidth_bps, n_bytes, xp=jnp):
    """Eq. 2: E_trans = beta_k(B) * D, with beta = P_tx / throughput."""
    return p_tx_w * (n_bytes * 8.0) / xp.maximum(bandwidth_bps, 1.0)


def accuracy_score(w, acc, xp=jnp):
    """Eq. 9."""
    return _sigmoid(w.p * (acc - w.q), xp)


def latency_score(t_total, t_all_local, xp=jnp):
    """Eq. 10."""
    return 1.0 - t_total / xp.maximum(t_all_local, 1e-9)


def energy_score(e_total, e_all_local, xp=jnp):
    """Eq. 11."""
    return 1.0 - e_total / xp.maximum(e_all_local, 1e-9)


def stability_score(w, utilization, xp=jnp):
    """Beyond-paper: ~1 while the device+link absorbs the offered load
    (u < 1), ~0 once requests queue faster than they drain (u > 1)."""
    return _sigmoid(w.p_stab * (1.0 - utilization), xp)


def numpy_tables(tables):
    """Numpy snapshot of the dense profile tables: the fleet hot path
    indexes them per epoch and must not pay jnp dispatch per call."""
    arrays = {f.name: getattr(tables, f.name)
              for f in dataclasses.fields(tables)
              if hasattr(getattr(tables, f.name), "shape")}
    return dataclasses.replace(
        tables, **{k: np.asarray(v) for k, v in arrays.items()})


def price_actions(cfg, tables, view: StateView, actions,
                  xp=jnp) -> PricingBreakdown:
    """Price actions (..., 2) = (version j, cut index l) — or (..., 3)
    = (version, cut, server) in cluster mode — for the devices in
    ``view`` under ``cfg`` (EnvConfig). ``tables``' arrays must live
    in the ``xp`` namespace (``numpy_tables`` snapshots them for np).

    The server-side term (queue wait) is gated on a tail actually
    running there: a terminal cut executes entirely on-device and never
    visits the server queue. Charging T_queue to local execution (and
    normalizing by the small local baseline) would make congestion
    punish local *harder* than offload, driving every policy to offload
    into an already-saturated server.
    """
    m = view.model_id
    j, k = actions[..., 0], actions[..., 1]
    head = tables.head_flops[m, j, k]
    tail = tables.tail_flops[m, j, k]
    act_bytes = tables.cut_bytes[m, j, k]
    wire_bytes = act_bytes
    if cfg.weight_ship_slots > 0:
        # Amortized per-frame share of staging this version's tail weights
        # server-side: shipped once per decision epoch (weight_ship_slots
        # slots), spread over every frame served in that epoch. act_bytes
        # is a per-frame quantity (env_step scales by frames_per_slot), so
        # the divisor must include frames_per_slot too.
        wire_bytes = wire_bytes + (tables.tail_weight_bytes[m, j, k]
                                   / (cfg.weight_ship_slots
                                      * cfg.frames_per_slot))
    acc = tables.acc[m, j]
    full = tables.full_flops[m, j]

    lp, pw, w = cfg.latency, cfg.power, cfg.weights
    head_s = local_time(lp, head, xp)
    offloaded = tail > 0.0
    if actions.shape[-1] == 3:
        # Cluster mode: the server column reprices the link (Eq. 2/3)
        # and the server-side queue/tail (Eq. 4) against the chosen
        # target. The trailing action dim is static under jit/vmap, so
        # this branch traces cleanly; oracle grids batch as (VKS, n, 3)
        # and the device index broadcasts against them.
        srv = actions[..., 2]
        dev = xp.arange(actions.shape[-2])
        srv_flops, srv_service_s = view.srv_flops, view.srv_service_s
        if srv_flops is None:
            srv_flops, srv_service_s = cfg.cluster.nominal(lp, xp)
        # compute in the tables' dtype: the legacy branch divides the
        # float32 tables by *python-float* LatencyParams scalars, which
        # stays float32 under weak promotion — a float64 per-server
        # array would silently promote and break single-server parity
        srv_flops = xp.asarray(srv_flops, dtype=tail.dtype)
        srv_service_s = xp.asarray(srv_service_s, dtype=tail.dtype)
        link_scale = (view.link_scale if view.link_scale is not None
                      else xp.asarray(cfg.cluster.link_scale))
        link_rtt_s = (view.link_rtt_s if view.link_rtt_s is not None
                      else xp.asarray(cfg.cluster.link_rtt_s))
        bw = view.bandwidth * link_scale[dev, srv]
        tx_s = transmit_time(bw, wire_bytes, xp) + link_rtt_s[dev, srv]
        tail_s = tail / srv_flops[srv]
        q = xp.asarray(view.queue)
        q_dev = q[srv] if q.ndim else q
        queue_s = xp.where(offloaded, q_dev * srv_service_s[srv], 0.0)
    else:
        bw = view.bandwidth
        tx_s = transmit_time(bw, wire_bytes, xp)
        tail_s = tail / lp.server_flops
        queue_s = xp.where(offloaded, view.queue * lp.job_service_s, 0.0)
    t_total = head_s + tx_s + queue_s + tail_s

    energy_j = (compute_energy(pw, head_s, xp)
                + transmit_energy(view.p_tx, bw, wire_bytes, xp))
    t_full_local = local_time(lp, full, xp)
    e_full_local = compute_energy(pw, t_full_local, xp)

    # per-request service time the device serializes: head compute + link
    service_s = head_s + tx_s
    util = view.load * cfg.peak_rps * service_s
    return PricingBreakdown(
        head_s=head_s, tx_s=tx_s, tail_s=tail_s, queue_s=queue_s,
        t_total=t_total, energy_j=energy_j, act_bytes=act_bytes,
        wire_bytes=wire_bytes, offloaded=offloaded,
        t_full_local=t_full_local, e_full_local=e_full_local,
        service_s=service_s,
        acc_score=accuracy_score(w, acc, xp),
        lat_score=latency_score(t_total, t_full_local, xp),
        energy_score=energy_score(energy_j, e_full_local, xp),
        stab_score=stability_score(w, util, xp))

"""Trace-driven fleet simulation CLI over the scenario/policy registries:
run any registered policy roster against a named scenario preset (or an
ad-hoc scenario assembled from flags) and report per-request latency
percentiles, SLO attainment, goodput and energy.

    # what's on the menu
    PYTHONPATH=src python scripts/simulate.py --list-scenarios

    # one preset, its default policy roster
    PYTHONPATH=src python scripts/simulate.py --scenario paper-mmpp-burst

    # preset + overrides + explicit roster (paired request streams)
    PYTHONPATH=src python scripts/simulate.py --scenario paper-mmpp-burst \
        --compare a2c,ppo,device_only,full_offload --requests 20000

    # train once, persist the controller, reload it later (identical
    # paired-seed metrics, no retraining)
    PYTHONPATH=src python scripts/simulate.py --scenario diurnal-fleet \
        --compare a2c --save-policy controller.npz
    PYTHONPATH=src python scripts/simulate.py --scenario diurnal-fleet \
        --compare a2c,device_only --load-policy controller.npz

    # nonstationary world + closed-loop adaptation: the preset pairs the
    # online-adapted controller against the same controller frozen at
    # its pre-drift parameters (repro.online)
    PYTHONPATH=src python scripts/simulate.py --scenario flash-crowd

    # apply a named drift schedule + online adaptation to any preset
    PYTHONPATH=src python scripts/simulate.py --scenario diurnal-fleet \
        --drift-schedule link-brownout --online

    # no --scenario: flags assemble a custom scenario (legacy behavior)
    PYTHONPATH=src python scripts/simulate.py --trace diurnal --devices 8 \
        --requests 100000

    # cross-check the analytical backend against real SplitServingEngine
    # execution on a reduced transformer
    PYTHONPATH=src python scripts/simulate.py --scenario tpu-execute
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro import obs
from repro.core import RewardWeights
from repro.policies import get_policy_spec, policy_names
from repro.scenarios import (Scenario, get_scenario, run_scenario,
                             scenario_names, split_policy_name)

# Flag defaults live here (not on the parser): the parser suppresses
# absent flags so a preset scenario only sees the overrides the user
# actually typed, while the no-scenario path fills in from this table.
DEFAULTS = dict(
    scenario=None, list_scenarios=False,
    trace="diurnal", devices=8, requests=100_000, engine="loop",
    policy=None, compare=None, seeds="0",
    online=False, drift_schedule=None,
    pool=None, topology=None, autoscale=None,
    episodes=300, train_seed=0, save_policy=None, load_policy=None,
    slo_ms=2000.0, slot_seconds=10.0,
    rate=6.0, rate_low=2.0, rate_high=30.0, peak_rps=30.0,
    replay_file=None, models="cycle",
    w_acc=0.05, w_lat=0.10, w_energy=0.15, w_stab=0.70,
    env="paper", arch="qwen2-0.5b", execute=False, sample=16, exec_seq=32,
    json=None, quiet=False, verbose=0, trace_out=None, timeline_out=None,
)

# which CLI rate flags feed which trace constructor kwargs
_TRACE_ARGS = {
    "poisson": {"rate": "rate_rps"},
    "mmpp": {"rate_low": "rate_low_rps", "rate_high": "rate_high_rps"},
    "diurnal": {"rate_low": "base_rps", "rate_high": "peak_rps"},
    "uniform": {"rate_high": "max_rps"},
    "replay": {},
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
        argument_default=argparse.SUPPRESS)
    ap.add_argument("--scenario", help="named preset; other flags override "
                    "its fields (see --list-scenarios)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print registered scenario presets and exit")
    ap.add_argument("--trace",
                    choices=("poisson", "mmpp", "diurnal", "uniform",
                             "replay"))
    ap.add_argument("--devices", type=int)
    ap.add_argument("--requests", type=int)
    ap.add_argument("--engine", choices=("loop", "vectorized", "scan"),
                    help="fleet epoch-flow engine (sim.megafleet): "
                    "loop = per-device oracle, vectorized = fused numpy "
                    "(bit-identical, 100k+ devices), scan = jitted "
                    "lax.scan (stationary worlds, static policies)")
    ap.add_argument("--policy", help="single policy (registry name)")
    ap.add_argument("--compare",
                    help="comma-separated policies; overrides --policy")
    ap.add_argument("--seeds",
                    help="comma-separated sim seeds; metrics average "
                    "over them (same seed = same request stream)")
    ap.add_argument("--online", action="store_true",
                    help="run every trainable policy in the roster with "
                    "online adaptation ('name+online') alongside its "
                    "frozen variant (repro.online)")
    ap.add_argument("--drift-schedule", metavar="NAME",
                    help="apply a named WorldSchedule (link-brownout, "
                    "battery-cliff, flash-crowd, device-churn) to the "
                    "scenario; overrides a preset's own drift")
    ap.add_argument("--pool", metavar="NAME",
                    help="server-pool preset (repro.cluster: single, "
                    "uniform-4, hetero-4); widens actions to (version, "
                    "cut, server)")
    ap.add_argument("--topology", metavar="NAME",
                    help="device->server link topology preset (uniform, "
                    "near-far, tiered); needs --pool")
    ap.add_argument("--autoscale", choices=("threshold", "hysteresis"),
                    help="pool autoscaler policy; needs --pool")
    ap.add_argument("--episodes", type=int,
                    help="training budget for trainable policies")
    ap.add_argument("--train-seed", type=int)
    ap.add_argument("--save-policy", metavar="PATH",
                    help="write each trained policy as an .npz artifact "
                    "(name inserted before the extension when several "
                    "trainable policies run)")
    ap.add_argument("--load-policy", metavar="PATH",
                    help="load trainable policies from artifacts instead "
                    "of retraining (same PATH convention)")
    ap.add_argument("--slo-ms", type=float)
    ap.add_argument("--slot-seconds", type=float)
    ap.add_argument("--rate", type=float,
                    help="poisson rate (requests/s/device)")
    ap.add_argument("--rate-low", type=float,
                    help="mmpp calm rate / diurnal base rate")
    ap.add_argument("--rate-high", type=float,
                    help="mmpp burst rate / diurnal peak / uniform max")
    ap.add_argument("--peak-rps", type=float,
                    help="load-feature saturation rate; 0 disables the "
                    "stability reward term (paper-faithful)")
    ap.add_argument("--replay-file")
    ap.add_argument("--models", choices=("cycle", "vgg", "resnet",
                                         "densenet"),
                    help="paper-env fleet composition")
    ap.add_argument("--w-acc", type=float)
    ap.add_argument("--w-lat", type=float)
    ap.add_argument("--w-energy", type=float)
    ap.add_argument("--w-stab", type=float)
    ap.add_argument("--env", choices=("paper", "tpu"))
    ap.add_argument("--arch")
    ap.add_argument("--execute", action="store_true",
                    help="cross-check a sampled subset through the real "
                    "SplitServingEngine (tpu env)")
    ap.add_argument("--sample", type=int)
    ap.add_argument("--exec-seq", type=int)
    ap.add_argument("--json", help="write results JSON here")
    ap.add_argument("--quiet", action="store_true",
                    help="warnings only on the console (a --trace-out "
                    "file still records the full log)")
    ap.add_argument("-v", "--verbose", action="count",
                    help="more console detail (-v: debug narration)")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="record structured obs events (spans, metrics, "
                    "JAX retrace accounting) to a JSONL file; summarize "
                    "with scripts/obsview.py")
    ap.add_argument("--timeline-out", metavar="PATH",
                    help="record the per-epoch fleet flight recorder "
                    "(repro.obs.timeline: fleet/server series, drift + "
                    "autoscale annotations, SLO error budgets) and write "
                    "it here ('-' = stdout); render with "
                    "scripts/fleetview.py")
    return ap


def replay_kw(replay_file, slot_seconds) -> dict:
    """The one spelling of the replay-trace kwargs (both the preset
    override path and the bare --trace replay path build them here)."""
    if not replay_file:
        raise SystemExit("--trace replay needs --replay-file (.npy)")
    return {"counts": np.load(replay_file),
            "slot_seconds_recorded": slot_seconds}


def trace_override(sc: Scenario, provided: dict, merged: dict) -> Scenario:
    """Apply --trace/--rate*/--replay-file on top of a scenario: a trace
    *kind* change rebuilds its kwargs from the merged flag values; rate
    flags alone patch only the matching kwargs of the current kind."""
    rate_flags = {"rate", "rate_low", "rate_high", "replay_file"}
    if not ({"trace"} | rate_flags) & set(provided):
        return sc
    name = merged["trace"] if "trace" in provided else sc.trace
    argmap = _TRACE_ARGS[name]
    applicable = set(argmap) | ({"replay_file"} if name == "replay"
                                else set())
    stray = (rate_flags & set(provided)) - applicable
    if stray:
        flags = ", ".join("--" + f.replace("_", "-") for f in sorted(stray))
        expects = ", ".join("--" + f.replace("_", "-")
                            for f in sorted(applicable)) or "no rate flags"
        raise SystemExit(f"{flags}: not applicable to trace {name!r} "
                         f"(which takes {expects}); the override would "
                         "be silently ignored")
    if name == sc.trace:
        kw = dict(sc.trace_kw)
        src = provided
    else:
        kw = {}
        src = merged     # fresh kind: every mapped kwarg from merged
    for flag, key in argmap.items():
        if flag in src:
            kw[key] = src[flag]
    if name == "replay":
        kw = replay_kw(merged.get("replay_file"),
                       merged["slot_seconds"] if "slot_seconds" in provided
                       else sc.slot_seconds)
    return sc.replace(trace=name, trace_kw=kw)


def apply_overrides(sc: Scenario, provided: dict, merged: dict) -> Scenario:
    """Explicitly-typed flags override preset fields, field by field."""
    direct = {"devices": "devices", "requests": "n_requests",
              "slot_seconds": "slot_seconds", "peak_rps": "peak_rps",
              "models": "models", "env": "env", "arch": "arch",
              "execute": "execute", "sample": "sample",
              "exec_seq": "exec_seq", "episodes": "episodes",
              "train_seed": "train_seed", "engine": "engine"}
    repl = {field: provided[flag] for flag, field in direct.items()
            if flag in provided}
    if "slo_ms" in provided:
        repl["slo_s"] = provided["slo_ms"] / 1e3
    if "seeds" in provided:
        repl["seeds"] = tuple(int(s) for s in provided["seeds"].split(","))
    wflags = {"w_acc": "w_acc", "w_lat": "w_lat", "w_energy": "w_energy",
              "w_stab": "w_stab"}
    wkw = {field: provided[flag] for flag, field in wflags.items()
           if flag in provided}
    if wkw:
        repl["weights"] = dataclasses.replace(sc.weights, **wkw)
    if "drift_schedule" in provided:
        repl["drift"] = provided["drift_schedule"]
        if provided["drift_schedule"] != sc.drift:
            repl["drift_kw"] = {}    # new kind: factory defaults
    cluster_flags = {"pool": "pool", "topology": "topology",
                     "autoscale": "autoscale"}
    for flag, field in cluster_flags.items():
        if flag in provided:
            repl[field] = provided[flag]
            if provided[flag] != getattr(sc, field):
                repl[f"{field}_kw"] = {}    # new kind: preset defaults
    if repl:
        sc = sc.replace(**repl)
    return trace_override(sc, provided, merged)


def scenario_from_args(merged: dict) -> Scenario:
    """No --scenario: assemble an ad-hoc scenario from the flag values
    (the CLI's historical default behavior, now one declaration)."""
    trace = merged["trace"]
    kw = {key: merged[flag] for flag, key in _TRACE_ARGS[trace].items()}
    if trace == "replay":
        kw = replay_kw(merged["replay_file"], merged["slot_seconds"])
    return Scenario(
        name="custom",
        description="ad-hoc scenario assembled from CLI flags",
        env=merged["env"], devices=merged["devices"],
        arch=merged["arch"], models=merged["models"],
        weights=RewardWeights(w_acc=merged["w_acc"], w_lat=merged["w_lat"],
                              w_energy=merged["w_energy"],
                              w_stab=merged["w_stab"]),
        slot_seconds=merged["slot_seconds"], peak_rps=merged["peak_rps"],
        slo_s=merged["slo_ms"] / 1e3,
        seeds=tuple(int(s) for s in merged["seeds"].split(",")),
        n_requests=merged["requests"], episodes=merged["episodes"],
        train_seed=merged["train_seed"], execute=merged["execute"],
        sample=merged["sample"], exec_seq=merged["exec_seq"],
        drift=merged["drift_schedule"], engine=merged["engine"],
        pool=merged["pool"], topology=merged["topology"] or "uniform",
        autoscale=merged["autoscale"],
        trace=trace, trace_kw=kw)


def artifact_path(path: str, name: str, multi: bool) -> str:
    """One --save/--load path serves N trainable policies by inserting
    the policy name before the extension when N > 1."""
    if not multi:
        return path
    root, ext = os.path.splitext(path)
    return f"{root}.{name}{ext or '.npz'}"


def main():
    ap = build_parser()
    provided = vars(ap.parse_args())
    merged = {**DEFAULTS, **provided}
    # 0 = warnings only, 1 = the usual narration + tables, 2 = debug
    obs.set_verbosity(0 if merged["quiet"]
                      else 1 + (merged["verbose"] or 0))

    if merged["list_scenarios"]:
        for name in scenario_names():
            sc = get_scenario(name)
            print(f"{name:18s} {sc.description}")
            print(f"{'':18s}   env={sc.env} devices={sc.devices} "
                  f"trace={sc.trace} slo={sc.slo_s}s "
                  f"seeds={list(sc.seeds)} requests={sc.n_requests} "
                  f"policies={','.join(sc.policies)}")
        return

    if merged["scenario"]:
        try:
            sc = get_scenario(merged["scenario"])
        except KeyError as e:
            ap.error(str(e.args[0]))
        sc = apply_overrides(sc, provided, merged)
    else:
        sc = scenario_from_args(merged)
    if sc.execute and sc.env != "tpu":
        ap.error("--execute needs --env tpu (the executable engine "
                 "serves the transformer stack)")

    if merged["compare"]:
        names = tuple(merged["compare"].split(","))
    elif merged["policy"]:
        names = (merged["policy"],)
    elif merged["scenario"]:
        names = sc.policies
    else:
        names = ("a2c",)
    try:
        parsed = [split_policy_name(n) for n in names]
        specs = [get_policy_spec(base) for base, _ in parsed]
    except KeyError as e:
        ap.error(str(e.args[0]))

    if merged["online"]:
        # every trainable roster entry gains its '+online' adapted
        # variant (before the frozen one, matching the preset layout)
        expanded = []
        adapted = {b for (b, o) in parsed if o}
        for n, (base, is_online), spec in zip(names, parsed, specs):
            if spec.trainable and not is_online and base not in adapted:
                expanded.append(f"{base}+online")
            expanded.append(n)
        names = tuple(dict.fromkeys(expanded))

    trainable = sorted({split_policy_name(n)[0] for n in names
                        if get_policy_spec(
                            split_policy_name(n)[0]).trainable})
    if (merged["save_policy"] or merged["load_policy"]) and not trainable:
        ap.error("--save-policy/--load-policy need a trainable policy "
                 f"(a2c, ppo) in the roster; got {','.join(names)}")
    multi = len(trainable) > 1
    save_map = {n: artifact_path(merged["save_policy"], n, multi)
                for n in trainable} if merged["save_policy"] else None
    load_map = {n: artifact_path(merged["load_policy"], n, multi)
                for n in trainable} if merged["load_policy"] else None

    rec_ctx = obs.recording(
        merged["trace_out"],
        meta={"tool": "simulate", "scenario": sc.name,
              "policies": list(names), "seeds": list(sc.seeds)}) \
        if merged["trace_out"] else contextlib.nullcontext()
    # `--timeline-out -` streams the flight-recorder JSON on stdout for
    # piping into fleetview; divert the human-facing report to stderr so
    # stdout stays pure JSON.
    human_ctx = contextlib.redirect_stdout(sys.stderr) \
        if merged["timeline_out"] == "-" else contextlib.nullcontext()
    with human_ctx:
        with rec_ctx:
            report = run_scenario(sc, names, save_policies=save_map,
                                  load_policies=load_map, verbose=True,
                                  timeline=bool(merged["timeline_out"]))

        cross = next((r.cross_check for r in report.results.values()
                      if r.cross_check), None)
        if cross:
            obs.info(
                f"\nexecute cross-check: {cross['samples']} requests "
                f"through SplitServingEngine; act-bytes "
                f"exact={cross['bytes_exact']} "
                f"({cross['bytes_mismatches']} mismatches); "
                f"wall/analytical latency ratio "
                f"median={cross['latency_ratio_median']:.2f} "
                f"max={cross['latency_ratio_max']:.2f} "
                f"(tolerance {cross['latency_tolerance']}x, within="
                f"{cross['latency_within_tolerance']})")
        if merged["json"]:
            out = report.to_json()
            out["config"] = {k: v for k, v in merged.items()
                             if k not in ("json", "list_scenarios")}
            with open(merged["json"], "w") as f:
                json.dump(out, f, indent=2, default=str)
            obs.info(f"\nwrote {merged['json']}")
        if merged["trace_out"]:
            obs.info(f"wrote obs trace {merged['trace_out']}; summarize "
                     f"with: python scripts/obsview.py "
                     f"{merged['trace_out']}")

    if merged["timeline_out"]:
        from repro.obs.timeline import write_timeline
        runs = [{"policy": name, "seed": int(seed), "timeline": tl}
                for name, r in report.results.items()
                for seed, tl in zip(sc.seeds, r.timelines)
                if tl is not None]
        write_timeline(merged["timeline_out"], runs,
                       meta={"tool": "simulate", "scenario": sc.name,
                             "slo_target": sc.slo_target})
        if merged["timeline_out"] != "-":
            obs.info(f"wrote timeline {merged['timeline_out']}; render "
                     f"with: python scripts/fleetview.py "
                     f"{merged['timeline_out']}")


if __name__ == "__main__":
    main()

"""repro.obs.timeline / slo / traindiag: the fleet flight recorder.

The load-bearing guarantees: capture is *result-neutral* (SimResult is
bit-identical with the recorder on vs off, on every engine, cluster
included), the SLO burn math follows the multi-window page/clear state
machine, stride subsampling always retains the horizon's final epoch,
the `cluster-brownout` acceptance regime produces the full annotated
record (regime switches, measured-depth autoscale triggers, per-server
series, a burn alert that fires during the brownout and clears after
recovery) through the fleetview JSON export, and the A2C/PPO learner
diagnostics add zero trace sites.
"""
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import A2CConfig, make_paper_env
from repro.core import a2c as A2C
from repro.core import ppo as PPO
from repro.obs import jaxmon, read_events, recording, report
from repro.obs.events import Recorder
from repro.obs.slo import SLOConfig, compute
from repro.obs.timeline import (Timeline, read_timeline, write_timeline)
from repro.obs.traindiag import (DIAG_KEYS, TrainDiag, approx_kl,
                                 check_health, explained_variance)
from repro.policies import build_policy
from repro.scenarios import get_scenario, run_scenario
from repro.sim import EpochLog, FleetConfig, simulate


def _world(preset):
    sc = get_scenario(preset)
    env_cfg, tables, model_ids, bf = sc.build_env()
    return sc, env_cfg, tables, model_ids, bf


def _run(sc, env_cfg, tables, model_ids, bf, policy, engine, *,
         n_requests, seed=0, autoscaler=None, **fl_kw):
    fl = FleetConfig(slo_s=sc.slo_s, engine=engine, **fl_kw)
    backend = bf() if engine != "scan" else None
    return simulate(env_cfg, tables, policy, sc.build_trace(),
                    n_requests=n_requests, seed=seed, fleet=fl,
                    backend=backend, model_ids=model_ids,
                    autoscaler=autoscaler)


# --------------------------------------------------------------------------
# SLO error budgets: burn math + the multi-window page state machine
# --------------------------------------------------------------------------

def test_slo_burn_rate_math():
    # constant 10% miss rate against a 5% budget: burn = 2.0 everywhere
    T = 40
    arrivals = np.full(T, 100)
    hits = np.full(T, 90)
    rep = compute(np.arange(T), arrivals, hits, SLOConfig(target=0.95))
    np.testing.assert_allclose(rep.burn_fast, 2.0)
    np.testing.assert_allclose(rep.burn_slow, 2.0)
    assert rep.attainment == pytest.approx(0.9)
    assert rep.alerts == []          # 2x < both page thresholds
    # budget: allowed = 0.05 * 4000 = 200, spent 400 -> exhausted
    assert rep.budget_remaining == 0.0
    assert rep.time_to_exhaustion == 0.0


def test_slo_alert_fires_and_clears():
    # calm -> hard brownout (60% miss, 12x burn) -> calm again
    cfg = SLOConfig(target=0.95)    # fast 8x/8ep, slow 4x/32ep
    arrivals = np.full(80, 100)
    hits = np.full(80, 100)
    hits[30:50] = 40
    rep = compute(np.arange(80), arrivals, hits, cfg)
    assert len(rep.alerts) == 1
    a = rep.alerts[0]
    # fires only once BOTH windows breach (slow window needs several
    # bad epochs), clears when the fast window recovers
    assert 30 < a["start"] < 50
    assert a["end"] is not None and a["end"] > 50
    assert a["peak_burn_fast"] == pytest.approx(12.0)
    assert a["peak_burn_fast"] > cfg.fast_burn
    assert a["peak_burn_slow"] > cfg.slow_burn
    # one bad epoch never pages (slow window holds it back)
    hits2 = np.full(80, 100)
    hits2[30] = 0
    assert compute(np.arange(80), arrivals, hits2, cfg).alerts == []


def test_slo_unclosed_alert_and_page_epochs():
    # run ends mid-incident: end stays None, page_epochs counts to T
    arrivals = np.full(40, 100)
    hits = np.full(40, 100)
    hits[20:] = 30
    rep = compute(np.arange(40), arrivals, hits, SLOConfig(target=0.95))
    assert len(rep.alerts) == 1 and rep.alerts[0]["end"] is None
    assert rep.summary()["page_epochs"] == 40 - rep.alerts[0]["start"]


def test_slo_config_validation():
    with pytest.raises(ValueError, match="target"):
        SLOConfig(target=1.0)
    with pytest.raises(ValueError, match="window"):
        SLOConfig(fast_window=16, slow_window=8)
    assert SLOConfig(target=0.98).budget == pytest.approx(0.02)


def test_slo_emit_events_folds_into_report_timeline():
    arrivals = np.full(40, 100)
    hits = np.full(40, 100)
    hits[10:30] = 20
    rep = compute(np.arange(40), arrivals, hits, SLOConfig(target=0.95))
    assert rep.alerts
    r = Recorder()
    obs.set_recorder(r)
    try:
        from repro.obs import slo as slo_mod
        slo_mod.emit_events(rep)
    finally:
        obs.set_recorder(None)
    names = [e["name"] for e in r.events if e["type"] == "event"]
    assert "slo.burn_alert" in names and "slo.budget" in names
    # report.fold routes slo.* (and timeline.*) into the run timeline
    folded = report.fold(r.events)
    assert any(t["name"].startswith("slo.") for t in folded["timeline"])


# --------------------------------------------------------------------------
# stride retention: the horizon's final epoch is never dropped
# --------------------------------------------------------------------------

def test_epoch_log_stride_retains_final_epoch():
    # stride 3, horizon 10: epochs 0,3,6,9 kept by stride; 9 is last
    log = EpochLog(stride=3)
    for e in range(10):
        log.append({"epoch": e})
    assert list(log.column("epoch")) == [0, 3, 6, 9]
    # horizon 11: epoch 10 is stride-skipped but must be retained
    log2 = EpochLog(stride=3)
    for e in range(11):
        log2.append({"epoch": e})
    assert list(log2.column("epoch")) == [0, 3, 6, 9, 10]
    # ...and the held row always tracks the newest offered epoch
    log3 = EpochLog(stride=3)
    for e in range(12):
        log3.append({"epoch": e})
    assert list(log3.column("epoch")) == [0, 3, 6, 9, 11]


def test_timeline_stride_retains_final_epoch():
    tl = Timeline(stride=3)
    for e in range(11):
        tl.append_epoch(epoch=e, arrivals=10, dropped=0, slo_hits=9,
                        alive=2, regime=0, queue_jobs=0.0, backlog_s=0.0,
                        lat=np.array([0.1]), energy_j=1.0)
    assert list(tl.column("epoch")) == [0, 3, 6, 9, 10]
    assert len(tl) == 5


def test_timeline_scan_bulk_path_matches_stride_rule():
    tl = Timeline(stride=4, slot_seconds=2.0)
    T = 10
    z = np.zeros(T)
    tl.extend_epochs(epoch=np.arange(T), arrivals=np.full(T, 8),
                     served=np.full(T, 8), dropped=z, slo_hits=np.full(T, 7),
                     alive=np.full(T, 4), queue_jobs=z, backlog_s=z,
                     lat_sum=np.full(T, 1.6), lat_max=np.full(T, 0.5),
                     energy_j=np.full(T, 3600.0))
    assert list(tl.column("epoch")) == [0, 4, 8, 9]
    # scan-carry rule: mean/max exact, percentiles NaN
    assert tl.column("lat_mean")[0] == pytest.approx(0.2)
    assert np.isnan(tl.column("lat_p95")).all()
    assert tl.column("energy_wh")[0] == pytest.approx(1.0)
    assert tl.column("goodput")[0] == pytest.approx(3.5)


# --------------------------------------------------------------------------
# crash-safe JSONL reads + incremental flushing
# --------------------------------------------------------------------------

def test_read_events_skips_torn_final_line(tmp_path):
    path = str(tmp_path / "e.jsonl")
    with recording(path):
        obs.event("first")
        obs.event("second")
    with open(path, "a") as f:
        f.write('{"type": "event", "name": "torn", "att')   # crash here
    meta, events = read_events(path)
    names = [e.get("name") for e in events if e["type"] == "event"]
    assert names == ["first", "second"]


def test_read_events_rejects_mid_file_corruption(tmp_path):
    path = str(tmp_path / "e.jsonl")
    with recording(path):
        obs.event("first")
    lines = open(path).read().splitlines()
    lines.insert(1, "{broken")                # corrupt a middle line
    lines.append(lines[-1])                   # valid tail after it
    open(path, "w").write("\n".join(lines) + "\n")
    with pytest.raises(ValueError, match="corrupt JSONL at line 2"):
        read_events(path)


def test_recorder_flush_every_writes_incrementally(tmp_path):
    path = str(tmp_path / "e.jsonl")
    rec = Recorder(path=path, flush_every=2)
    obs.set_recorder(rec)
    try:
        obs.event("a")
        obs.event("b")                        # hits the flush threshold
        meta, events = read_events(path)      # readable pre-close
        assert [e["name"] for e in events
                if e["type"] == "event"] == ["a", "b"]
        obs.event("c")                        # below threshold: unflushed
    finally:
        obs.set_recorder(None)
    rec.close()
    meta, events = read_events(path)
    assert [e["name"] for e in events
            if e["type"] == "event"] == ["a", "b", "c"]


# --------------------------------------------------------------------------
# result neutrality: recording must not change the simulation
# --------------------------------------------------------------------------

def _assert_bit_identical(a, b):
    assert np.array_equal(a.selection_hist, b.selection_hist)
    assert a.served == b.served and a.epochs == b.epochs
    assert a.metrics.dropped == b.metrics.dropped
    assert np.array_equal(a.metrics.latencies_s, b.metrics.latencies_s)
    assert np.array_equal(a.metrics.energies_j, b.metrics.energies_j)
    assert a.summary == b.summary


@pytest.mark.parametrize("engine", ["loop", "vectorized", "scan"])
def test_recording_neutral_across_engines(engine, tmp_path):
    sc, env_cfg, tables, mids, bf = _world("diurnal-fleet")
    pol = build_policy("device_only", env_cfg, tables)
    kw = dict(n_requests=3000, seed=1)
    off = _run(sc, env_cfg, tables, mids, bf, pol, engine, **kw)
    with recording(str(tmp_path / "t.jsonl")):
        on = _run(sc, env_cfg, tables, mids, bf, pol, engine,
                  timeline=True, **kw)
    _assert_bit_identical(off, on)
    assert off.timeline is None
    tl = on.timeline
    assert len(tl) == on.epochs
    assert tl.engine == engine
    assert tl.slo_report is not None
    # the recorded series account for the same workload
    assert int(tl.column("served").sum()) == on.served
    assert int(tl.column("arrivals").sum()) >= on.served
    if engine == "scan":
        assert np.isnan(tl.column("lat_p95")).all()   # scan-carry rule
    else:
        assert np.isfinite(tl.column("lat_p95")).any()


@pytest.mark.parametrize("engine", ["loop", "vectorized"])
def test_recording_neutral_on_cluster_preset(engine, tmp_path):
    sc, env_cfg, tables, mids, bf = _world("edge-cluster")
    pol = build_policy("join_shortest_queue", env_cfg, tables)
    kw = dict(n_requests=3000, seed=0, autoscaler=sc.build_autoscaler())
    off = _run(sc, env_cfg, tables, mids, bf, pol, engine, **kw)
    with recording(str(tmp_path / "t.jsonl")):
        on = _run(sc, env_cfg, tables, mids, bf, pol, engine,
                  timeline=True, **kw)
    _assert_bit_identical(off, on)
    tl = on.timeline
    assert tl.n_servers == 4
    # per-server vector columns: (epochs, S), captured pre-autoscale
    for key in ("srv_queue", "srv_dvfs", "srv_replicas", "srv_power_w"):
        assert tl.column(key).shape == (len(tl), 4)
    assert (tl.column("srv_replicas") >= 1).all()


def test_online_run_annotates_triggers_and_hotswaps():
    """Drift + closed-loop adaptation leave their marks: the Page-
    Hinkley trip, the burst start, and every param hot-swap land as
    timeline annotations alongside the regime switch."""
    from repro.online import OnlineConfig, get_schedule
    from repro.sim import PoissonTrace

    cfg, tables = make_paper_env(n_uavs=3, slot_seconds=10.0,
                                 peak_rps=20.0)
    pol = build_policy("a2c", cfg, tables, episodes=2)
    pol.train(seed=0)
    res = simulate(cfg, tables, pol, PoissonTrace(rate_rps=6.0),
                   n_requests=6000, seed=0,
                   fleet=FleetConfig(slo_s=1.0, timeline=True),
                   schedule=get_schedule("link-brownout", onset=5,
                                         recover=0),
                   online=OnlineConfig(algo="a2c", gate="always",
                                       window=16, min_window=4,
                                       update_every=1))
    assert res.adaptation["online"]["updates"] > 1
    kinds = {a["kind"] for a in res.timeline.annotations}
    assert "regime_switch" in kinds and "hotswap" in kinds
    assert "burst_start" in kinds or "drift_trigger" in kinds
    swaps = [a for a in res.timeline.annotations if a["kind"] == "hotswap"]
    assert len(swaps) == res.adaptation["online"]["updates"]


# --------------------------------------------------------------------------
# the acceptance regime: cluster-brownout through the JSON export
# --------------------------------------------------------------------------

def _load_fleetview():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "fleetview.py")
    spec = importlib.util.spec_from_file_location("fleetview", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def brownout_export(tmp_path_factory):
    """One small cluster-brownout run -> write_timeline -> fleetview
    summarize: the exact artifact chain CI exercises, sized so the
    brownout burns hard enough to page and the recovery clears it."""
    sc = get_scenario("cluster-brownout").replace(
        seeds=(0,), n_requests=30_000, slo_target=0.98,
        drift_kw={"onset": 8, "relax": 20, "scale": 1.75,
                  "queue_scale": 6.0})
    rep = run_scenario(sc, ("join_shortest_queue",), timeline=True)
    r = rep.results["join_shortest_queue"]
    path = str(tmp_path_factory.mktemp("fv") / "flight.json")
    write_timeline(path, [{"policy": "join_shortest_queue", "seed": 0,
                           "timeline": r.timelines[0]}],
                   meta={"scenario": sc.name})
    fv = _load_fleetview()
    doc = read_timeline(path)
    return fv, doc, fv.summarize(doc), r


def test_brownout_regime_switch_annotations(brownout_export):
    _, _, summ, _ = brownout_export
    run = summ["runs"][0]
    switches = [a for a in run["annotations"]
                if a["kind"] == "regime_switch"]
    assert [s["epoch"] for s in switches] == [8, 20]   # onset + relax
    assert run["annotation_counts"]["regime_switch"] == 2
    # the regime column tracks the switches (patch index, monotone:
    # 0 calm, 1 surge, 2 recovered)
    regimes = doc_col(brownout_export[1], "regime")
    assert regimes[7] == 0 and regimes[10] == 1 and regimes[-1] == 2


def doc_col(doc, key):
    return doc["runs"][0]["timeline"]["columns"][key]


def test_brownout_autoscale_decision_with_measured_trigger(
        brownout_export):
    _, _, summ, _ = brownout_export
    run = summ["runs"][0]
    decisions = [a for a in run["annotations"] if a["kind"] == "autoscale"]
    assert decisions, "autoscaler never moved"
    for d in decisions:
        assert d["action"] in ("dvfs_up", "dvfs_down", "replica_up",
                               "replica_down")
        assert isinstance(d["queue"], (int, float))    # measured depth
    # the surge must push capacity *up* at some point
    assert any(d["action"] in ("dvfs_up", "replica_up") for d in decisions)


def test_brownout_per_server_series(brownout_export):
    _, _, summ, _ = brownout_export
    srv = summ["runs"][0]["servers"]
    assert srv["n"] == 4 and len(srv["names"]) == 4
    epochs = summ["runs"][0]["epochs"]
    for key in ("srv_queue", "srv_dvfs", "srv_replicas", "srv_power_w"):
        assert len(srv[key]) == 4
        assert all(len(s) == epochs for s in srv[key])
    # DVFS actually moved during the surge
    dvfs = np.asarray(srv["srv_dvfs"], float)
    assert (dvfs.max(axis=1) > dvfs.min(axis=1)).any()


def test_brownout_burn_alert_fires_and_clears(brownout_export):
    _, _, summ, _ = brownout_export
    slo = summ["runs"][0]["slo"]
    assert slo["alerts"] >= 1
    a = slo["alerts_detail"][0]
    # fires during the brownout (epochs 8..20), clears after recovery
    assert 8 <= a["start"] <= 20
    assert a["end"] is not None and a["end"] > 20
    assert a["peak_burn_fast"] > slo["fast_burn"]
    assert a["peak_burn_slow"] > slo["slow_burn"]
    # mirrored as timeline annotations too
    kinds = summ["runs"][0]["annotation_counts"]
    assert kinds.get("slo_alert", 0) == slo["alerts"]


def test_brownout_scenario_report_carries_slo(brownout_export):
    _, _, _, r = brownout_export
    assert r.slo is not None
    assert r.slo["mean"]["alerts"] >= 1
    assert r.slo["per_seed"][0]["target"] == pytest.approx(0.98)


def test_fleetview_renders_and_exports(brownout_export, tmp_path):
    fv, doc, summ, _ = brownout_export
    text = fv.render(doc)
    assert "error budget" in text and "page #1" in text
    assert "regime_switch" in text and "tier0" in text
    html = fv.to_html(doc)
    assert "<svg" in html and "flight recorder" in html
    # the summary is valid JSON end to end
    json.loads(json.dumps(summ))
    assert summ["type"] == "fleetview"


def test_fleetview_sparkline_handles_nan_and_flat():
    fv = _load_fleetview()
    assert fv.spark(np.array([np.nan, np.nan]), 10) == "··"
    assert fv.spark(np.array([1.0, 1.0, 1.0]), 10) == "▄▄▄"
    s = fv.spark(np.linspace(0, 1, 64), 8)
    assert len(s) == 8 and s[0] == "▁" and s[-1] == "█"


# --------------------------------------------------------------------------
# learner diagnostics: series present, zero added trace sites
# --------------------------------------------------------------------------

def test_explained_variance_and_kl_helpers():
    r = np.array([1.0, 2.0, 3.0, 4.0])
    assert float(explained_variance(r, r)) == pytest.approx(1.0)
    # a constant prediction explains nothing; an anti-correlated one
    # is worse than the mean (EV = 1 - Var(2r)/Var(r) = -3)
    assert float(explained_variance(r, np.zeros(4))) == pytest.approx(
        0.0, abs=1e-6)
    assert float(explained_variance(r, -r)) == pytest.approx(-3.0,
                                                             rel=1e-5)
    assert float(explained_variance(np.ones(4), np.zeros(4))) == 0.0
    lp = np.array([-1.0, -2.0])
    assert float(approx_kl(lp, lp)) == pytest.approx(0.0)
    assert float(approx_kl(lp, lp - 0.5)) == pytest.approx(0.5)


@pytest.mark.parametrize("algo", ["a2c", "ppo"])
def test_train_diag_series_with_zero_retraces(algo):
    cfg, tables = make_paper_env(n_uavs=3)
    jaxmon.reset_trace_counts()
    with jaxmon.track_traces() as d:
        if algo == "a2c":
            _, hist = A2C.train(cfg, tables, A2CConfig(episodes=4),
                                jax.random.key(0))
        else:
            _, hist = PPO.train(cfg, tables, PPO.PPOConfig(episodes=4),
                                jax.random.key(0))
    # one trace for the whole run: the diagnostics add no trace sites
    assert d.get(f"train.{algo}", 0) == 1, f"re-traced: {d}"
    diag = TrainDiag.from_history(hist)
    assert diag.updates == 4
    assert set(DIAG_KEYS) <= set(diag.keys)
    for k in DIAG_KEYS:
        assert np.isfinite(diag.column(k)).all(), k
    s = diag.summary()
    assert s["updates"] == 4 and "entropy" in s
    assert isinstance(check_health(diag), list)


def test_check_health_flags_kl_spike_and_entropy_collapse():
    diag = TrainDiag.from_history([
        {"entropy": 1e-6, "approx_kl": 5.0, "grad_norm": 1.0},
        {"entropy": 1e-6, "approx_kl": 5.0, "grad_norm": 1.0}])
    issues = " ".join(check_health(diag))
    assert "approx_kl" in issues or "kl" in issues.lower()
    assert "entropy" in issues.lower()

"""Model assembly: stacks of blocks scanned with ``lax.scan`` over stacked
layer parameters (keeps HLO size O(1) in depth — required for the 100-layer
VLM / 64-layer SSM dry-runs).

Public API:
  plan_model(cfg)                 -> param plan (shapes + logical axes)
  init(cfg, rng)                  -> params
  forward_train(cfg, params, batch, remat) -> (loss, metrics)
  prefill(cfg, params, batch, total_len)   -> (last_logits, cache)
  init_cache(cfg, batch_size, seq_len)     -> zeroed decode cache
  decode_step(cfg, params, cache, token, pos) -> (logits, new_cache)

Stacks: homogeneous runs of layers grouped into scan-able "superblocks"
(e.g. recurrentgemma (rec,rec,attn) periods; llama-vision 4x self + 1 cross).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as pp
from repro.models.params import P
from repro.models.layers import (plan_norm, apply_norm, dense,
                                 sinusoidal_positions)
from repro.quant.quantize import QTensor
from repro.models.blocks import plan_block, apply_block


@dataclasses.dataclass(frozen=True)
class Sub:
    name: str
    kind: str
    repeat: int = 1
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class StackDef:
    name: str
    length: int          # scan length
    subs: Tuple[Sub, ...]

    @property
    def layers(self) -> int:
        return self.length * sum(s.repeat for s in self.subs)


def stack_defs(cfg: ModelConfig) -> Tuple[StackDef, ...]:
    """Decoder trunk stacks, in execution order."""
    L = cfg.n_layers
    if cfg.ssm:
        return (StackDef("main", L, (Sub("blk", "ssm"),)),)
    if cfg.block_pattern:
        p = cfg.block_pattern
        n_per, n_full = len(p), L // len(p)
        defs = [StackDef("period", n_full,
                         tuple(Sub(f"s{i}", p[i]) for i in range(n_per)))]
        rem = L - n_full * n_per
        if rem:
            rem_kinds = p[:rem]
            if len(set(rem_kinds)) == 1:
                defs.append(StackDef("tail", rem, (Sub("blk", rem_kinds[0]),)))
            else:
                for i, k in enumerate(rem_kinds):
                    defs.append(StackDef(f"tail{i}", 1, (Sub("blk", k),)))
        return tuple(defs)
    if cfg.cross_attn_every:
        e = cfg.cross_attn_every
        n_full = L // e
        defs = [StackDef("period", n_full,
                         (Sub("attn", "attn", e - 1), Sub("xattn", "xattn")))]
        rem = L - n_full * e
        if rem:
            defs.append(StackDef("tail", rem, (Sub("blk", "attn"),)))
        return tuple(defs)
    if cfg.enc_dec:
        return (StackDef("main", L, (Sub("blk", "dec"),)),)
    if cfg.moe:
        defs = []
        if cfg.first_dense_layers:
            defs.append(StackDef("dense0", cfg.first_dense_layers,
                                 (Sub("blk", "attn", 1, False),)))
        defs.append(StackDef("main", L - cfg.first_dense_layers,
                             (Sub("blk", "attn", 1, True),)))
        return tuple(defs)
    return (StackDef("main", L, (Sub("blk", "attn"),)),)


def enc_stack_defs(cfg: ModelConfig) -> Tuple[StackDef, ...]:
    if not cfg.enc_dec:
        return ()
    return (StackDef("enc", cfg.n_encoder_layers, (Sub("blk", "enc"),)),)


def _sub_window(cfg: ModelConfig, sub: Sub) -> Optional[int]:
    if sub.kind == "attn" and cfg.block_pattern:
        return cfg.local_window          # griffin local attention
    if sub.kind in ("attn", "dec"):
        return cfg.sliding_window
    return None


# --------------------------------------------------------------------------
# plans / init
# --------------------------------------------------------------------------

def _plan_superblock(cfg: ModelConfig, sdef: StackDef):
    plan = {}
    for sub in sdef.subs:
        bp = plan_block(cfg, sub.kind, moe=sub.moe)
        plan[sub.name] = pp.stack(bp, sub.repeat) if sub.repeat > 1 else bp
    return plan


def plan_model(cfg: ModelConfig):
    d, V = cfg.d_model, cfg.vocab_size
    plan = {
        "tok_embed": P((V, d), ("vocab", "embed"), "normal", scale=0.01),
        "final_norm": plan_norm(cfg),
        "stacks": {s.name: pp.stack(_plan_superblock(cfg, s), s.length)
                   for s in stack_defs(cfg)},
    }
    if not cfg.tie_embeddings:
        plan["lm_head"] = P((d, V), ("embed", "vocab"))
    if cfg.enc_dec:
        plan["enc_stacks"] = {
            s.name: pp.stack(_plan_superblock(cfg, s), s.length)
            for s in enc_stack_defs(cfg)}
        plan["enc_norm"] = plan_norm(cfg)
    return plan


def init(cfg: ModelConfig, rng: jax.Array):
    return pp.materialize(plan_model(cfg), rng, cfg.pdtype)


def abstract_params(cfg: ModelConfig):
    return pp.abstract(plan_model(cfg), cfg.pdtype)


def param_axes(cfg: ModelConfig):
    return pp.axes_tree(plan_model(cfg))


def n_params(cfg: ModelConfig) -> int:
    return pp.count_params(plan_model(cfg))


# --------------------------------------------------------------------------
# stack application
# --------------------------------------------------------------------------

def _apply_stack(cfg: ModelConfig, sdef: StackDef, p_stack, x, *, mode: str,
                 pos0, cache=None, kv_src=None, total_len=None, remat=False):
    want_cache = mode in ("prefill", "decode")
    has_cache_in = cache is not None

    def body(carry, xs):
        x, aux = carry
        p_sl, c_sl = xs if has_cache_in else (xs, None)
        new_c = {}
        for sub in sdef.subs:
            window = _sub_window(cfg, sub)
            clen = None
            if mode == "prefill" and total_len is not None:
                clen = min(total_len, window) if window else total_len
            sub_cache = c_sl[sub.name] if c_sl is not None else None
            if sub.repeat == 1:
                x, nc, a = apply_block(cfg, sub.kind, p_sl[sub.name], x,
                                       mode=mode, pos0=pos0, cache=sub_cache,
                                       kv_src=kv_src, window=window,
                                       cache_len=clen)
                aux = aux + a
                if want_cache:
                    new_c[sub.name] = nc
            else:
                def inner(carry2, xs2, _k=sub.kind, _w=window, _cl=clen):
                    x2, aux2 = carry2
                    pp2, cc2 = xs2 if has_cache_in else (xs2, None)
                    x2, nc2, a2 = apply_block(cfg, _k, pp2, x2, mode=mode,
                                              pos0=pos0, cache=cc2,
                                              kv_src=kv_src, window=_w,
                                              cache_len=_cl)
                    return (x2, aux2 + a2), (nc2 if want_cache else None)

                inner_xs = ((p_sl[sub.name], sub_cache) if has_cache_in
                            else p_sl[sub.name])
                (x, aux), ncs = jax.lax.scan(inner, (x, aux), inner_xs)
                if want_cache:
                    new_c[sub.name] = ncs
        return (x, aux), (new_c if want_cache else None)

    if remat and mode == "train":
        body = jax.checkpoint(body)
    xs = (p_stack, cache) if has_cache_in else p_stack
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, new_cache, aux


def _embed(cfg: ModelConfig, params, tokens, pos0=0):
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(cfg.cdtype)
    if cfg.family == "hybrid":   # gemma convention
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    if cfg.enc_dec:              # whisper decoder: absolute positions, no RoPE
        x = x + sinusoidal_positions(tokens.shape[1], cfg.d_model,
                                     offset=pos0).astype(cfg.cdtype)
    return x


def _encode(cfg: ModelConfig, params, enc_frames):
    x = enc_frames.astype(cfg.cdtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cfg.cdtype)
    for s in enc_stack_defs(cfg):
        x, _, _ = _apply_stack(cfg, s, params["enc_stacks"][s.name], x,
                               mode="train", pos0=jnp.int32(0))
    return apply_norm(cfg, params["enc_norm"], x)


def _trunk(cfg: ModelConfig, params, x, *, mode, pos0, caches=None,
           kv_src=None, total_len=None, remat=False):
    aux_total = jnp.float32(0.0)
    new_caches = {} if mode in ("prefill", "decode") else None
    for s in stack_defs(cfg):
        cache_s = caches[s.name] if caches is not None else None
        x, nc, aux = _apply_stack(cfg, s, params["stacks"][s.name], x,
                                  mode=mode, pos0=pos0, cache=cache_s,
                                  kv_src=kv_src, total_len=total_len,
                                  remat=remat)
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches[s.name] = nc
    return apply_norm(cfg, params["final_norm"], x), new_caches, aux_total


def _head(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h,
                          params["tok_embed"].astype(h.dtype))
    w = params["lm_head"]
    if not isinstance(w, QTensor):
        w = w.astype(h.dtype)
    return dense(h, w)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def _kv_src(cfg: ModelConfig, params, batch):
    if cfg.enc_dec:
        return _encode(cfg, params, batch["enc_frames"])
    if cfg.cross_attn_every:
        return batch["media"].astype(cfg.cdtype)
    return None


LOSS_CHUNK = 512


def lm_loss(cfg: ModelConfig, params, h, targets):
    """Chunked cross-entropy. h: (B,S,d); targets: (B,S) int32, <0 = masked."""
    B, S, d = h.shape
    chunk = min(LOSS_CHUNK, S)
    if S % chunk != 0:
        chunk = S
    n = S // chunk
    hs = h.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hc, tc = xs
        logits = _head(cfg, params, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        mask = (tc >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        loss_sum, count = acc
        return (loss_sum + jnp.sum(nll), count + jnp.sum(mask)), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ts))
    return loss_sum / jnp.maximum(count, 1.0)


def forward_train(cfg: ModelConfig, params, batch, remat: bool = True):
    """batch: tokens (B,S), targets (B,S), [media | enc_frames]."""
    x = _embed(cfg, params, batch["tokens"])
    kv = _kv_src(cfg, params, batch)
    h, _, aux = _trunk(cfg, params, x, mode="train", pos0=jnp.int32(0),
                       kv_src=kv, remat=remat)
    loss = lm_loss(cfg, params, h, batch["targets"])
    return loss + aux, {"lm_loss": loss, "aux_loss": aux}


def forward_logits(cfg: ModelConfig, params, batch):
    """Full-sequence logits (small-model testing path)."""
    x = _embed(cfg, params, batch["tokens"])
    kv = _kv_src(cfg, params, batch)
    h, _, _ = _trunk(cfg, params, x, mode="train", pos0=jnp.int32(0), kv_src=kv)
    return _head(cfg, params, h)


def prefill(cfg: ModelConfig, params, batch, total_len: Optional[int] = None):
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    kv = _kv_src(cfg, params, batch)
    total = total_len if total_len is not None else tokens.shape[1]
    h, caches, _ = _trunk(cfg, params, x, mode="prefill", pos0=jnp.int32(0),
                          kv_src=kv, total_len=total)
    logits = _head(cfg, params, h[:, -1])
    return logits, caches


def decode_step(cfg: ModelConfig, params, caches, token, pos):
    """token: (B,) int32; pos: scalar int32 (tokens already cached)."""
    x = _embed(cfg, params, token[:, None], pos0=pos)
    h, new_caches, _ = _trunk(cfg, params, x, mode="decode",
                              pos0=jnp.asarray(pos, jnp.int32), caches=caches)
    return _head(cfg, params, h[:, 0]), new_caches


# --------------------------------------------------------------------------
# cache construction (decode entry without a real prefill — dry-run path)
# --------------------------------------------------------------------------

def _zero_cache_block(cfg: ModelConfig, kind: str, window, B: int,
                      seq_len: int, dtype):
    Dh, HK = cfg.resolved_head_dim, cfg.n_kv_heads
    C = min(window, seq_len) if window else seq_len
    if kind == "ssm":
        return {"conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), dtype)}
    if kind == "rec":
        w = cfg.resolved_lru_width
        return {"conv": jnp.zeros((B, cfg.ssm_conv - 1, w), dtype),
                "lru": jnp.zeros((B, w), dtype)}
    if kind == "xattn":
        return {"xk": jnp.zeros((B, cfg.n_media_tokens, HK, Dh), dtype),
                "xv": jnp.zeros((B, cfg.n_media_tokens, HK, Dh), dtype)}
    if cfg.use_mla and kind in ("attn",):
        return {"ckv": jnp.zeros((B, C, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((B, C, cfg.qk_rope_head_dim), dtype)}
    blk = {"k": jnp.zeros((B, C, HK, Dh), dtype),
           "v": jnp.zeros((B, C, HK, Dh), dtype)}
    if kind == "dec":
        blk["xk"] = jnp.zeros((B, cfg.encoder_seq, HK, Dh), dtype)
        blk["xv"] = jnp.zeros((B, cfg.encoder_seq, HK, Dh), dtype)
    return blk


def init_cache(cfg: ModelConfig, B: int, seq_len: int, dtype=None):
    dtype = dtype if dtype is not None else cfg.cdtype

    def tile(tree, reps):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, reps + a.shape)
            if reps else a, tree)

    caches = {}
    for s in stack_defs(cfg):
        sub_caches = {}
        for sub in s.subs:
            window = _sub_window(cfg, sub)
            blk = _zero_cache_block(cfg, sub.kind, window, B, seq_len, dtype)
            reps = (s.length,) if sub.repeat == 1 else (s.length, sub.repeat)
            sub_caches[sub.name] = tile(blk, reps)
        caches[s.name] = sub_caches
    return caches


# --------------------------------------------------------------------------
# logical axes for cache trees (mirrors init_cache; used by launch/shardings)
# --------------------------------------------------------------------------

def _cache_axes_block(cfg: ModelConfig, kind: str):
    if kind == "ssm":
        return {"conv": ("batch", None, "inner"),
                "ssm": ("batch", "inner", None)}
    if kind == "rec":
        return {"conv": ("batch", None, "lru"), "lru": ("batch", "lru")}
    if kind == "xattn":
        return {"xk": ("batch", None, "kv_heads", None),
                "xv": ("batch", None, "kv_heads", None)}
    if cfg.use_mla and kind == "attn":
        return {"ckv": ("batch", "kv_cache_seq", None),
                "krope": ("batch", "kv_cache_seq", None)}
    blk = {"k": ("batch", "kv_cache_seq", "kv_heads", None),
           "v": ("batch", "kv_cache_seq", "kv_heads", None)}
    if kind == "dec":
        blk["xk"] = ("batch", None, "kv_heads", None)
        blk["xv"] = ("batch", None, "kv_heads", None)
    return blk


def cache_axes(cfg: ModelConfig):
    axes = {}
    for s in stack_defs(cfg):
        sub_axes = {}
        for sub in s.subs:
            blk = _cache_axes_block(cfg, sub.kind)
            lead = ("layers",) if sub.repeat == 1 else ("layers", "layers")
            sub_axes[sub.name] = {k: lead + v for k, v in blk.items()}
        axes[s.name] = sub_axes
    return axes

"""Fleet simulation via the library API (DESIGN.md §4): train the
stability-aware controller under domain-randomized load, then stress it
against bursty (MMPP) traffic next to the static baselines — the same
request stream for every policy, per-request metrics out.

    PYTHONPATH=src python examples/fleet_sim.py [--devices 4]
"""
import argparse

import numpy as np

from repro.core import (A2CConfig, RewardWeights, agent_policy,
                        make_paper_env, train_agent)
from repro.core.baselines import POLICIES
from repro.core.latency import LatencyParams
from repro.sim import FleetConfig, MMPPTrace, simulate
from repro.sim.traces import RandomRateTrace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--episodes", type=int, default=500)
    ap.add_argument("--requests", type=int, default=20_000)
    args = ap.parse_args()

    n, burst = args.devices, 30.0
    cfg, tables = make_paper_env(
        n_uavs=n, slot_seconds=10.0, peak_rps=burst,
        frames_per_slot=10.0 * burst,   # drain parity with the fleet
        latency=LatencyParams(server_flops=0.55e12 * n, bw_max_bps=1e9),
        weights=RewardWeights(w_acc=0.05, w_lat=0.1, w_energy=0.15,
                              w_stab=0.7))
    mids = np.zeros(n, np.int32)          # homogeneous vgg fleet

    print(f"training controller ({args.episodes} episodes) ...")
    params, _ = train_agent(cfg, tables,
                            A2CConfig(episodes=args.episodes,
                                      entropy_coef=0.03),
                            trace=RandomRateTrace(max_rps=burst))

    trace = MMPPTrace(rate_low_rps=2.0, rate_high_rps=burst)
    print(f"\n{'policy':14s} {'p50_s':>8s} {'p95_s':>8s} {'slo_att':>8s} "
          f"{'E/req_J':>8s}")
    for name, pol in (("a2c", agent_policy(params)),
                      ("device_only", POLICIES["device_only"]),
                      ("full_offload", POLICIES["full_offload"])):
        runs = [simulate(cfg, tables, pol, trace,
                         n_requests=args.requests, seed=seed,
                         fleet=FleetConfig(slo_s=2.0), model_ids=mids)
                for seed in (0, 2, 4)]
        m = {k: float(np.mean([r.summary[k] for r in runs]))
             for k in ("p50", "p95", "slo_attainment",
                       "energy_per_request_j")}
        print(f"{name:14s} {m['p50']:8.3f} {m['p95']:8.2f} "
              f"{m['slo_attainment']:8.3f} {m['energy_per_request_j']:8.3f}")


if __name__ == "__main__":
    main()

"""PPO agent (beyond-paper ablation).

The paper chooses A2C "for its efficiency and effectiveness"; PPO is the
natural modern baseline to test that choice. Built on the same shared
networks and batched rollout machinery as A2C
(``repro.core.actor_critic`` — the rollout records the behavior policy's
logp/value for the clipped surrogate); adds GAE and multiple surrogate
epochs per episode batch. ``batch_envs`` parallel envs per update, same
as A2C. Compared against A2C in ``benchmarks.run --only ablation_agents``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import actor_critic as net
from repro.core.a2c import A2CConfig
from repro.core.actor_critic import critic_apply, init_agent, logp_entropy
from repro.core.env import EnvConfig, ProfileTables
from repro.obs import jaxmon, traindiag
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    gamma: float = 0.95
    lam: float = 0.95           # GAE
    clip: float = 0.2
    epochs: int = 4             # surrogate epochs per episode
    lr: float = 3e-4
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    episodes: int = 300         # update steps; each uses batch_envs episodes
    batch_envs: int = 1         # parallel env instances per update (vmap)
    base: A2CConfig = dataclasses.field(default_factory=A2CConfig)


def make_train_episode(env_cfg: EnvConfig, tables: ProfileTables,
                       pc: PPOConfig, model_ids=None):
    opt = AdamWConfig(lr=pc.lr, weight_decay=0.0, warmup_steps=0,
                      total_steps=pc.episodes * pc.epochs, grad_clip=1.0,
                      min_lr_ratio=1.0)
    E = max(int(pc.batch_envs), 1)
    n = env_cfg.n_uavs
    rollout = net.make_rollout(env_cfg, tables, record_policy=True)

    def loss_fn(params, traj, advs, rets):
        """Clipped surrogate over the flattened (E*T,) transition batch;
        advantages normalized across the whole batch (for E=1 this is
        the per-episode normalization the unbatched agent used)."""
        def per_step(obs, actions, valid):
            lp, ent = logp_entropy(params, obs, actions, valid)
            return lp, ent, critic_apply(params, obs)
        lp, ent, values = jax.vmap(per_step)(
            traj["obs"], traj["actions"], traj["valid"])
        ratio = jnp.exp(lp - traj["logp"])
        a_n = (advs - jnp.mean(advs)) / (jnp.std(advs) + 1e-6)
        surr = jnp.minimum(ratio * a_n,
                           jnp.clip(ratio, 1 - pc.clip, 1 + pc.clip) * a_n)
        actor_loss = -jnp.mean(surr)
        critic_loss = 0.5 * jnp.mean(jnp.square(rets - values))
        loss = (actor_loss + pc.value_coef * critic_loss
                - pc.entropy_coef * jnp.mean(ent))
        return loss, {"actor_loss": actor_loss, "critic_loss": critic_loss,
                      # learner-health panel (repro.obs.traindiag):
                      # KL is measured against the *behavior* policy the
                      # rollout recorded, per device like entropy
                      "entropy": jnp.mean(ent) / n,
                      "approx_kl": traindiag.approx_kl(traj["logp"],
                                                       lp) / n,
                      "adv_mean": jnp.mean(advs),
                      "adv_std": jnp.std(advs),
                      "explained_var": traindiag.explained_variance(
                          rets, values)}

    @jax.jit
    def train_episode(params, opt_state, rng, task_seq=None):
        jaxmon.count_trace("train.ppo")
        task_seq = net.prepare_task_seq(task_seq, E)
        _, traj, bootstrap = net.run_batched_episodes(
            env_cfg, tables, rollout, params, rng, E,
            model_ids=model_ids, task_seq=task_seq)
        advs, rets = jax.vmap(net.gae, in_axes=(0, 0, 0, None, None))(
            traj["reward"], traj["value"], bootstrap, pc.gamma, pc.lam)
        # surrogate epochs see one flat transition batch across all envs
        flat = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj)
        advs, rets = advs.reshape(-1), rets.reshape(-1)

        def epoch(carry, _):
            params, opt_state = carry
            (loss, stats), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, flat, advs, rets)
            params, opt_state, om = adamw_update(opt, params, grads,
                                                 opt_state)
            return (params, opt_state), dict(
                stats, loss=loss, grad_norm=om["grad_norm"])
        (params, opt_state), per_epoch = jax.lax.scan(
            epoch, (params, opt_state), None, length=pc.epochs)
        # report the final surrogate epoch: the policy/critic actually
        # carried forward (KL there measures total drift this update)
        last = jax.tree.map(lambda x: x[-1], per_epoch)
        return params, opt_state, dict(
            last, mean_reward=jnp.mean(traj["reward"]),
            episode_reward=jnp.mean(jnp.sum(traj["reward"], -1)))

    return train_episode


def train(env_cfg: EnvConfig, tables: ProfileTables, pc: PPOConfig, rng,
          model_ids=None, log_every: int = 0, task_sampler=None):
    """``task_sampler(episode) -> (episode_len, n_uavs)`` offered-load
    sequences enable trace-driven training exactly like A2C.train (with
    ``pc.batch_envs = E > 1`` each update consumes E sampled sequences —
    per-env domain randomization; the episode-indexing convention lives
    once in ``actor_critic.stack_task_seqs``)."""
    params = init_agent(env_cfg, tables, pc.base, rng)
    opt_state = adamw_init(params)
    step = make_train_episode(env_cfg, tables, pc, model_ids=model_ids)
    E = max(int(pc.batch_envs), 1)
    history = []
    for ep in range(pc.episodes):
        rng, k = jax.random.split(rng)
        if task_sampler is None:
            params, opt_state, stats = step(params, opt_state, k)
        else:
            params, opt_state, stats = step(
                params, opt_state, k, net.stack_task_seqs(task_sampler,
                                                          ep, E))
        history.append({k2: float(v) for k2, v in stats.items()})
        if log_every and (ep + 1) % log_every == 0:
            print(f"ppo ep {ep+1:4d} "
                  f"reward={history[-1]['mean_reward']:+.4f}", flush=True)
    return params, history

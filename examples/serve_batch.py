"""Batched serving: prefill + scanned decode with KV caches across
architectures (dense GQA, MLA+MoE, SSM, hybrid — the cache machinery
differs per family; the engine API does not).

    PYTHONPATH=src python examples/serve_batch.py [--new-tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init
from repro.serving import ServeConfig, ServingEngine

ARCHS = ["qwen2-0.5b", "deepseek-v2-lite-16b", "falcon-mamba-7b",
         "recurrentgemma-2b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = init(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, params,
                            ServeConfig(max_new_tokens=args.new_tokens))
        toks = (jnp.arange(args.batch * args.prompt_len, dtype=jnp.int32)
                .reshape(args.batch, args.prompt_len) * 13) % cfg.vocab_size
        batch = {"tokens": toks}
        if cfg.cross_attn_every:
            batch["media"] = jnp.zeros(
                (args.batch, cfg.n_media_tokens, cfg.d_model))
        if cfg.enc_dec:
            batch["enc_frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model))
        t0 = time.time()
        out = eng.generate(batch)
        dt = time.time() - t0
        per_tok = dt / args.new_tokens * 1e3
        print(f"{arch:24s} [{cfg.family:6s}] generated {out.shape} "
              f"in {dt:5.2f}s ({per_tok:6.1f} ms/tok incl. compile)  "
              f"sample={list(map(int, out[0, :6]))}")


if __name__ == "__main__":
    main()

"""Multi-host initialization for real pod deployments.

The dry-run proves the mesh/sharding config with 512 virtual devices in one
process; on real hardware each host runs this same entrypoint and
``jax.distributed.initialize`` stitches processes into one global device
set. Environment contract (set by the scheduler / launch script):

  REPRO_COORDINATOR   host:port of process 0       (e.g. 10.0.0.1:8476)
  REPRO_NUM_PROCESSES total process count          (e.g. 128 hosts)
  REPRO_PROCESS_ID    this process's index

On Cloud TPU these fall back to the TPU metadata auto-detection built into
jax.distributed.initialize(). See launch/run_multipod.sh.
"""
from __future__ import annotations

import os

import jax


def maybe_initialize_distributed() -> bool:
    """Initialize multi-process JAX if the env contract is present.

    Returns True when running multi-process. Safe to call on single host
    (no-op). Must run before any other jax API touches the backend.
    """
    coord = os.environ.get("REPRO_COORDINATOR")
    nproc = os.environ.get("REPRO_NUM_PROCESSES")
    pid = os.environ.get("REPRO_PROCESS_ID")
    if coord and nproc and pid:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(nproc),
            process_id=int(pid),
        )
        return True
    if os.environ.get("REPRO_TPU_AUTODETECT"):
        jax.distributed.initialize()   # Cloud TPU metadata path
        return True
    return False


def describe_topology() -> str:
    return (f"process {jax.process_index()}/{jax.process_count()} "
            f"local_devices={jax.local_device_count()} "
            f"global_devices={jax.device_count()}")

"""EdgeRL core: the paper's contribution as a composable JAX module.

Profiles (CNN analytic + transformer), energy/latency cost models
(Eqs. 1-5), the EdgeEnv MDP (Eq. 6-7), reward (Eqs. 8-11), the A2C agent
(Sec. II-C) and the centralized controller (Sec. II-D).
"""
from repro.core.env import (OBS_FEATURES, EnvConfig, ProfileTables,
                            build_tables, env_reset, env_step, observe)
from repro.core.reward import RewardWeights
from repro.core.a2c import A2CConfig, train, init_agent, make_train_episode
from repro.core.profiles import paper_profiles, transformer_profile
from repro.core.controller import (make_paper_env, make_tpu_env,
                                   measured_state, resolve_selection,
                                   train_agent, evaluate_policy, decide,
                                   agent_policy)
from repro.core.roofline_env import make_dryrun_tpu_env

__all__ = [
    "OBS_FEATURES", "EnvConfig", "ProfileTables", "build_tables",
    "env_reset", "env_step", "observe", "RewardWeights", "A2CConfig",
    "train", "init_agent", "make_train_episode", "paper_profiles",
    "transformer_profile", "make_paper_env", "make_tpu_env",
    "measured_state", "resolve_selection", "train_agent",
    "evaluate_policy", "decide", "agent_policy", "make_dryrun_tpu_env",
]

"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models.attention_core import plain_attention
from repro.models.ssm import ssm_scan_chunked
from repro.models.rglru import rglru_scan


def flash_attention_ref(q, k, v, *, causal=True, window=None,
                        logit_scale=None):
    """q: (B,H,Sq,D); k,v: (B,HK,Skv,D) -> (B,H,Sq,Dv)  [kernel layout]."""
    out = plain_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        q_positions=jnp.arange(q.shape[2], dtype=jnp.int32),
        kv_positions=jnp.arange(k.shape[2], dtype=jnp.int32),
        causal=causal, window=window, logit_scale=logit_scale)
    return out.transpose(0, 2, 1, 3)


def mamba_scan_ref(cfg, p, u, h0=None):
    """Chunked selective-scan oracle (models/ssm.py)."""
    return ssm_scan_chunked(cfg, p, u, h0=h0)


def rglru_scan_ref(a, gx, h0=None):
    """Diagonal linear recurrence oracle: h_t = a_t h_{t-1} + gx_t.

    a, gx: (B, S, W) f32. Returns (h_seq, h_last)."""
    import jax

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    if h0 is not None:
        gx = gx.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h, h[:, -1]

"""Scan-aware analytic cost model over jaxprs.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified in this
container: 2-layer and 8-layer scanned models report identical FLOPs), so for
scan-over-layers models it undercounts by ~n_layers. This walker recurses
into scan bodies and multiplies by trip count, giving exact dot FLOPs and a
bytes proxy:

  flops: dot_general = 2*M*N*K; elementwise/reduce ops = 1/elem; layout ops = 0
  bytes (two bounds):
    bytes_min — perfectly-fused traffic: dot_general inputs+outputs,
                gather/scatter/dynamic-slice outputs (params, activations,
                KV-cache movement); elementwise assumed fused.
    bytes     — unfused upper bound: every eqn's outputs as well.
  The roofline memory term uses bytes_min (a roofline is the best case).

EXPERIMENTS.md §Roofline reports both this and raw cost_analysis numbers.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import numpy as np

LAYOUT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "concatenate", "rev", "pad", "copy", "convert_element_type",
    "bitcast_convert_type", "stop_gradient", "device_put", "sharding_constraint",
}
ZERO_PRIMS = {"iota", "eq", "ne", "ge", "gt", "le", "lt", "and", "or", "not",
              "select_n", "sign", "is_finite", "argmax", "argmin"}
TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                  "sin", "cos", "pow", "log1p", "expm1", "cbrt"}


def _size(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def _bytes(aval) -> int:
    return _size(aval) * aval.dtype.itemsize


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * _size(out) * k


def analyze_jaxpr(jaxpr, mult: int = 1, acc: Dict[str, float] = None):
    """Returns dict(flops=..., bytes=..., bytes_min=..., bytes_fused=...)."""
    if acc is None:
        acc = {"flops": 0.0, "bytes": 0.0, "bytes_min": 0.0,
               "bytes_fused": 0.0}
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = eqn.params["length"]
            analyze_jaxpr(eqn.params["jaxpr"], mult * length, acc)
            continue
        if name == "while":
            # we never emit unbounded whiles; count body once if present
            analyze_jaxpr(eqn.params["body_jaxpr"], mult, acc)
            continue
        if name == "cond":
            branches = eqn.params["branches"]
            sub = [analyze_jaxpr(b, mult) for b in branches]
            acc["flops"] += max(s["flops"] for s in sub)
            acc["bytes"] += max(s["bytes"] for s in sub)
            continue
        sub_jaxpr = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        if sub_jaxpr is not None:   # pjit / remat / custom_* / closed_call
            analyze_jaxpr(sub_jaxpr, mult, acc)
            continue
        if name in LAYOUT_PRIMS or name in ZERO_PRIMS:
            continue
        out_elems = sum(_size(v.aval) for v in eqn.outvars)
        out_bytes = sum(_bytes(v.aval) for v in eqn.outvars)
        if name == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            in_bytes = [_bytes(v.aval) for v in eqn.invars]
            moved = out_bytes + sum(in_bytes)
            acc["bytes"] += mult * moved
            acc["bytes_min"] += mult * moved
            # flash-fused accounting: a "score-like" tensor is a dot output
            # much larger than both operands (q@k^T), and a "prob-like"
            # input is much larger than the dot's output (probs@v) — both
            # stay in VMEM inside the flash kernel.
            fused = out_bytes if out_bytes <= 4 * max(in_bytes) else 0
            fused += sum(b for b in in_bytes
                         if b <= 4 * max(out_bytes, min(in_bytes)))
            acc["bytes_fused"] += mult * fused
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "take"):
            acc["bytes"] += mult * out_bytes
            acc["bytes_min"] += mult * out_bytes
            acc["bytes_fused"] += mult * out_bytes
        elif name in TRANSCENDENTAL:
            acc["flops"] += mult * out_elems  # count 1/elem (roofline proxy)
            acc["bytes"] += mult * out_bytes
        else:
            acc["flops"] += mult * out_elems
            acc["bytes"] += mult * out_bytes
    return acc


def analyze_fn(fn, *args, **kwargs) -> Dict[str, float]:
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return analyze_jaxpr(jaxpr)


def human(x: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(x) < 1000:
            return f"{x:.3g}{unit}"
        x /= 1000
    return f"{x:.3g}Z"


def analyze_jaxpr_by_op(jaxpr, mult: int = 1, acc=None):
    """Like analyze_jaxpr but keyed by primitive name — used to find which
    ops dominate a roofline term before picking the next perf iteration."""
    if acc is None:
        acc = {}
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "scan":
            analyze_jaxpr_by_op(eqn.params["jaxpr"],
                                mult * eqn.params["length"], acc)
            continue
        sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr") \
            or eqn.params.get("body_jaxpr")
        if sub is not None:
            analyze_jaxpr_by_op(sub, mult, acc)
            continue
        if name in LAYOUT_PRIMS or name in ZERO_PRIMS:
            continue
        out_bytes = sum(_bytes(v.aval) for v in eqn.outvars)
        d = acc.setdefault(name, {"flops": 0.0, "bytes_min": 0.0, "count": 0})
        d["count"] += mult
        if name == "dot_general":
            d["flops"] += mult * _dot_flops(eqn)
            d["bytes_min"] += mult * (
                out_bytes + sum(_bytes(v.aval) for v in eqn.invars))
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "take"):
            d["bytes_min"] += mult * out_bytes
    return acc

"""Architecture configs (assigned pool) + shape suite.

``ALL_ARCHS`` lists the 10 assigned architecture ids; importing this module
registers all of them. ``SHAPES`` is the assigned input-shape suite.
"""
from repro.configs.base import ModelConfig, get_config, list_configs, register

# import for registration side effects
from repro.configs import (  # noqa: F401
    qwen2_0_5b,
    llama_3_2_vision_90b,
    starcoder2_3b,
    recurrentgemma_2b,
    phi3_medium_14b,
    falcon_mamba_7b,
    deepseek_v2_lite_16b,
    qwen3_0_6b,
    whisper_large_v3,
    mixtral_8x22b,
)

ALL_ARCHS = (
    "qwen2-0.5b",
    "llama-3.2-vision-90b",
    "starcoder2-3b",
    "recurrentgemma-2b",
    "phi3-medium-14b",
    "falcon-mamba-7b",
    "deepseek-v2-lite-16b",
    "qwen3-0.6b",
    "whisper-large-v3",
    "mixtral-8x22b",
)

# (name, seq_len, global_batch, kind)
SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="decode"),
}

__all__ = [
    "ModelConfig", "get_config", "list_configs", "register",
    "ALL_ARCHS", "SHAPES",
]

"""repro.online: nonstationary worlds + closed-loop controller
adaptation under drift.

- ``drift``   — regime-switching world model: timed ``EnvPatch``es over
  EnvConfig (bandwidth brownout, battery cliff, server slowdown,
  flash-crowd rate shifts, device churn) compiled into per-regime
  records; named schedule factories behind ``get_schedule``.
- ``adapt``   — windowed replay of measured transitions captured inside
  the fleet loop, a jitted incremental update step (A2C and PPO on the
  shared ``core.actor_critic`` machinery), and policy hot-swap through
  ``Policy.jitted()``'s param-swap re-trace path.
- ``monitor`` — EWMA + Page-Hinkley drift detection gating adaptation
  bursts, and per-regime adaptation metrics: regret vs the greedy
  oracle re-solved per regime, and recovery time to within 10% of it.
"""
from repro.online.adapt import OnlineConfig, OnlineLearner, ReplayWindow
from repro.online.drift import (EnvPatch, Regime, WorldSchedule,
                                apply_env_patch, get_schedule,
                                scale_counts, schedule_names)
from repro.online.monitor import (AdaptationTracker, DriftMonitor,
                                  PageHinkley, oracle_reward)

__all__ = [
    "EnvPatch", "Regime", "WorldSchedule", "apply_env_patch",
    "get_schedule", "schedule_names", "scale_counts",
    "OnlineConfig", "OnlineLearner", "ReplayWindow",
    "AdaptationTracker", "DriftMonitor", "PageHinkley", "oracle_reward",
]

"""End-to-end training driver: train a qwen2-family LM on the synthetic
deterministic pipeline with AdamW + cosine schedule + checkpointing.

Default is a quick CPU-sized run (~1.4M params, loss visibly decreases in
~100 steps). ``--size 100m`` selects the ~124M-parameter config (the
"train a ~100M model for a few hundred steps" driver — sized for real
hardware; on this CPU container expect minutes/step).

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpointing import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models import init, n_params
from repro.optim import AdamWConfig, adamw_init


def build_cfg(size: str):
    base = get_config("qwen2-0.5b")
    if size == "100m":
        return base.with_overrides(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2048, vocab_size=50_304, tie_embeddings=False)
    return base.reduced().with_overrides(n_layers=2, d_model=128, d_ff=256,
                                         vocab_size=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="small", choices=["small", "100m"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = build_cfg(args.size)
    print(f"model: {cfg.name} variant={args.size} params={n_params(cfg):,}")
    params = init(cfg, jax.random.key(0))
    opt = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                      weight_decay=0.01)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))

    start = 0
    if (s := latest_step(args.ckpt_dir)) is not None:
        params = restore_checkpoint(args.ckpt_dir, s, params)
        start = s
        print(f"resumed from checkpoint step {s}")

    ds = SyntheticLMDataset(cfg, DataConfig(batch_size=args.batch,
                                            seq_len=args.seq))
    t0, first_loss = time.time(), None
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if first_loss is None:
            first_loss = float(m["loss"])
        if (i + 1) % 10 == 0:
            print(f"step {i+1:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f}", flush=True)
        if (i + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, i + 1, params)
            print(f"  checkpoint -> {path}")
    dt = time.time() - t0
    final = float(m["loss"])
    print(f"\ndone: {args.steps - start} steps in {dt:.1f}s "
          f"({dt/(args.steps-start+1e-9):.2f}s/step); "
          f"loss {first_loss:.3f} -> {final:.3f}")
    assert final < first_loss, "loss did not decrease"


if __name__ == "__main__":
    main()

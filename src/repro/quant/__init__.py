"""repro.quant: quantized model versions backing the EdgeRL (version, cut)
action space.

``quantize_tree``/``dequantize_tree`` convert dense-projection weights to
``QTensor`` leaves (int8 / int4-packed weight-only, or w8a8); the
``QuantVersion`` registry (bf16 / w8 / w4) derives the env's version-axis
tables (accuracy proxy, FLOP scale, activation + weight bytes) from those
real variants; ``build_version_params`` materializes the per-version param
trees the SplitServingEngine executes. The int8 matmul itself lives in
kernels/quant_matmul.py; models route every dense projection through
models/layers.py::dense, which hands QTensor leaves to
kernels/ops.py::quantized_dense (the REPRO_USE_PALLAS dispatch point).
"""
from repro.quant.quantize import (DENSE_WEIGHTS, QTensor, dequantize_tree,
                                  quantize, quantize_act, quantize_tree,
                                  tree_weight_bytes)
from repro.quant.versions import (DEFAULT_VERSIONS, QuantVersion,
                                  accuracy_proxy, build_version_params,
                                  get_version, list_versions,
                                  relative_quant_error)

__all__ = [
    "DENSE_WEIGHTS", "QTensor", "dequantize_tree", "quantize",
    "quantize_act", "quantize_tree", "tree_weight_bytes",
    "DEFAULT_VERSIONS", "QuantVersion", "accuracy_proxy",
    "build_version_params", "get_version", "list_versions",
    "relative_quant_error",
]

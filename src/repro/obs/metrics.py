"""Counters, gauges and histograms with labeled series.

A ``Metrics`` registry lives on each ``Recorder``; the module-level
``inc``/``gauge``/``observe`` helpers dispatch through the process
recorder (no-ops when recording is off). Each (name, labels) pair is
one series — e.g. ``inc("fleet.dropped", 3, policy="a2c")`` and
``inc("fleet.dropped", 1, policy="ppo")`` accumulate independently —
and every series snapshots to one ``metric`` JSONL event at
``Recorder.close()``.

Histograms keep raw values (fleet runs observe a few values per epoch,
thousands at most) and summarize to count/mean/min/max/p50/p95/p99 in
the snapshot.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.obs import events as _ev


def _key(name: str, labels: Dict) -> Tuple:
    return (name, tuple(sorted(labels.items())))


class Metrics:
    """Label-keyed counter/gauge/histogram registry (one per Recorder)."""

    def __init__(self):
        self._counters: Dict[Tuple, float] = {}
        self._gauges: Dict[Tuple, float] = {}
        self._hists: Dict[Tuple, List[float]] = {}

    def inc(self, name: str, value: float = 1.0, **labels):
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0.0) + float(value)

    def gauge(self, name: str, value: float, **labels):
        self._gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels):
        self._hists.setdefault(_key(name, labels), []).append(float(value))

    def snapshot(self) -> List[Dict]:
        """One ``metric`` event dict per series (JSONL-ready)."""
        out = []
        for (name, labels), v in sorted(self._counters.items()):
            out.append({"type": "metric", "kind": "counter", "name": name,
                        "labels": dict(labels), "value": v})
        for (name, labels), v in sorted(self._gauges.items()):
            out.append({"type": "metric", "kind": "gauge", "name": name,
                        "labels": dict(labels), "value": v})
        for (name, labels), vals in sorted(self._hists.items()):
            a = np.asarray(vals)
            out.append({"type": "metric", "kind": "histogram", "name": name,
                        "labels": dict(labels), "count": int(a.size),
                        "mean": float(a.mean()), "min": float(a.min()),
                        "max": float(a.max()),
                        "p50": float(np.percentile(a, 50)),
                        "p95": float(np.percentile(a, 95)),
                        "p99": float(np.percentile(a, 99))})
        return out


# -- module-level helpers over the process recorder ------------------------

def inc(name: str, value: float = 1.0, **labels) -> None:
    _ev.get_recorder().metrics.inc(name, value, **labels)


def gauge(name: str, value: float, **labels) -> None:
    _ev.get_recorder().metrics.gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    _ev.get_recorder().metrics.observe(name, value, **labels)

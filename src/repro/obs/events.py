"""Structured tracing: a process-global ``Recorder`` with a null
default, nested spans on a monotonic clock, and versioned JSONL
emission.

Design rules (DESIGN.md §9):

- **Null by default, zero overhead off.** The module-global recorder is
  a ``NullRecorder`` whose ``span()`` returns one shared no-op context
  manager — a disabled ``with obs.span(...)`` is a dict-free attribute
  lookup plus two no-op calls, unmeasurable against the fleet loop's
  per-epoch work (acceptance: ``fleet_sim`` within 2% with recording
  off).
- **Recording never changes results.** Spans and events read the
  monotonic clock and append dicts; they consume no RNG and touch no
  simulation state, so ``SimResult``/``ComparisonReport`` are
  bit-identical with recording on vs. off (tested).
- **No host callbacks on traced paths.** Nothing here may be called
  from *inside* a jitted computation (no ``io_callback``/``debug``
  hooks): spans wrap host-side calls around jit boundaries, and the
  JAX accounting (``repro.obs.jaxmon``) hooks trace/compile time only —
  both run host-side, outside the compiled graph.

JSONL schema (``SCHEMA_VERSION``): the first line is a meta record
``{"type": "meta", "schema": 1, "clock": "perf_counter", "meta": {...}}``;
every following line is one event with a ``type`` in {span, event, log,
metric, jax}, a monotonic ``t`` (seconds since the recorder started)
and a total-order ``seq``. Spans are emitted at *exit* (so a parent
follows its children in the file) and carry ``dur`` (seconds),
``depth`` and ``parent``; ``read_events`` round-trips the file and
checks the schema version.
"""
from __future__ import annotations

import json
import sys
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

EVENT_TYPES = ("span", "event", "log", "metric", "jax")


# --------------------------------------------------------------------------
# null (disabled) implementation — the process default
# --------------------------------------------------------------------------

class _NullSpan:
    """Shared no-op context manager: the entire cost of a disabled
    span."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _NullMetrics:
    __slots__ = ()

    def inc(self, name, value=1.0, **labels):
        return None

    def gauge(self, name, value, **labels):
        return None

    def observe(self, name, value, **labels):
        return None


class NullRecorder:
    """Disabled recorder: every hook is a no-op."""

    enabled = False
    metrics = _NullMetrics()

    def span(self, name, /, **attrs):
        return _NULL_SPAN

    def event(self, name, /, **attrs):
        return None

    def log_event(self, level: str, msg: str):
        return None

    def close(self):
        return None


# --------------------------------------------------------------------------
# live implementation
# --------------------------------------------------------------------------

class _Span:
    """Timed nested region. Enter pushes onto the recorder's span
    stack; exit records one ``span`` event with start/duration/depth/
    parent. Single-threaded by design (the fleet loop is)."""

    __slots__ = ("_rec", "name", "attrs", "_t0")

    def __init__(self, rec: "Recorder", name: str, attrs: Dict):
        self._rec = rec
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._rec._stack.append(self.name)
        self._t0 = self._rec.clock()
        return self

    def __exit__(self, *exc):
        rec = self._rec
        t1 = rec.clock()
        stack = rec._stack
        stack.pop()
        ev = {"type": "span", "name": self.name,
              "t": self._t0 - rec.t0, "dur": t1 - self._t0,
              "depth": len(stack),
              "parent": stack[-1] if stack else None}
        if self.attrs:
            ev["attrs"] = self.attrs
        rec._emit(ev)
        return False


def _json_default(o):
    """numpy scalars/arrays inside attrs serialize as plain JSON."""
    if hasattr(o, "item"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


class Recorder:
    """In-memory event recorder, optionally flushed to a JSONL file on
    ``close()``. Install process-wide with ``set_recorder`` or the
    ``recording(...)`` context manager."""

    enabled = True

    def __init__(self, path: Optional[str] = None,
                 meta: Optional[Dict] = None, clock=time.perf_counter,
                 flush_every: Optional[int] = None):
        from repro.obs.metrics import Metrics

        self.path = path
        self.clock = clock
        self.t0 = clock()
        self.meta = dict(meta or {})
        self.events: List[Dict] = []
        self.metrics = Metrics()
        self._stack: List[str] = []
        self._seq = 0
        self._closed = False
        # opt-in incremental flushing: every N events the tail is
        # appended to the file, so a killed long run loses at most the
        # last N events (read back with the truncation-tolerant
        # read_events). Default None keeps the single write at close().
        self._flush_every = int(flush_every) if flush_every else None
        self._written = 0          # events already flushed to the file
        self._fh = None
        # jax compile accounting: snapshot the process counters now,
        # emit the delta as one "jax" summary event at close
        self._jax0: Optional[Dict] = None
        try:
            from repro.obs import jaxmon
            jaxmon.install()
            self._jax0 = jaxmon.compile_stats()
        except Exception:       # jax absent: plain tracing still works
            self._jax0 = None

    def _emit(self, ev: Dict):
        ev["seq"] = self._seq
        self._seq += 1
        self.events.append(ev)
        if self._flush_every and self.path and \
                len(self.events) - self._written >= self._flush_every:
            self._flush()

    def _flush(self):
        """Append the unwritten event tail to the file (opens it — and
        writes the meta header — on first use)."""
        if self._fh is None:
            self._fh = open(self.path, "w")
            self._fh.write(json.dumps(
                {"type": "meta", "schema": SCHEMA_VERSION,
                 "clock": "perf_counter", "meta": self.meta},
                default=_json_default) + "\n")
        for ev in self.events[self._written:]:
            self._fh.write(json.dumps(ev, default=_json_default) + "\n")
        self._written = len(self.events)
        self._fh.flush()

    def span(self, name: str, /, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def event(self, name: str, /, **attrs):
        ev = {"type": "event", "name": name, "t": self.clock() - self.t0}
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)

    def log_event(self, level: str, msg: str):
        self._emit({"type": "log", "level": level, "msg": msg,
                    "t": self.clock() - self.t0})

    def report(self) -> Dict:
        """Fold this recorder's events into a summary (repro.obs.report)."""
        from repro.obs.report import fold
        return fold(self.events, meta=self.meta)

    def close(self):
        """Flush metrics + the jax compile delta, then write the JSONL
        file (when a path was given). Idempotent."""
        if self._closed:
            return
        self._closed = True
        t = self.clock() - self.t0
        for m in self.metrics.snapshot():
            m["t"] = t
            self._emit(m)
        if self._jax0 is not None:
            from repro.obs import jaxmon
            now = jaxmon.compile_stats()
            delta = {k: now[k] - self._jax0.get(k, 0)
                     for k in now if now[k] != self._jax0.get(k, 0)}
            self._emit({"type": "jax", "t": t, "compile": delta,
                        "traces": jaxmon.trace_counts()})
        if self._fh is not None:
            self._flush()
            self._fh.close()
            self._fh = None
        elif self.path:
            with open(self.path, "w") as f:
                f.write(json.dumps(
                    {"type": "meta", "schema": SCHEMA_VERSION,
                     "clock": "perf_counter", "meta": self.meta},
                    default=_json_default) + "\n")
                for ev in self.events:
                    f.write(json.dumps(ev, default=_json_default) + "\n")


# --------------------------------------------------------------------------
# process-global recorder + module-level hooks (the instrumentation API)
# --------------------------------------------------------------------------

_NULL = NullRecorder()
_RECORDER = _NULL


def get_recorder():
    return _RECORDER


def set_recorder(rec) -> None:
    """Install ``rec`` process-wide (None restores the null default)."""
    global _RECORDER
    _RECORDER = rec if rec is not None else _NULL


def span(name: str, /, **attrs):
    """Nested timed region on the active recorder (no-op when off)."""
    return _RECORDER.span(name, **attrs)


def event(name: str, /, **attrs) -> None:
    """Point-in-time structured event (no-op when off)."""
    return _RECORDER.event(name, **attrs)


@contextmanager
def recording(path: Optional[str] = None, meta: Optional[Dict] = None,
              flush_every: Optional[int] = None):
    """Install a fresh Recorder for the block; restore the previous one
    and close (flush/write) on exit. Yields the recorder."""
    prev = _RECORDER
    rec = Recorder(path=path, meta=meta, flush_every=flush_every)
    set_recorder(rec)
    try:
        yield rec
    finally:
        set_recorder(prev)
        rec.close()


def read_events(path: str) -> Tuple[Dict, List[Dict]]:
    """Load a JSONL event file -> (meta, events). Fails loudly on a
    missing/mismatched schema version. A truncated *final* line — a run
    killed mid-write under ``flush_every`` — is skipped silently;
    corruption anywhere else still raises."""
    with open(path) as f:
        raw = [s for s in f if s.strip()]
    lines = []
    for i, s in enumerate(raw):
        try:
            lines.append(json.loads(s))
        except json.JSONDecodeError:
            if i == len(raw) - 1:
                break               # torn tail from a killed run
            raise ValueError(f"{path}: corrupt JSONL at line {i + 1}")
    if not lines or lines[0].get("type") != "meta":
        raise ValueError(f"{path}: not an obs event file (no meta header)")
    meta = lines[0]
    if meta.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema {meta.get('schema')!r} != "
                         f"supported {SCHEMA_VERSION}")
    events = lines[1:]
    bad = [e for e in events if e.get("type") not in EVENT_TYPES]
    if bad:
        raise ValueError(f"{path}: unknown event type(s) "
                         f"{sorted({e.get('type') for e in bad})}")
    return meta, events


# --------------------------------------------------------------------------
# structured logging (the print() replacement)
# --------------------------------------------------------------------------
#
# Verbosity gates what reaches the console; every log is additionally
# recorded as a "log" event when recording is on, so --quiet runs still
# keep their story in the JSONL.

_VERBOSITY = 1          # 0 = warnings only, 1 = info, 2 = debug


def set_verbosity(level: int) -> None:
    global _VERBOSITY
    _VERBOSITY = int(level)


def get_verbosity() -> int:
    return _VERBOSITY


def log(msg: str, level: str = "info") -> None:
    _RECORDER.log_event(level, msg)
    if level == "warn":
        print(msg, file=sys.stderr, flush=True)
    elif level == "info" and _VERBOSITY >= 1:
        print(msg, flush=True)
    elif level == "debug" and _VERBOSITY >= 2:
        print(msg, flush=True)


def info(msg: str) -> None:
    log(msg, "info")


def debug(msg: str) -> None:
    log(msg, "debug")


def warn(msg: str) -> None:
    log(msg, "warn")

"""repro.sim: trace-driven fleet simulator closing the loop between the
EdgeRL controller and the executable serving stack.

- ``traces``   — pluggable per-device request arrival generators
  (Poisson, MMPP bursty, diurnal sinusoid, replay-from-array).
- ``metrics``  — per-request latency percentiles, SLO attainment,
  goodput and energy (schema shared with ``serving.ServerStats``).
- ``backends`` — request pricing: a fast analytical backend over the
  env's latency/energy/ProfileTables machinery, and an execute backend
  that cross-checks a sampled subset through ``SplitServingEngine``.
- ``fleet``    — the discrete-event loop: each decision epoch the
  controller picks (version, cut) per device from *measured* state.
- ``megafleet`` — the vectorized engines behind
  ``FleetConfig(engine=...)``: the whole epoch as fused
  (devices,)-array ops in numpy (bit-identical to the loop oracle) or
  as a jitted ``jax.lax.scan`` over epochs with an opt-in sharded
  device axis — 100k+ devices per host.
"""
from repro.sim.traces import (DiurnalTrace, MMPPTrace, PoissonTrace,
                              RandomRateTrace, ReplayTrace, Trace,
                              get_trace, presample_counts, trace_names)
from repro.sim.metrics import (EpochLog, FleetMetrics, LATENCY_SCHEMA,
                               summarize_latencies)
from repro.sim.backends import AnalyticalBackend, ExecuteBackend
from repro.sim.fleet import ENGINES, FleetConfig, SimResult, simulate
from repro.sim.megafleet import lindley_core, simulate_scan

__all__ = [
    "Trace", "PoissonTrace", "MMPPTrace", "DiurnalTrace", "ReplayTrace",
    "RandomRateTrace",
    "get_trace", "trace_names", "presample_counts",
    "EpochLog", "FleetMetrics", "LATENCY_SCHEMA", "summarize_latencies",
    "AnalyticalBackend", "ExecuteBackend", "FleetConfig", "SimResult",
    "simulate", "ENGINES", "lindley_core", "simulate_scan",
]

"""Fleet simulation via the scenario API (DESIGN.md §4/§7): take the
``paper-mmpp-burst`` preset — train the stability-aware controller under
domain-randomized load, then stress it against bursty (MMPP) traffic
next to the static baselines, the same request stream for every policy —
and optionally persist the trained controller as a reusable artifact.

    PYTHONPATH=src python examples/fleet_sim.py [--devices 4] \
        [--save-policy controller.npz]
"""
import argparse

from repro.scenarios import get_scenario, run_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--episodes", type=int, default=500)
    ap.add_argument("--requests", type=int, default=20_000)
    ap.add_argument("--save-policy", default=None,
                    help="persist the trained controller (.npz)")
    args = ap.parse_args()

    scenario = get_scenario("paper-mmpp-burst").replace(
        devices=args.devices, episodes=args.episodes,
        n_requests=args.requests)
    report = run_scenario(
        scenario, ("a2c", "device_only", "full_offload"),
        save_policies={"a2c": args.save_policy} if args.save_policy
        else None,
        verbose=True)

    best = max(report.results.values(),
               key=lambda r: r.mean["slo_attainment"])
    print(f"\nbest SLO attainment: {best.name} "
          f"({best.mean['slo_attainment']:.3f} over paired seeds "
          f"{list(report.seeds)})")


if __name__ == "__main__":
    main()

"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th slot.

100 layers total = 80 self-attention decoder layers + 20 gated
cross-attention layers. The ViT vision tower + projector is a STUB:
``input_specs`` supplies precomputed patch embeddings (n_media_tokens,
d_model). [hf:meta-llama/Llama-3.2-11B-Vision scaled per 90B card]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-90B-Vision",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_media_tokens=1601,    # one image tile: (448/14)^2 + 1 cls
    norm="rmsnorm",
    mlp_act="swiglu",
))

"""JAX-aware accounting: jit trace counts per call site + process-wide
compile-time totals via ``jax.monitoring``.

Two independent mechanisms, both host-side (nothing is inserted into a
compiled graph — the no-host-callback rule of DESIGN.md §9):

**Trace counting** — ``count_trace(site)`` is placed inside the Python
body of an instrumented jit site (``Policy.jitted``, the online
learner's capture/update jits). jit executes that body only while
*tracing*; a cache hit never runs it. Each call therefore increments
the site's counter exactly once per (re-)trace and costs nothing at
execution time. This turns PR 5's "params hot-swapped without
re-tracing" comment into a measured invariant: across an online
adaptation run the ``decide.<policy>`` counter must not move on a
param hot-swap, and must move exactly once on a genuine shape change
(tests/test_obs.py).

**Compile accounting** — a ``jax.monitoring`` duration listener
accumulates the number and wall-time of jaxpr traces, MLIR lowerings
and backend compiles process-wide, and mirrors each into the active
recorder as a ``jax.compile`` event. ``Recorder`` snapshots these at
start and emits the delta as one ``jax`` summary event at close, so an
events.jsonl tells you how much of a run was spent compiling. The
listener registers once (jax.monitoring has no unregister) and does
work only when a compile actually happens.
"""
from __future__ import annotations

import collections
from contextlib import contextmanager
from typing import Dict

from repro.obs import events as _ev

# site -> number of times jit traced it (process-wide, monotone)
_TRACE_COUNTS: collections.Counter = collections.Counter()

# compile/trace/lowering totals from jax.monitoring (process-wide)
_COMPILE: collections.Counter = collections.Counter()
_INSTALLED = False

_DURATION_EVENTS = {
    "/jax/core/compile/jaxpr_trace_duration": "jaxpr_trace",
    "/jax/core/compile/jaxpr_to_mlir_module_duration": "mlir_lower",
    "/jax/core/compile/backend_compile_duration": "backend_compile",
}


def count_trace(site: str) -> None:
    """Record one jit (re-)trace of ``site``. Call from inside the
    traced Python body — it runs at trace time only, never inside the
    compiled computation."""
    _TRACE_COUNTS[site] += 1
    rec = _ev.get_recorder()
    if rec.enabled:
        rec.event("jax.trace", site=site, n=_TRACE_COUNTS[site])


def trace_counts() -> Dict[str, int]:
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


@contextmanager
def track_traces():
    """Yields a dict filled (on exit) with per-site trace-count deltas
    for the block: ``{} `` means no site re-traced."""
    before = dict(_TRACE_COUNTS)
    delta: Dict[str, int] = {}
    try:
        yield delta
    finally:
        for k, v in _TRACE_COUNTS.items():
            d = v - before.get(k, 0)
            if d:
                delta[k] = d


def install() -> None:
    """Register the jax.monitoring listener (idempotent)."""
    global _INSTALLED
    if _INSTALLED:
        return
    import jax.monitoring

    def _on_duration(event: str, duration: float, **kw) -> None:
        key = _DURATION_EVENTS.get(event)
        if key is None:
            return
        _COMPILE[key + "_n"] += 1
        _COMPILE[key + "_s"] += duration
        rec = _ev.get_recorder()
        if rec.enabled:
            rec.event("jax.compile", phase=key, dur=duration)

    jax.monitoring.register_event_duration_secs_listener(_on_duration)
    _INSTALLED = True


def compile_stats() -> Dict[str, float]:
    """Process-wide compile totals: {phase}_n counts and {phase}_s
    seconds for jaxpr_trace / mlir_lower / backend_compile."""
    install()
    return dict(_COMPILE)


@contextmanager
def track_compiles():
    """Yields a dict filled (on exit) with compile-stat deltas for the
    block; ``backend_compile_n`` is the number of XLA compilations it
    triggered."""
    install()
    before = dict(_COMPILE)
    delta: Dict[str, float] = {}
    try:
        yield delta
    finally:
        for k, v in _COMPILE.items():
            d = v - before.get(k, 0)
            if d:
                delta[k] = d

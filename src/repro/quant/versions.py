"""QuantVersion registry: the executable model-version axis of the EdgeRL
action space.

The paper's "DNN version j" is a compression-derived variant trading
accuracy for compute/bytes (VGG11 vs VGG19). Here a version is a
quantization level of the same trunk:

  bf16 — full-precision baseline (no quantization)
  w8   — w8a8: int8 weights + dynamic int8 activations; runs on the MXU's
         int8 path (2x MAC throughput) and ships int8 cut activations
  w4   — int4-packed weight-only: 4x smaller weights, full-precision math

Everything the env's ProfileTables needs per version is *derived* here
instead of hand-tuned: the accuracy proxy from measured quantization error
on a probe layer, the FLOP cost scale from the int8 MXU speedup, activation
itemsize from the shipped dtype, and weight bytes from the code width.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.quant.quantize import quantize, quantize_act, quantize_tree


@dataclasses.dataclass(frozen=True)
class QuantVersion:
    name: str
    weight_bits: int = 16
    act_bits: int = 0           # 0 = activations stay in compute dtype

    @property
    def mode(self) -> Optional[str]:
        """quantize_tree mode; None = leave params untouched."""
        if self.weight_bits >= 16:
            return None
        if self.weight_bits == 4:
            return "w4"
        return "w8a8" if self.act_bits == 8 else "w8wo"

    @property
    def bytes_per_param(self) -> float:
        """Nominal wire width of the quantized weight codes, per param.

        Only meaningful for quantized versions: profiles price
        full-precision leaves (and the whole tree when mode is None) at
        the config's actual param dtype width (cfg.pdtype.itemsize), not
        this number — see profiles.build_quant_versions."""
        return self.weight_bits / 8.0

    @property
    def act_itemsize(self) -> int:
        """Nominal link width of the cut activation: 1 for int8-shipping
        versions. The 2 for full-precision versions assumes the TPU bf16
        serving regime; profiles override it with the config's actual
        compute dtype width (cfg.cdtype.itemsize)."""
        return 1 if self.act_bits == 8 else 2

    @property
    def matmul_cost_scale(self) -> float:
        """Effective FLOP cost multiplier: int8 x int8 runs at 2x MXU
        throughput, so a w8a8 MAC costs half a bf16 MAC."""
        return 0.5 if (self.weight_bits <= 8 and self.act_bits == 8) else 1.0


_REGISTRY: Dict[str, QuantVersion] = {
    "bf16": QuantVersion("bf16", weight_bits=16, act_bits=0),
    "w8": QuantVersion("w8", weight_bits=8, act_bits=8),
    "w4": QuantVersion("w4", weight_bits=4, act_bits=0),
}

DEFAULT_VERSIONS = ("bf16", "w8", "w4")


def get_version(name: str) -> QuantVersion:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown quant version {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_versions() -> Dict[str, QuantVersion]:
    return dict(_REGISTRY)


@functools.lru_cache(maxsize=None)
def relative_quant_error(weight_bits: int, act_bits: int, *, d: int = 512,
                         f: int = 512, rows: int = 32,
                         seed: int = 0) -> float:
    """Measured relative output error of one quantized dense projection.

    Probe: a fan-in-scaled gaussian weight (the init distribution of every
    dense projection in models/) against gaussian activations. This is the
    *measured* accuracy cost of a version — profiles derive their accuracy
    proxy from it instead of a hand-tuned constant.
    """
    qv = QuantVersion("probe", weight_bits, act_bits)
    if qv.mode is None:
        return 0.0
    k1, k2 = jax.random.split(jax.random.key(seed))
    w = jax.random.normal(k1, (d, f), jnp.float32) * d ** -0.5
    x = jax.random.normal(k2, (rows, d), jnp.float32)
    y = x @ w
    qt = quantize(w, qv.mode)
    if qv.act_bits == 8:
        from repro.kernels.quant_matmul import quant_matmul_ref
        xq, xs = quantize_act(x)
        yq = quant_matmul_ref(xq, qt.q, xs.reshape(-1), qt.scale.reshape(-1))
    else:
        yq = x @ qt.dequantize()
    num = jnp.linalg.norm(y - yq)
    den = jnp.maximum(jnp.linalg.norm(y), 1e-12)
    return float(num / den)


def accuracy_proxy(qv: QuantVersion, base_acc: float = 0.75,
                   dense_frac: float = 1.0) -> float:
    """Version accuracy for the env tables: baseline accuracy degraded by
    the measured per-layer quantization error (SNR-proportional proxy).

    ``dense_frac`` is the fraction of the model's compute that actually
    runs through quantized dense projections (profiles pass the
    dense-share / total-FLOPs ratio) — an SSM trunk whose mixers stay
    full precision must not be charged the full dense-probe error."""
    err = relative_quant_error(qv.weight_bits, qv.act_bits) * dense_frac
    return base_acc * (1.0 - err)


def build_version_params(cfg, params,
                         versions: Sequence[str] = DEFAULT_VERSIONS) -> Dict:
    """Materialize the quantized param tree for each requested version.

    Returns {version_name: params} where bf16 aliases the input tree and
    quantized versions share nothing with it (fresh QTensor leaves).
    """
    out = {}
    for name in versions:
        qv = get_version(name)
        out[name] = params if qv.mode is None else quantize_tree(params,
                                                                 qv.mode)
    return out
